"""End-to-end training driver (paper §5.4 scaled to this container):
train an AR transformer with DiffusionBlocks on a synthetic corpus for a few
hundred steps, with LR schedule, gradient clipping, block-wise checkpointing,
periodic eval, and a final side-by-side against end-to-end backprop.

    PYTHONPATH=src python examples/train_ar_diffusionblocks.py \
        [--steps 300] [--blocks 4] [--width 128] [--layers 8] [--e2e-compare]

At --width 768 --layers 12 this is the paper's exact §5.4 architecture
(~100M params); the default is sized for CPU minutes.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_blocks, save_block
from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import make_db_train_step, make_e2e_train_step
from repro.data import MarkovLM, HostDataLoader
from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ar_db")
    ap.add_argument("--e2e-compare", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(name="ar-db", family="dense", n_layers=args.layers,
                      d_model=args.width, n_heads=max(args.width // 32, 2),
                      n_kv_heads=max(args.width // 32, 2),
                      d_ff=args.width * 4, vocab_size=args.vocab)
    db = DBConfig(num_blocks=args.blocks, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params, {args.layers} layers, "
          f"B={args.blocks} blocks -> {args.layers//args.blocks} layers/block")

    lm = MarkovLM(vocab_size=args.vocab, branching=4, seed=11)
    data = HostDataLoader(lm.iterator(args.batch, args.seq, seed=1))
    tcfg = TrainConfig(steps=args.steps, lr=args.lr,
                       warmup_steps=args.steps // 10, grad_clip=1.0)

    rng = jax.random.PRNGKey(0)
    rng, r0 = jax.random.split(rng)
    params = dbm.init(r0)
    steppers, opts = [], []
    for b in range(db.num_blocks):
        io, st = make_db_train_step(dbm, b, tcfg)
        steppers.append(st)
        opts.append(io(params))

    t0 = time.time()
    per_block_losses = {b: [] for b in range(db.num_blocks)}
    for it in range(args.steps):
        rng, rb, rs = jax.random.split(rng, 3)
        b = int(jax.random.randint(rb, (), 0, db.num_blocks))
        params, opts[b], loss, m = steppers[b](params, opts[b], next(data),
                                               rs, None)
        per_block_losses[b].append(float(loss))
        if it % 50 == 0:
            print(f"it={it:4d} block={b} loss={float(loss):.4f} "
                  f"lr={float(m['lr']):.2e} gn={float(m['grad_norm']):.2f}")

    print(f"train time: {time.time()-t0:.1f}s")
    for b in range(db.num_blocks):
        l = per_block_losses[b]
        if l:
            print(f"block {b}: first={np.mean(l[:3]):.3f} "
                  f"last={np.mean(l[-3:]):.3f} (σ∈{dbm.edges[b+1]:.3f}"
                  f"..{dbm.edges[b]:.2f})")

    # block-wise checkpoints (each pod would write only its own block)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    for b, (s, z) in enumerate(dbm.ranges):
        save_block(args.ckpt_dir, params, b, s, z, step=args.steps)
    print("checkpoints:", sorted(os.listdir(args.ckpt_dir)))
    restored = load_blocks(args.ckpt_dir,
                           jax.tree_util.tree_map(jnp.zeros_like, params),
                           dbm.ranges)
    ok = all(np.allclose(a, b) for a, b in
             zip(jax.tree_util.tree_leaves(restored),
                 jax.tree_util.tree_leaves(params)))
    print("block-checkpoint roundtrip:", "OK" if ok else "MISMATCH")

    # generation eval
    prompts = jnp.asarray(lm.sample(np.random.RandomState(3), 4, 12))
    out = generate(dbm, params, prompts, max_new=24)
    print("DB generation legal-rate:",
          lm.transition_accuracy(np.array(out)))

    if args.e2e_compare:
        rng = jax.random.PRNGKey(0)
        rng, r0 = jax.random.split(rng)
        params_e = dbm.init(r0)
        io, step = make_e2e_train_step(dbm, tcfg)
        opt = io(params_e)
        data2 = HostDataLoader(lm.iterator(args.batch, args.seq, seed=1))
        for it in range(args.steps):
            rng, rs = jax.random.split(rng)
            params_e, opt, loss, _ = step(params_e, opt, next(data2), rs,
                                          None)
            if it % 50 == 0:
                print(f"[e2e] it={it:4d} loss={float(loss):.4f}")
        data2.close()
    data.close()


if __name__ == "__main__":
    main()
