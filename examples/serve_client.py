"""Streaming HTTP/SSE client demo against the serving frontend — start an
in-process ``InferenceServer`` on a tiny model, then exercise the whole
endpoint surface: stream a request token-by-token (asserting the SSE
reassembly equals the ``done`` event), cancel a long request mid-stream
(pages return to the pool immediately), run a non-streaming request,
and read ``/v1/health`` before and after a graceful drain.

    PYTHONPATH=src python examples/serve_client.py

Against an external server (``python -m repro.launch.server --port 8080``)
the same client calls work with ``host, port = "127.0.0.1", 8080``.
Wire format: docs/api.md.
"""
import asyncio

import jax
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher
from repro.launch.server import (InferenceServer, request_json,
                                 stream_generate)


def build_server():
    cfg = ModelConfig(name="client-ex", family="dense", n_layers=4,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=32)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=12,
                           max_len=40, seg_len=3, page_size=4,
                           chunk_size=4, precision="fp32")
    return InferenceServer(cb, rng=jax.random.PRNGKey(7))


async def main():
    server = build_server()
    await server.start()
    host, port = server.host, server.port
    print(f"serving on {host}:{port}")
    rs = np.random.RandomState(0)

    # ---- streaming: one SSE `token` event per decode segment -------------
    prompt = [int(t) for t in rs.randint(0, 32, size=6)]
    r = await stream_generate(host, port, prompt, max_new=12)
    assert r["status"] == 200 and not r["final"]["cancelled"]
    assert r["ids"] == r["final"]["ids"]      # reassembly == done event
    print(f"request {r['request_id']}: {r['events']} SSE events, "
          f"ids={r['ids']}, ttft={r['final'].get('ttft_ms')}ms")

    # ---- mid-stream cancellation: POST /v1/cancel after 4 tokens ---------
    r = await stream_generate(host, port, prompt, max_new=24,
                              cancel_after=4)
    assert r["final"]["cancelled"] and 0 < len(r["ids"]) < 24
    print(f"request {r['request_id']}: cancelled after {len(r['ids'])} "
          "tokens, pages freed")

    # ---- non-streaming: single JSON response -----------------------------
    code, out = await request_json(host, port, "POST", "/v1/generate",
                                   {"prompt": prompt, "max_new": 8,
                                    "stream": False})
    assert code == 200 and len(out["ids"]) == 8
    print(f"request {out['request_id']}: non-streaming ids={out['ids']}")

    # ---- health + graceful drain -----------------------------------------
    _, health = await request_json(host, port, "GET", "/v1/health")
    print(f"health: {health}")
    await server.drain()
    code, out = await request_json(host, port, "POST", "/v1/generate",
                                   {"prompt": prompt, "max_new": 4})
    assert code == 503
    print(f"after drain: new requests rejected with 503 ({out['error']})")
    await server.aclose()


if __name__ == "__main__":
    asyncio.run(main())
