"""Streaming HTTP/SSE client demo against the serving frontend — start an
in-process ``InferenceServer`` on a tiny model, then exercise the whole
endpoint surface: stream a request token-by-token (asserting the SSE
reassembly equals the ``done`` event), cancel a long request mid-stream
(pages return to the pool immediately), run a non-streaming request,
ride out admission-control sheds with a backoff-and-retry helper, and
read ``/v1/health`` before and after a graceful drain.

    PYTHONPATH=src python examples/serve_client.py

Against an external server (``python -m repro.launch.server --port 8080``)
the same client calls work with ``host, port = "127.0.0.1", 8080``.
Wire format: docs/api.md.
"""
import asyncio

import jax
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector
from repro.launch.serve import ContinuousBatcher
from repro.launch.server import (InferenceServer, request_json,
                                 stream_generate)


def build_server(*, num_slots=2, faults=None, **cb_kw):
    cfg = ModelConfig(name="client-ex", family="dense", n_layers=4,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=32)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(dbm, params, num_slots=num_slots, max_prompt=12,
                           max_len=40, seg_len=3, page_size=4,
                           chunk_size=4, precision="fp32", faults=faults,
                           **cb_kw)
    return InferenceServer(cb, rng=jax.random.PRNGKey(7))


async def generate_with_retry(host, port, payload, *, max_attempts=8,
                              base_delay=0.05):
    """POST ``/v1/generate`` and retry on 429/503 with exponential backoff.

    The server's ``Retry-After`` header (seconds, from its service-time
    EWMA) overrides the local backoff when present — honoring it keeps a
    shed client from hammering an overloaded server. Returns
    ``(code, obj, attempts)`` with the first non-shed response.
    """
    delay = base_delay
    for attempt in range(1, max_attempts + 1):
        code, obj, hdrs = await request_json(
            host, port, "POST", "/v1/generate", payload,
            return_headers=True)
        if code not in (429, 503):
            return code, obj, attempt
        hint = hdrs.get("retry-after")
        wait = float(hint) if hint is not None else delay
        print(f"  attempt {attempt}: {code} ({obj.get('error')}), "
              f"retrying in {wait:.2f}s")
        await asyncio.sleep(wait)
        delay = min(delay * 2, 2.0)
    return code, obj, max_attempts


async def main():
    server = build_server()
    await server.start()
    host, port = server.host, server.port
    print(f"serving on {host}:{port}")
    rs = np.random.RandomState(0)

    # ---- streaming: one SSE `token` event per decode segment -------------
    prompt = [int(t) for t in rs.randint(0, 32, size=6)]
    r = await stream_generate(host, port, prompt, max_new=12)
    assert r["status"] == 200 and not r["final"]["cancelled"]
    assert r["ids"] == r["final"]["ids"]      # reassembly == done event
    print(f"request {r['request_id']}: {r['events']} SSE events, "
          f"ids={r['ids']}, ttft={r['final'].get('ttft_ms')}ms")

    # ---- mid-stream cancellation: POST /v1/cancel after 4 tokens ---------
    r = await stream_generate(host, port, prompt, max_new=24,
                              cancel_after=4)
    assert r["final"]["cancelled"] and 0 < len(r["ids"]) < 24
    print(f"request {r['request_id']}: cancelled after {len(r['ids'])} "
          "tokens, pages freed")

    # ---- non-streaming: single JSON response -----------------------------
    code, out = await request_json(host, port, "POST", "/v1/generate",
                                   {"prompt": prompt, "max_new": 8,
                                    "stream": False})
    assert code == 200 and len(out["ids"]) == 8
    print(f"request {out['request_id']}: non-streaming ids={out['ids']}")

    # ---- admission control: shed + backoff-and-retry ---------------------
    # A deliberately overloaded server (1 slot, queue depth 1, and a chaos
    # hook stalling token delivery) sheds the probe with 429 + Retry-After;
    # `generate_with_retry` backs off and lands once the queue drains.
    crowded = build_server(
        num_slots=1, max_queue=1,
        faults=FaultInjector({"token_stall": {"every": 1, "sleep": 0.1}}))
    await crowded.start()
    streams = [asyncio.ensure_future(
        stream_generate(crowded.host, crowded.port, prompt, max_new=10))]
    while True:                                # first request must be ACTIVE
        _, h = await request_json(crowded.host, crowded.port, "GET",
                                  "/v1/health")
        if h["active_slots"] >= 1 and h["queued"] == 0:
            break
        await asyncio.sleep(0.005)
    streams.append(asyncio.ensure_future(     # second fills the queue
        stream_generate(crowded.host, crowded.port, prompt, max_new=10)))
    while (await request_json(crowded.host, crowded.port, "GET",
                              "/v1/health"))[1]["queued"] < 1:
        await asyncio.sleep(0.005)
    print("overloaded server: probing with retry-on-shed")
    code, out, attempts = await generate_with_retry(
        crowded.host, crowded.port,
        {"prompt": prompt, "max_new": 4, "stream": False})
    assert code == 200 and len(out["ids"]) == 4
    print(f"request {out['request_id']}: admitted after {attempts} "
          f"attempt(s), ids={out['ids']}")
    assert all(r["status"] == 200 for r in await asyncio.gather(*streams))
    await crowded.aclose()

    # ---- health + graceful drain -----------------------------------------
    _, health = await request_json(host, port, "GET", "/v1/health")
    print(f"health: {health}")
    await server.drain()
    code, out = await request_json(host, port, "POST", "/v1/generate",
                                   {"prompt": prompt, "max_new": 4})
    assert code == 503
    print(f"after drain: new requests rejected with 503 ({out['error']})")
    await server.aclose()


if __name__ == "__main__":
    asyncio.run(main())
