"""Block-parallel training demo: every DiffusionBlocks block advances
concurrently on its own ``pod`` mesh group — the paper's gradient isolation
(§3) turned into wall-clock speedup instead of just memory savings.

    PYTHONPATH=src python examples/block_parallel_train.py

The script forces 8 virtual CPU devices so the shard_map path (pod=4 ×
data=2) runs anywhere; on real hardware drop the XLA_FLAGS line and give
each block a TPU/GPU pod group. With fewer devices than blocks the trainer
degrades to the round-robin schedule — same losses, no parallelism.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                         # noqa: E402
import jax.numpy as jnp                                            # noqa: E402
import numpy as np                                                 # noqa: E402

from repro.configs import DBConfig                                 # noqa: E402
from repro.configs.base import ModelConfig, TrainConfig            # noqa: E402
from repro.core import DiffusionBlocksModel                        # noqa: E402
from repro.data import MarkovLM                                    # noqa: E402
from repro.parallel import BlockParallelTrainer                    # noqa: E402


def main():
    # paper §5.4-style AR setup, B=4 blocks, reduced dims for CPU
    cfg = ModelConfig(name="bp-demo", family="dense", n_layers=8,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)
    db = DBConfig(num_blocks=4, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    print(f"devices={jax.device_count()} blocks={db.num_blocks} "
          f"unit ranges={dbm.ranges}")

    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def data():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 64))

    # tcfg.steps = TOTAL per-block updates; the trainer runs steps/B batches,
    # each advancing all four blocks in one jitted shard_map call.
    tcfg = TrainConfig(steps=160, lr=2e-3, warmup_steps=4, log_every=10)
    trainer = BlockParallelTrainer(dbm, tcfg,
                                   periphery="replicate+psum-mean")
    print(f"mode={trainer.mode}"
          + (f" mesh={dict(trainer.mesh.shape)}" if trainer.mesh else ""))

    params, hist = trainer.train(data(), jax.random.PRNGKey(0),
                                 ckpt_dir="/tmp/repro_blockpar_ckpt")
    for b in range(db.num_blocks):
        ls = [l for _, blk, l in hist if blk == b]
        print(f"block {b}: first-loss={ls[0]:.3f} last-loss={ls[-1]:.3f}")
    print("per-block checkpoints written to /tmp/repro_blockpar_ckpt "
          "(block_XX.npz + block_XX.opt.npz + periphery.opt.npz)")

    # the assembled full model generates exactly like the sequential one
    from repro.launch.serve import generate
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), 2, 8))
    out = generate(dbm, params, prompts, max_new=16)
    print("legal-transition rate:", lm.transition_accuracy(np.array(out)))


if __name__ == "__main__":
    main()
