"""Masked diffusion LM with DiffusionBlocks (paper §5.3 / App. D): the
masking schedule α(t) is partitioned by equal decrements — each block owns an
equal share of the demasking work.

    PYTHONPATH=src python examples/masked_diffusion.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.masked import MaskedDiffusionBlocks
from repro.data import MarkovLM
from repro.optim import adamw, apply_updates


def main():
    cfg = ModelConfig(name="mdm-ex", family="dense", n_layers=6, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=33,
                      norm="layernorm", mlp="gelu")
    db = DBConfig(num_blocks=3, overlap_gamma=0.0)
    mdm = MaskedDiffusionBlocks(cfg, db)
    print("masking-rate ranges per block:",
          [mdm.t_range(b) for b in range(db.num_blocks)])

    lm = MarkovLM(vocab_size=32, branching=2, seed=4)
    params = mdm.init(jax.random.PRNGKey(0))
    init, update = adamw(2e-3)
    st = init(params)
    grad_fns = [jax.jit(jax.value_and_grad(
        lambda p, t, r, b=b: mdm.block_loss(p, b, t, r)[0]))
        for b in range(db.num_blocks)]
    rng = jax.random.PRNGKey(1)
    it = np.random.RandomState(1)
    brng = np.random.RandomState(0)
    for i in range(200):
        toks = jnp.asarray(lm.sample(it, 16, 32))
        rng, r = jax.random.split(rng)
        b = brng.randint(0, db.num_blocks)
        loss, g = grad_fns[b](params, toks, r)
        upd, st, _ = update(g, st, params)
        params = apply_updates(params, upd)
        if i % 40 == 0:
            print(f"it={i:4d} block={b} loss={float(loss):.4f}")

    test = jnp.asarray(lm.sample(np.random.RandomState(9), 16, 32))
    bpc = float(mdm.nelbo_bpc(params, test, jax.random.PRNGKey(5),
                              n_samples=4))
    floor = -lm.log_likelihood(np.array(test))
    print(f"BPC: {bpc:.3f} (entropy floor of the chain: {floor:.3f})")
    gen = mdm.generate(params, jax.random.PRNGKey(6), 4, 32)
    print("generation legal-rate:", lm.transition_accuracy(np.array(gen)))


if __name__ == "__main__":
    main()
