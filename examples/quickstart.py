"""Quickstart: convert a small transformer into DiffusionBlocks, train the
blocks independently on synthetic text, and generate with the block-wise
Euler sampler.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db
from repro.data import MarkovLM
from repro.launch.serve import generate


def main():
    # 1. Any residual/transformer architecture (paper §3.1: the recipe needs
    #    only the residual structure).
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)

    # 2. DiffusionBlocks conversion: B=3 blocks, EDM noise schedule,
    #    equi-probability partitioning (§3.3), AR adapter (App. E.4).
    db = DBConfig(num_blocks=3, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    print("units per block:", dbm.ranges)
    print("sigma ranges   :", [tuple(round(x, 4) for x in
                                     dbm.edges[b:b + 2])
                               for b in range(db.num_blocks)])

    # 3. Train block-wise: each step samples ONE block; gradients exist for
    #    n_layers/B layers only.
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def data():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=150, lr=2e-3, warmup_steps=10, log_every=25)
    params, hist = train_db(dbm, tcfg, data(), jax.random.PRNGKey(0))

    # 4. Generate: denoise each new token through the blocks (σ_max -> 0).
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), 2, 8))
    out = generate(dbm, params, prompts, max_new=16)
    print("prompt+generation:", np.array(out))
    print("legal-transition rate:",
          lm.transition_accuracy(np.array(out)))


if __name__ == "__main__":
    main()
