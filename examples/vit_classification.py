"""ViT classification with DiffusionBlocks (paper §5.1): noise the label
embedding, each block denoises it within its σ-range; inference runs the
Euler chain and classifies the final estimate.

    PYTHONPATH=src python examples/vit_classification.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.vit import ViTDiffusionBlocks
from repro.data import GaussianMixtureImages
from repro.optim import adamw, apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--blocks", type=int, default=3)
    args = ap.parse_args()

    cfg = ModelConfig(name="vit-ex", family="dense", n_layers=6, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=10,
                      norm="layernorm", mlp="gelu", rope_theta=0.0)
    db = DBConfig(num_blocks=args.blocks, overlap_gamma=0.05)
    vit = ViTDiffusionBlocks(cfg, db, image_size=16, patch=4, channels=3)
    params = vit.init(jax.random.PRNGKey(0))

    g = GaussianMixtureImages(num_classes=10, image_size=16, noise_scale=0.6)
    it = np.random.RandomState(1)
    test_x, test_y = g.sample(np.random.RandomState(99), 256)
    test_x = jnp.asarray(test_x)

    init, update = adamw(2e-3)
    st = init(params)
    key = jax.random.PRNGKey(1)
    grad_fns = [jax.jit(jax.value_and_grad(
        lambda p, x, y, r, b=b: vit.block_loss(p, b, x, y, r)[0]))
        for b in range(args.blocks)]
    brng = np.random.RandomState(0)
    for i in range(args.steps):
        x, y = g.sample(it, 32)
        key, r = jax.random.split(key)
        b = brng.randint(0, args.blocks)
        loss, grads = grad_fns[b](params, jnp.asarray(x), jnp.asarray(y), r)
        upd, st, _ = update(grads, st, params)
        params = apply_updates(params, upd)
        if i % 40 == 0:
            print(f"it={i:4d} block={b} loss={float(loss):.4f}")

    pred, _ = vit.predict(params, test_x, jax.random.PRNGKey(7))
    acc = float((np.asarray(pred) == test_y).mean())
    print(f"DiffusionBlocks ViT accuracy: {acc:.3f} "
          f"(training {cfg.n_layers // args.blocks}/{cfg.n_layers} layers "
          f"at a time)")


if __name__ == "__main__":
    main()
