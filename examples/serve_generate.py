"""Batched serving with the block-wise sampler — train briefly, then serve a
batch of prompts and report throughput + quality.

    PYTHONPATH=src python examples/serve_generate.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db
from repro.data import MarkovLM
from repro.launch.serve import generate


def main():
    cfg = ModelConfig(name="serve-ex", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)
    db = DBConfig(num_blocks=3, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def data():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=150, lr=2e-3, warmup_steps=10, log_every=50)
    params, _ = train_db(dbm, tcfg, data(), jax.random.PRNGKey(0))

    batch, prompt_len, max_new = 8, 8, 32
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), batch,
                                    prompt_len))
    t0 = time.time()
    out = generate(dbm, params, prompts, max_new=max_new)
    dt = time.time() - t0
    print(f"served {batch} sequences × {max_new} new tokens in {dt:.1f}s "
          f"({batch*max_new/dt:.1f} tok/s, includes compile)")
    print("legal-transition rate:", lm.transition_accuracy(np.array(out)))
    # each denoising step touched only n_layers/B layers (paper App. H)
    print(f"layers per denoise step: {cfg.n_layers // db.num_blocks} "
          f"of {cfg.n_layers}")


if __name__ == "__main__":
    main()
