"""Batched serving with the chunked-prefill + scan-fused decode engine —
train briefly, then serve a static batch (chunked prompt ingest + one
compiled decode scan), a continuously-batched queue of ragged requests over
a shared page pool, and two requests sharing a system prompt through the
shared-prefix page cache (the second prefills only its suffix).

    PYTHONPATH=src python examples/serve_generate.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db
from repro.data import MarkovLM
from repro.launch.serve import ContinuousBatcher, get_engine


def main():
    cfg = ModelConfig(name="serve-ex", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)
    db = DBConfig(num_blocks=3, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def data():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=150, lr=2e-3, warmup_steps=10, log_every=50)
    params, _ = train_db(dbm, tcfg, data(), jax.random.PRNGKey(0))

    # ---- static batch: chunked prefill + ONE decode scan (2 dispatches) --
    batch, prompt_len, max_new = 8, 8, 32
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), batch,
                                    prompt_len))
    eng = get_engine(dbm, steps_per_block=1, temperature=0.0, top_k=0,
                     precision="bf16", impl="auto", prefill="chunked",
                     chunk_size=8)
    t0 = time.time()
    out = eng.generate(params, prompts, max_new, jax.random.PRNGKey(1))
    dt = time.time() - t0
    print(f"[static] {batch}x{max_new} tokens in {dt:.1f}s "
          f"({batch*max_new/dt:.1f} tok/s incl. compile, "
          f"{eng.dispatches} dispatches — the seed paid {1 + max_new} "
          f"plus a host sync per token; prefill took "
          f"{eng.prefill_steps} serial step(s) for {prompt_len} prompt "
          f"tokens, vs one per token)")
    print("legal-transition rate:", lm.transition_accuracy(np.array(out)))
    # each denoising step touched only n_layers/B layers (paper App. H)
    print(f"layers per denoise step: {cfg.n_layers // db.num_blocks} "
          f"of {cfg.n_layers}")

    # ---- continuous batching: ragged queue on fewer slots ----------------
    cb = ContinuousBatcher(dbm, params, num_slots=4, page_size=8,
                           max_prompt=prompt_len,
                           max_len=prompt_len + max_new, seg_len=8,
                           precision="bf16")
    rs = np.random.RandomState(3)
    for _ in range(10):
        plen = rs.randint(4, prompt_len + 1)
        cb.submit(lm.sample(rs, 1, plen)[0], max_new=max_new)
    t0 = time.time()
    done = cb.run(jax.random.PRNGKey(2))
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    # score per sequence: padding to a rectangle would fabricate transitions
    accs = [lm.transition_accuracy(
        np.concatenate([r.prompt, np.asarray(r.out, np.int64)])[None])
        for r in done]
    ttfts = [r.ttft for r in done if r.ttft is not None]
    print(f"[continuous] {len(done)} ragged requests / {n_tok} tokens on "
          f"4 slots in {dt:.1f}s ({n_tok/dt:.1f} tok/s incl. compile, "
          f"mean TTFT {np.mean(ttfts)*1e3:.0f}ms)")
    print("legal-transition rate:", float(np.mean(accs)))

    # ---- shared-prefix page cache: two requests, one system prompt -------
    # The second request's prompt extends the first one's prefix, so it maps
    # the cached prefix pages read-only and prefills ONLY its suffix (the
    # boundary page is copy-on-written if the prefix ends mid-page).
    rs2 = np.random.RandomState(7)
    system_prompt = lm.sample(rs2, 1, 24)[0]            # 6 pages of 4
    user1 = lm.sample(rs2, 1, 6)[0]
    user2 = lm.sample(rs2, 1, 6)[0]
    cb = ContinuousBatcher(dbm, params, num_slots=2, page_size=4,
                           max_prompt=32, max_len=32 + max_new, seg_len=8,
                           chunk_size=8, prefix_cache=True,
                           precision="bf16")
    cb.submit(np.concatenate([system_prompt, user1]), max_new=max_new)
    first = cb.run(jax.random.PRNGKey(4))[0]
    steps_cold = cb.eng.prefill_steps
    cb.submit(np.concatenate([system_prompt, user2]), max_new=max_new)
    second = cb.run(jax.random.PRNGKey(5))[0]
    print(f"[prefix-cache] request 1: TTFT {first.ttft*1e3:.0f}ms, "
          f"shared 0/{len(system_prompt) + len(user1)} prompt tokens "
          f"(cold)")
    print(f"[prefix-cache] request 2: TTFT {second.ttft*1e3:.0f}ms, "
          f"shared {second.shared_tokens}/"
          f"{len(system_prompt) + len(user2)} prompt tokens — prefilled "
          f"only its suffix in {cb.eng.prefill_steps - steps_cold} chunk "
          f"step(s); {cb.prefix.hits} cache hit(s), {cb.cow_copies} "
          f"copy-on-write page cop(ies)")


if __name__ == "__main__":
    main()
