"""Batched serving with the scan-fused decode engine — train briefly, then
serve a static batch (one compiled scan for the whole generation) and a
continuously-batched queue of ragged requests over a shared page pool.

    PYTHONPATH=src python examples/serve_generate.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db
from repro.data import MarkovLM
from repro.launch.serve import ContinuousBatcher, get_engine


def main():
    cfg = ModelConfig(name="serve-ex", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)
    db = DBConfig(num_blocks=3, overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def data():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=150, lr=2e-3, warmup_steps=10, log_every=50)
    params, _ = train_db(dbm, tcfg, data(), jax.random.PRNGKey(0))

    # ---- static batch: prefill scan + ONE decode scan (2 dispatches) -----
    batch, prompt_len, max_new = 8, 8, 32
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), batch,
                                    prompt_len))
    eng = get_engine(dbm, steps_per_block=1, temperature=0.0, top_k=0,
                     precision="bf16", impl="auto")
    t0 = time.time()
    out = eng.generate(params, prompts, max_new, jax.random.PRNGKey(1))
    dt = time.time() - t0
    print(f"[static] {batch}x{max_new} tokens in {dt:.1f}s "
          f"({batch*max_new/dt:.1f} tok/s incl. compile, "
          f"{eng.dispatches} dispatches — the seed paid {1 + max_new} "
          f"plus a host sync per token)")
    print("legal-transition rate:", lm.transition_accuracy(np.array(out)))
    # each denoising step touched only n_layers/B layers (paper App. H)
    print(f"layers per denoise step: {cfg.n_layers // db.num_blocks} "
          f"of {cfg.n_layers}")

    # ---- continuous batching: ragged queue on fewer slots ----------------
    cb = ContinuousBatcher(dbm, params, num_slots=4, page_size=8,
                           max_prompt=prompt_len,
                           max_len=prompt_len + max_new, seg_len=8,
                           precision="bf16")
    rs = np.random.RandomState(3)
    for _ in range(10):
        plen = rs.randint(4, prompt_len + 1)
        cb.submit(lm.sample(rs, 1, plen)[0], max_new=max_new)
    t0 = time.time()
    done = cb.run(jax.random.PRNGKey(2))
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    # score per sequence: padding to a rectangle would fabricate transitions
    accs = [lm.transition_accuracy(
        np.concatenate([r.prompt, np.asarray(r.out, np.int64)])[None])
        for r in done]
    print(f"[continuous] {len(done)} ragged requests / {n_tok} tokens on "
          f"4 slots in {dt:.1f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    print("legal-transition rate:", float(np.mean(accs)))


if __name__ == "__main__":
    main()
