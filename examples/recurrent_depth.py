"""Recurrent-depth (Huginn-style) training, baseline vs DiffusionBlocks
(paper §5.5): K-iteration truncated BPTT vs single-pass denoiser training.

    PYTHONPATH=src python examples/recurrent_depth.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.recurrent import RecurrentDepthModel
from repro.data import MarkovLM
from repro.optim import adamw, apply_updates


def train(model, loss_name, steps, lm, lr=2e-3):
    params = model.init(jax.random.PRNGKey(0))
    init, update = adamw(lr)
    st = init(params)
    loss_fn = getattr(model, loss_name)
    grad = jax.jit(jax.value_and_grad(lambda p, t, r: loss_fn(p, t, r)[0]))
    rng = jax.random.PRNGKey(1)
    it = np.random.RandomState(1)
    t0, losses = time.time(), []
    for i in range(steps):
        toks = jnp.asarray(lm.sample(it, 8, 32))
        rng, r = jax.random.split(rng)
        loss, g = grad(params, toks, r)
        upd, st, _ = update(g, st, params)
        params = apply_updates(params, upd)
        losses.append(float(loss))
    return params, losses, time.time() - t0


def main():
    cfg = ModelConfig(name="huginn-ex", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)
    K = 8
    lm = MarkovLM(vocab_size=32, branching=2, seed=6)
    steps = 100

    base = RecurrentDepthModel(cfg, DBConfig(num_blocks=1), prelude=1,
                               coda=1, recurrence=K, bptt_k=4)
    _, lb, tb = train(base, "baseline_loss", steps, lm)
    print(f"Huginn baseline (K={K}, tbptt): first={np.mean(lb[:5]):.3f} "
          f"last={np.mean(lb[-5:]):.3f}  time={tb:.1f}s "
          f"({K} core passes/step)")

    dbm = RecurrentDepthModel(cfg, DBConfig(num_blocks=1), prelude=1,
                              coda=1, recurrence=K, bptt_k=4)
    _, ld, td = train(dbm, "db_loss", steps, lm)
    print(f"Huginn+DiffusionBlocks:      first={np.mean(ld[:5]):.3f} "
          f"last={np.mean(ld[-5:]):.3f}  time={td:.1f}s (1 core pass/step)")
    print(f"training speedup: {tb/td:.2f}x (paper: up to K-fold)")


if __name__ == "__main__":
    main()
