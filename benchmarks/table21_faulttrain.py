"""Table 21 (beyond-paper): fault-tolerant elastic block-parallel training —
resume parity, per-block anomaly isolation, and a chaos training run
(ROADMAP robustness item: crash-consistent checkpoints + supervised loop).

Two acceptance gates, both ASSERTED (not just reported):

  resume-parity   a training run KILLED at a seeded step (``halt_after`` —
                  no shutdown checkpoint; work since the last cadence
                  generation is lost) and resumed from the atomic manifest
                  checkpoint produces BIT-IDENTICAL final params AND
                  optimizer state to an uninterrupted run. Checked for
                  ``--mode db`` and ``--block-parallel`` on both engine
                  paths (shard_map when the host has a pod per block,
                  round-robin always).
  chaos           with seeded pod kills (degrade to round-robin + re-adopt),
                  NaN gradient injections (per-block guard skips), and a
                  checkpoint generation corrupted mid-write (checksum
                  fallback) all firing in ONE run, training completes with
                  finite per-block losses within tolerance of a clean run's
                  — and the injected faults demonstrably fired.

CPU caveat: tiny model, synthetic Markov data; the measurements are the
parity bits and the chaos-survival invariants, not wall-clock. Writes
``BENCH_faulttrain.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import numpy as np

from repro.checkpoint import tree_digest
from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel
from repro.data import MarkovLM, MarkovStream
from repro.launch.faults import FaultInjector
from repro.launch.trainrunner import TrainRunner

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(name="bench-faulttrain", family="dense", n_layers=8,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=64)
B = 4
BATCH, SEQ = 4, 16


def _build(steps):
    dbm = DiffusionBlocksModel(CFG, DBConfig(num_blocks=B,
                                             overlap_gamma=0.05))
    tcfg = TrainConfig(steps=steps, batch_size=BATCH, seq_len=SEQ, lr=2e-3,
                       warmup_steps=2, log_every=0)
    return dbm, tcfg


def _make_data_factory():
    lm = MarkovLM(vocab_size=CFG.vocab_size, seed=7)

    def make_data(cur):
        return (lm.stream(BATCH, SEQ) if cur is None
                else MarkovStream.from_cursor(cur))
    return make_data


def _opt_digests(runner):
    if runner.mode == "block-parallel":
        return (tree_digest(jax.device_get(runner.state.stack_opt)),
                tree_digest(jax.device_get(runner.state.periph_opt)))
    return tuple(tree_digest(o) for o in runner.opt_states)


def _parity_case(mode, steps, ckpt_every, halt_after, devices=None):
    """clean vs (killed at ``halt_after`` → resumed) — assert bit parity."""
    dbm, tcfg = _build(steps)
    make_data = _make_data_factory()
    rng = jax.random.PRNGKey(0)
    quiet = lambda *a: None  # noqa: E731

    def runner(ckpt_dir):
        return TrainRunner(dbm, tcfg, mode=mode, ckpt_dir=ckpt_dir,
                           ckpt_every=ckpt_every, devices=devices, log=quiet)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        r_clean = runner(d1)
        p_clean, _ = r_clean.train(make_data, rng)
        r_kill = runner(d2)
        r_kill.train(make_data, rng, halt_after=halt_after)
        r_res = runner(d2)
        p_res, _ = r_res.train(make_data, rng, resume=True)
        engine = (r_clean.trainer.mode if mode == "block-parallel" else "n/a")
        params_ok = tree_digest(p_clean) == tree_digest(p_res)
        opt_ok = _opt_digests(r_clean) == _opt_digests(r_res)
    assert params_ok, f"resume params diverged ({mode}/{engine})"
    assert opt_ok, f"resume optimizer state diverged ({mode}/{engine})"
    return {"mode": mode, "engine": engine, "steps": steps,
            "killed_at": halt_after, "ckpt_every": ckpt_every,
            "params_bit_identical": True, "opt_bit_identical": True}


def _final_block_losses(history, n_blocks):
    out = {}
    for it, b, loss in history:
        if b >= 0:
            out[b] = loss
    return [out.get(b, float("nan")) for b in range(n_blocks)]


def _chaos_parallel(steps, tol_abs=0.75, tol_rel=0.4):
    """Seeded pod kill + NaN injections + a corrupted generation, one run."""
    dbm, tcfg = _build(steps)
    make_data = _make_data_factory()
    rng = jax.random.PRNGKey(0)
    quiet = lambda *a: None  # noqa: E731

    with tempfile.TemporaryDirectory() as d:
        r_clean = TrainRunner(dbm, tcfg, mode="block-parallel", ckpt_dir=d,
                              ckpt_every=2, log=quiet)
        _, h_clean = r_clean.train(make_data, rng)
    faults = FaultInjector({"pod_die": {"at": [3]},
                            "grad_nan": {"at": [2, 5]},
                            "ckpt_corrupt": {"at": [2]},
                            "data_stall": {"at": [4], "sleep": 0.01}}, seed=0)
    with tempfile.TemporaryDirectory() as d:
        r = TrainRunner(dbm, tcfg, mode="block-parallel", ckpt_dir=d,
                        ckpt_every=2, faults=faults, pod_restart_after=2,
                        log=quiet)
        _, h = r.train(make_data, rng)
        # the corrupted generation must be detected, not loaded: a resume
        # from the chaos run's directory still works (falls back)
        r2 = TrainRunner(dbm, tcfg, mode="block-parallel", ckpt_dir=d,
                         ckpt_every=2, log=quiet)
        p2, _ = r2.train(make_data, jax.random.PRNGKey(0), resume=True)
        assert np.all(np.isfinite(
            np.concatenate([np.ravel(x) for x in
                            jax.tree_util.tree_leaves(p2)])))
    clean = np.asarray(_final_block_losses(h_clean, B))
    chaos = np.asarray(_final_block_losses(h, B))
    inj = faults.stats()
    stats = r.stats()["counters"]
    assert np.isfinite(chaos).all(), chaos
    tol = tol_abs + tol_rel * np.abs(clean)
    assert (np.abs(chaos - clean) <= tol).all(), (clean, chaos, tol)
    assert inj["pod_die"]["fired"] >= 1, inj
    assert inj["grad_nan"]["fired"] >= 2, inj
    assert inj["ckpt_corrupt"]["fired"] >= 1, inj
    assert stats["pod_deaths"] >= 1 and stats["readoptions"] >= 1, stats
    assert stats["nan_injected"] >= 2, stats
    assert stats["degraded_batches"] >= 1, stats
    return {"mode": "block-parallel", "engine": r.trainer.mode,
            "steps": steps,
            "final_loss_clean": [float(x) for x in clean],
            "final_loss_chaos": [float(x) for x in chaos],
            "max_abs_gap": float(np.abs(chaos - clean).max()),
            "within_tolerance": True,
            "pod_deaths": stats["pod_deaths"],
            "readoptions": stats["readoptions"],
            "degraded_batches": stats["degraded_batches"],
            "nan_injected": stats["nan_injected"],
            "data_stalls": stats["data_stalls"],
            "ckpt_corrupt_fired": inj["ckpt_corrupt"]["fired"],
            "resume_after_chaos_ok": True}


def _chaos_db(steps, tol_abs=0.75, tol_rel=0.4):
    """db mode: pod_die = simulated process death → bounded restart from the
    latest generation; NaNs guarded per block."""
    dbm, tcfg = _build(steps)
    make_data = _make_data_factory()
    rng = jax.random.PRNGKey(0)
    quiet = lambda *a: None  # noqa: E731

    with tempfile.TemporaryDirectory() as d:
        r_clean = TrainRunner(dbm, tcfg, mode="db", ckpt_dir=d,
                              ckpt_every=4, log=quiet)
        _, h_clean = r_clean.train(make_data, rng)
    faults = FaultInjector({"pod_die": {"at": [9]},
                            "grad_nan": {"at": [5]},
                            "ckpt_corrupt": {"at": [3]}}, seed=0)
    with tempfile.TemporaryDirectory() as d:
        r = TrainRunner(dbm, tcfg, mode="db", ckpt_dir=d, ckpt_every=4,
                        faults=faults, max_restarts=3, log=quiet)
        _, h = r.train(make_data, rng)
    # per-iteration mean over the tail (block sampling is random, so compare
    # the mean of the final quarter rather than per-block last losses)
    tail = max(1, len(h_clean) // 4)
    clean = float(np.mean([l for _, _, l in h_clean[-tail:]]))
    chaos = float(np.mean([l for _, _, l in h[-tail:]]))
    inj = faults.stats()
    stats = r.stats()["counters"]
    assert np.isfinite(chaos), chaos
    assert abs(chaos - clean) <= tol_abs + tol_rel * abs(clean), (clean,
                                                                  chaos)
    assert stats["restarts"] >= 1, stats
    assert inj["grad_nan"]["fired"] >= 1, inj
    return {"mode": "db", "steps": steps, "final_loss_clean": clean,
            "final_loss_chaos": chaos, "gap": abs(chaos - clean),
            "within_tolerance": True, "restarts": stats["restarts"],
            "nan_injected": stats["nan_injected"],
            "ckpt_corrupt_fired": inj["ckpt_corrupt"]["fired"]}


def run(quick: bool = True, out: str = None):
    db_steps = 10 if quick else 40
    par_steps = 12 if quick else 48

    parity = []
    parity.append(_parity_case("db", db_steps, ckpt_every=4, halt_after=7))
    print(f"[parity db] bit-identical after kill@7/resume "
          f"({db_steps} steps)")
    # round-robin engine path: pin the mesh to one device
    parity.append(_parity_case("block-parallel", par_steps, ckpt_every=1,
                               halt_after=2, devices=[jax.devices()[0]]))
    print(f"[parity block-parallel/{parity[-1]['engine']}] bit-identical "
          f"after kill@2/resume ({par_steps} steps)")
    if jax.device_count() >= B:
        parity.append(_parity_case("block-parallel", par_steps,
                                   ckpt_every=1, halt_after=2))
        print(f"[parity block-parallel/{parity[-1]['engine']}] "
              f"bit-identical after kill@2/resume ({par_steps} steps)")
    else:
        print(f"[parity] shard_map path skipped: {jax.device_count()} "
              f"devices < {B} blocks")

    chaos_par = _chaos_parallel(32 if quick else 96)
    print(f"[chaos block-parallel/{chaos_par['engine']}] "
          f"{chaos_par['pod_deaths']} pod deaths, "
          f"{chaos_par['nan_injected']} NaNs, "
          f"{chaos_par['ckpt_corrupt_fired']} corrupted generations | "
          f"max |loss gap| {chaos_par['max_abs_gap']:.3f} (within tol)")
    chaos_db = _chaos_db(24 if quick else 80)
    print(f"[chaos db] {chaos_db['restarts']} restarts, "
          f"{chaos_db['nan_injected']} NaNs | loss gap "
          f"{chaos_db['gap']:.3f} (within tol)")

    report = {
        "meta": {"model": CFG.name, "blocks": B,
                 "backend": jax.default_backend(),
                 "devices": jax.device_count(), "quick": bool(quick)},
        "resume_parity": parity,
        "chaos": {"block_parallel": chaos_par, "db": chaos_db},
        "note": ("CPU figures for a tiny model; the measurements are the "
                 "bit-parity gates and chaos-survival invariants, not "
                 "wall-clock."),
    }
    out = out or os.path.join(ROOT, "BENCH_faulttrain.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = []
    for p in r["resume_parity"]:
        rows.append({"name": f"parity_{p['mode']}_{p['engine']}",
                     "steps": p["steps"], "killed_at": p["killed_at"],
                     "params_bit_identical": int(p["params_bit_identical"]),
                     "opt_bit_identical": int(p["opt_bit_identical"])})
    c = r["chaos"]["block_parallel"]
    rows.append({"name": "chaos_block_parallel", "steps": c["steps"],
                 "pod_deaths": c["pod_deaths"],
                 "readoptions": c["readoptions"],
                 "degraded_batches": c["degraded_batches"],
                 "nan_injected": c["nan_injected"],
                 "ckpt_corrupt_fired": c["ckpt_corrupt_fired"],
                 "max_abs_loss_gap": c["max_abs_gap"],
                 "within_tolerance": int(c["within_tolerance"])})
    c = r["chaos"]["db"]
    rows.append({"name": "chaos_db", "steps": c["steps"],
                 "restarts": c["restarts"],
                 "nan_injected": c["nan_injected"],
                 "ckpt_corrupt_fired": c["ckpt_corrupt_fired"],
                 "loss_gap": c["gap"],
                 "within_tolerance": int(c["within_tolerance"])})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    run(quick=a.quick, out=a.out)
