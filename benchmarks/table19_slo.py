"""Table 19 (beyond-paper): SLO-aware scheduling under overload — priority
classes, deadline attainment, preemption, admission control, and engine
fault tolerance (ROADMAP open item 2, scheduling half).

The load harness replays a 2x-over-capacity BURSTY trace with a mixed
priority population (interactive / standard / batch) against a batcher
running admission control (``max_queue``, ``shed_below_pages``) on a
deliberately undersized page pool, so every robustness mechanism fires
under the same load:

  slo point       interactive requests carry a TTFT SLO; the scheduler
                  admits by (priority, deadline) and spills lower-priority
                  slots for pages. ASSERTED: interactive p99 TTFT meets its
                  SLO while excess batch load sheds with 429 + Retry-After
                  — the overload lands on the class that can absorb it.
  preempt parity  gate: a request force-preempted mid-decode (KV pages +
                  cross-attention state spilled to host, restored into
                  different physical pages) finishes bit-identical to an
                  uninterrupted run — conditioned AND unconditioned.
  fault point     the same traffic through the HTTP/SSE frontend while a
                  seeded ``FaultInjector`` crashes the engine thread twice
                  and starves the page allocator: the supervisor restarts,
                  spilled slots resume, and every request completes with no
                  hung stream. ASSERTED: zero errors, crash/restart
                  counters match the injection schedule, pool whole.

CPU caveat: absolute latencies are CPU-of-the-day figures for a tiny
model; the measurement is the CONTRAST (interactive vs batch TTFT under
identical overload) and the invariants. Writes ``BENCH_slo.json``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os

import jax
import numpy as np

try:
    from benchmarks.loadgen import (at_time_zero, offered_rate, replay_http,
                                    replay_inproc, slo_summary, summarize,
                                    synth_workload)
except ImportError:                      # run as a script: benchmarks/ on path
    from loadgen import (at_time_zero, offered_rate, replay_http,
                         replay_inproc, slo_summary, summarize,
                         synth_workload)

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector
from repro.launch.serve import ContinuousBatcher
from repro.launch.server import InferenceServer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(name="bench-slo-vlm", family="vlm", n_layers=4,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=32, cross_attn_every=2, n_image_tokens=4)
MAX_PROMPT, MAX_NEW_CAP = 24, 12
CB_KW = dict(num_slots=4, page_size=4, max_prompt=MAX_PROMPT,
             max_len=MAX_PROMPT + MAX_NEW_CAP, seg_len=4, chunk_size=8,
             precision="fp32", prefix_cache=True)
WL_KW = dict(vocab=CFG.vocab_size, max_prompt=MAX_PROMPT,
             max_new_cap=MAX_NEW_CAP, sys_len=8, sys_frac=0.5,
             cond_frac=0.3)
# page pool for the overload point: too small for four max-size requests
# (4 * pages_for(36) = 36 mapped pages + trash), so admission must spill
# lower-priority slots for pages instead of waiting out the burst
PRESSURE_PAGES = 30


def _classes(interactive_slo_ms):
    return [
        {"name": "interactive", "frac": 0.25, "priority": "interactive",
         "ttft_slo_ms": interactive_slo_ms},
        {"name": "standard", "frac": 0.35, "priority": "standard"},
        {"name": "batch", "frac": 0.40, "priority": "batch"},
    ]


def _build():
    dbm = DiffusionBlocksModel(CFG, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(99)
    registry = {f"cond{i}": {"image_embs":
                             rs.randn(CFG.n_image_tokens, CFG.d_model)
                             .astype(np.float32)}
                for i in range(3)}
    return dbm, params, registry


def _assert_pool_whole(cb):
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1, (
        len(cb.free_pages), len(cb.page_refs), cb.total_pages)


def _preempt_parity(dbm, params, registry):
    """Acceptance gate: force-preempting a request mid-decode (spill KV
    pages + per-slot cross state to host, restore into fresh physical
    pages) must not change a single output token vs the uninterrupted run
    — for an unconditioned AND a conditioned (cross-attending) request."""
    one = dict(CB_KW, num_slots=1, prefix_cache=False)
    prompt = (np.arange(1, 10, dtype=np.int32) * 3) % CFG.vocab_size
    checked = []
    for aux_name in (None, "cond0"):
        aux = registry[aux_name] if aux_name else None

        def run_once(preempt_at):
            cb = ContinuousBatcher(dbm, params, **one)
            rid = cb.submit(prompt, 8, aux_inputs=aux)
            rng, fin, step = jax.random.PRNGKey(11), [], 0
            while cb.has_work():
                if step == preempt_at:
                    cb.preempt(rid)
                rng, f = cb.step(rng, strict=False)
                fin.extend(f)
                step += 1
            assert len(cb.free_pages) == cb.total_pages - 1
            return fin[0].out, cb

        base, _ = run_once(None)
        for at in (1, 2):
            got, cb = run_once(at)
            assert cb.preemptions >= 1 and cb.restores >= 1, cb.preemptions
            assert got == base, (aux_name, at, got, base)
        checked.append(aux_name or "unconditioned")
    return {"bit_identical": True, "preempt_steps": [1, 2],
            "populations": checked}


def _inproc_point(dbm, params, registry, items, seed, **cb_extra):
    # every in-proc point runs on the PRESSURE_PAGES pool: the pool size is
    # part of the compiled cache shape, so one warmup compile covers the
    # whole benchmark (a mid-trace recompile would masquerade as queueing)
    kw = dict(CB_KW, total_pages=PRESSURE_PAGES, **cb_extra)
    cb = ContinuousBatcher(dbm, params, **kw)
    recs = replay_inproc(cb, items, aux_registry=dict(registry),
                        rng=jax.random.PRNGKey(seed))
    _assert_pool_whole(cb)
    return recs, cb


def _fault_point(dbm, params, registry, items, seed):
    """The trace through the asyncio SSE frontend while the engine thread
    is crashed twice and the page allocator intermittently starved — the
    supervisor must restart, restore spilled slots, and finish every
    stream."""
    faults = FaultInjector({"engine_crash": {"at": [5, 12]},
                            "alloc_exhaust": {"p": 0.03}}, seed=3)

    async def main():
        cb = ContinuousBatcher(dbm, params, faults=faults,
                               **dict(CB_KW, total_pages=PRESSURE_PAGES))
        server = InferenceServer(cb, aux_registry=registry,
                                 rng=jax.random.PRNGKey(seed),
                                 max_restarts=3)
        await server.start()
        try:
            recs = await replay_http("127.0.0.1", server.port, items)
            runner = server.runner
            stats = {"crashes": runner.crashes, "restarts": runner.restarts,
                     "gave_up": runner.gave_up,
                     "preemptions": cb.preemptions, "restores": cb.restores,
                     "injector": faults.stats()}
        finally:
            await server.aclose()
        _assert_pool_whole(cb)
        return recs, stats

    return asyncio.run(main())


def run(quick: bool = True, out: str = None):
    dbm, params, registry = _build()
    cond_names = tuple(sorted(registry))
    rs = np.random.RandomState(0)

    parity = _preempt_parity(dbm, params, registry)

    # warm up the num_slots=4 engine (compiles the batched programs)
    warm = at_time_zero(synth_workload(rs, 6, arrival="poisson", rate=1000.0,
                                       cond_names=cond_names, **WL_KW))
    _inproc_point(dbm, params, registry, warm, seed=0)

    # calibrate capacity: whole trace at t=0 -> zero-queueing-slack ceiling
    n_cal = 16 if quick else 32
    calib = at_time_zero(synth_workload(rs, n_cal, arrival="poisson",
                                        rate=1000.0, cond_names=cond_names,
                                        **WL_KW))
    cal = summarize(_inproc_point(dbm, params, registry, calib, seed=1)[0])
    assert cal["errors"] == 0 and cal["shed"] == 0, cal
    capacity_rps = cal["completed"] / cal["makespan_s"]

    # light-load baseline: per-request latency with queueing slack — the
    # reference the interactive SLO is set against (calibration TTFTs are
    # dominated by the everything-at-t=0 queue wait, so they can't be)
    light = synth_workload(rs, 12 if quick else 24, arrival="poisson",
                           rate=0.5 * capacity_rps,
                           cond_names=cond_names, **WL_KW)
    base = summarize(_inproc_point(dbm, params, registry, light, seed=2)[0],
                     offered_rps=offered_rate(light))
    assert base["errors"] == 0 and base["shed"] == 0, base
    slo_ms = round(max(6 * base["p99_ttft_ms"], 2500.0))

    # THE MEASUREMENT: 2x-over-capacity bursty mixed-priority overload with
    # admission control and an undersized page pool. Interactive requests
    # must ride out the burst inside their SLO; the excess must land on the
    # batch class as 429s carrying a Retry-After hint.
    classes = _classes(slo_ms)
    n_pt = 32 if quick else 64
    items = synth_workload(rs, n_pt, arrival="bursty",
                           rate=2.0 * capacity_rps, cond_names=cond_names,
                           classes=classes, **WL_KW)
    recs, cb = _inproc_point(dbm, params, registry, items, seed=3,
                             max_queue=6, shed_below_pages=2)
    overall = summarize(recs, offered_rps=offered_rate(items))
    per_cls = slo_summary(recs, classes)
    sheds = [r for r in recs if r.get("shed")]

    assert overall["errors"] == 0, overall
    assert per_cls["interactive"]["served"] > 0, per_cls
    assert per_cls["interactive"]["slo_attainment"] == 1.0, per_cls
    assert per_cls["batch"]["shed"] > 0, per_cls
    assert all(r["retry_after"] is not None for r in sheds), sheds
    if per_cls["batch"]["p99_ttft_ms"] is not None:
        assert (per_cls["interactive"]["p99_ttft_ms"]
                <= per_cls["batch"]["p99_ttft_ms"]), per_cls
    engine = {"preemptions": cb.preemptions, "restores": cb.restores,
              "deadline_cancels": cb.deadline_cancels,
              "shed_count": cb.shed_count}
    for name, c in per_cls.items():
        print(f"[overload 2.0x {name:>11}] n={c['n']:3d} "
              f"shed={c['shed']:2d} served={c['served']:3d} "
              f"p99 TTFT {c['p99_ttft_ms'] or float('nan'):8.0f} ms "
              f"(slo {c['ttft_slo_ms']}) attain={c['slo_attainment']}")

    # fault injection under load through the HTTP frontend
    fitems = synth_workload(rs, 12 if quick else 20, arrival="poisson",
                            rate=0.8 * capacity_rps,
                            cond_names=cond_names,
                            classes=_classes(None), **WL_KW)
    frecs, fstats = _fault_point(dbm, params, registry, fitems, seed=4)
    fsum = summarize(frecs, offered_rps=offered_rate(fitems))
    assert fsum["errors"] == 0 and fsum["shed"] == 0, fsum
    assert fsum["completed"] == len(fitems), fsum
    assert fstats["crashes"] == 2 and fstats["restarts"] == 2, fstats
    assert not fstats["gave_up"], fstats
    print(f"[faults] {fstats['crashes']} crashes supervised, "
          f"{fstats['preemptions']} spills, all "
          f"{fsum['completed']} requests completed")

    report = {
        "meta": {
            "model": CFG.name, "family": CFG.family,
            "backend": jax.default_backend(), "quick": bool(quick),
            "num_slots": CB_KW["num_slots"], "page_size": CB_KW["page_size"],
            "pressure_pages": PRESSURE_PAGES,
            "max_queue": 6, "shed_below_pages": 2,
            "classes": classes,
            "workload": {**WL_KW, "cond_names": list(cond_names)},
        },
        "preempt_parity": parity,
        "calibration": {**cal, "capacity_rps": round(capacity_rps, 3)},
        "light_baseline": base,
        "interactive_slo_ms": slo_ms,
        "overload": {"overall": overall, "per_class": per_cls,
                     "engine": engine},
        "faults": {"summary": fsum, "engine": fstats},
        "note": ("CPU figures for a tiny model; the measurement is the "
                 "interactive-vs-batch contrast under identical 2x "
                 "overload and the zero-error fault-recovery invariants, "
                 "not absolute latency."),
    }
    out = out or os.path.join(ROOT, "BENCH_slo.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"capacity {capacity_rps:.2f} rps | interactive SLO {slo_ms} ms "
          f"attained | batch shed {per_cls['batch']['shed']}/"
          f"{per_cls['batch']['n']}")
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = []
    for name, c in r["overload"]["per_class"].items():
        rows.append({
            "name": f"overload_{name}", "n": c["n"], "shed": c["shed"],
            "served": c["served"], "p50_ttft_ms": c["p50_ttft_ms"],
            "p99_ttft_ms": c["p99_ttft_ms"],
            "slo_attainment": c["slo_attainment"],
            "goodput_rps": c["goodput_rps"],
        })
    eng = r["overload"]["engine"]
    rows.append({"name": "summary",
                 "capacity_rps": r["calibration"]["capacity_rps"],
                 "interactive_slo_ms": r["interactive_slo_ms"],
                 "preemptions": eng["preemptions"],
                 "restores": eng["restores"],
                 "fault_crashes": r["faults"]["engine"]["crashes"],
                 "fault_completed": r["faults"]["summary"]["completed"],
                 "preempt_parity":
                     int(r["preempt_parity"]["bit_identical"])})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace (CI smoke)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_slo.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
