"""Table 15 (beyond-paper): decode-engine benchmark — scan-fused paged
serving vs the seed per-token path.

Measured on the current backend, batch >= 8 with RAGGED prompt lengths:

  dispatches   host-side count of jitted calls per generated token. The seed
               path paid one dispatch PLUS a host sync per token; the fused
               engine pays one prefill scan + one decode scan for the whole
               batch. Both run the SAME step function, so greedy outputs are
               bit-identical (asserted and recorded).
  tok/s        end-to-end walltime after warmup. On CPU the win is the
               removed per-token dispatch/sync overhead; on TPU the same
               fusion also keeps the device busy between tokens.
  cache bytes  seed worst-case dense fp32 slab vs the paged bf16 pool
               (page-granular), plus the bytes actually backed by allocated
               pages for the ragged request set (what the continuous
               scheduler holds).

A continuous-batching row serves a queue of ragged requests through
``launch.serve.ContinuousBatcher`` (admission + retirement between scan
segments) and reports its throughput and dispatch rate.

CPU caveat (as for table14): ``--impl kernels`` runs the Pallas flash-decode
kernel in INTERPRET mode on CPU — per-page emulation dominates walltime
there, so the default is the jnp attend path; the compiled-kernel walltime
comparison is TPU-only. Dispatch counts and cache bytes are
backend-independent measurements.

Writes ``BENCH_decode.json`` at the repo root. ``--quick`` shrinks shapes
for the CI smoke lane (and fails loudly on parity regressions).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, get_engine
from repro.nn import cache as KVC

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def run(quick: bool = True, out: str = None, impl: str = "auto"):
    if quick:
        layers, d_model, B, s0, max_new, blocks, reps = 6, 64, 8, 12, 12, 3, 1
    else:
        layers, d_model, B, s0, max_new, blocks, reps = 8, 96, 8, 16, 48, 4, 3
    page_size = 8
    cfg = ModelConfig(name="bench-decode", family="dense", n_layers=layers,
                      d_model=d_model, n_heads=4, n_kv_heads=2,
                      d_ff=2 * d_model, vocab_size=256)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=blocks,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(1)
    prompts = jnp.asarray(rs.randint(0, cfg.vocab_size, size=(B, s0)))
    plens = rs.randint(max(2, s0 // 2), s0 + 1, size=B)   # ragged
    eng = get_engine(dbm, steps_per_block=1, temperature=0.0, top_k=0,
                     precision="bf16", impl=impl)
    n_tok = B * max_new
    kw = dict(prompt_lengths=plens, page_size=page_size)
    print(f"backend={jax.default_backend()} impl={impl} "
          f"B={B} prompts={[int(p) for p in plens]} max_new={max_new}")

    def gen(reference: bool):
        return eng.generate(params, prompts, max_new, jax.random.PRNGKey(7),
                            reference=reference, **kw)

    # warm both programs, then time INTERLEAVED pairs (CPU frequency drift
    # between two back-to-back blocks otherwise swamps the ~ms/token
    # dispatch overhead this benchmark measures) and take the median.
    jax.block_until_ready(gen(True))
    jax.block_until_ready(gen(False))
    times = {True: [], False: []}
    disp = {}
    outs = {}
    for _ in range(reps):
        for reference in (True, False):
            d0 = eng.dispatches
            t0 = time.time()
            outs[reference] = gen(reference)
            jax.block_until_ready(outs[reference])
            times[reference].append(time.time() - t0)
            disp[reference] = eng.dispatches - d0

    def row_for(reference: bool):
        dt = float(np.median(times[reference]))
        d = disp[reference]
        row = {"walltime_s": dt, "tok_s": n_tok / dt, "dispatches": d,
               "dispatches_per_token": d / n_tok}
        name = "per-token loop" if reference else "scan-fused"
        print(f"  {name:16s} {row['tok_s']:8.1f} tok/s  "
              f"{d:4d} dispatches ({row['dispatches_per_token']:.3f}/token)")
        return row

    ref_row, ref_out = row_for(True), np.asarray(outs[True])
    fused_row, fused_out = row_for(False), np.asarray(outs[False])
    parity = bool(np.array_equal(ref_out, fused_out))
    print(f"  greedy scan-fused == per-token loop (bit-identical): {parity}")
    assert parity, "scan-fused greedy diverged from the reference loop"

    # ---- cache memory: seed dense fp32 worst-case vs paged bf16 ----------
    seed_cache = dbm.model.init_cache(B, s0 + max_new, jnp.float32)
    seed_bytes = KVC.cache_bytes(seed_cache)
    pps = KVC.pages_for(s0 + max_new, page_size)
    pool = dbm.model.init_paged_cache(B, 1 + B * pps, page_size, "bf16")
    pool_bytes = KVC.cache_bytes(pool)
    # bytes actually backed by allocated pages for the ragged request set
    n_units = dbm.model.n_units
    page_bytes = pool.k[0, 0].nbytes * 2 * n_units      # k+v, one page, all units
    used_pages = sum(KVC.pages_for(int(p) + max_new, page_size)
                     for p in plens)
    used_bytes = (1 + used_pages) * page_bytes
    cache = {
        "seed_dense_fp32_bytes": int(seed_bytes),
        "paged_bf16_pool_bytes": int(pool_bytes),
        "paged_bf16_used_bytes": int(used_bytes),
        "bytes_ratio_pool": seed_bytes / pool_bytes,
        "bytes_ratio_used": seed_bytes / used_bytes,
        "page_size": page_size,
    }
    print(f"  cache bytes: seed fp32 {seed_bytes/1e6:.2f}MB vs paged bf16 "
          f"pool {pool_bytes/1e6:.2f}MB ({cache['bytes_ratio_pool']:.2f}x) "
          f"/ used {used_bytes/1e6:.2f}MB ({cache['bytes_ratio_used']:.2f}x)")

    # ---- continuous batching over a shared pool --------------------------
    n_req, slots, seg = (3 * B // 2, max(2, B // 2), max_new // 2)
    mk_cb = lambda: ContinuousBatcher(
        dbm, params, num_slots=slots, page_size=page_size, max_prompt=s0,
        max_len=s0 + max_new, seg_len=seg, precision="bf16", impl=impl)
    warm = mk_cb()                       # compile the segment program once
    warm.submit(rs.randint(0, cfg.vocab_size, size=s0 // 2), max_new)
    warm.run(jax.random.PRNGKey(10))
    cb = mk_cb()
    for i in range(n_req):
        pl = int(rs.randint(max(2, s0 // 2), s0 + 1))
        cb.submit(rs.randint(0, cfg.vocab_size, size=pl), max_new)
    d0 = cb.eng.dispatches
    t0 = time.time()
    done = cb.run(jax.random.PRNGKey(11))
    dt = time.time() - t0
    c_tok = sum(len(r.out) for r in done)
    cont = {"requests": n_req, "slots": slots, "seg_len": seg,
            "walltime_s": dt, "tok_s": c_tok / dt,
            "dispatches": cb.eng.dispatches - d0,
            "dispatches_per_token": (cb.eng.dispatches - d0) / c_tok,
            "pool_pages": cb.total_pages,
            "pool_bytes": int(KVC.cache_bytes(cb.kv))}
    print(f"  continuous       {cont['tok_s']:8.1f} tok/s  "
          f"{cont['dispatches']:4d} dispatches "
          f"({cont['dispatches_per_token']:.3f}/token) "
          f"[{n_req} reqs on {slots} slots]")

    report = {
        "table": "table15_decode",
        "backend": jax.default_backend(),
        "pallas_mode": ("interpret" if _interpret() else "mosaic")
        if impl in ("kernels", "pallas") else "jnp (impl=auto)",
        "quick": bool(quick),
        "config": {"layers": layers, "d_model": d_model, "batch": B,
                   "prompt_max": s0, "prompt_lengths": [int(p) for p in plens],
                   "max_new": max_new, "blocks": blocks, "impl": impl},
        "per_token_loop": ref_row,
        "scan_fused": fused_row,
        "dispatch_speedup": ref_row["dispatches"] / fused_row["dispatches"],
        "walltime_speedup": ref_row["walltime_s"] / fused_row["walltime_s"],
        "greedy_bit_identical": parity,
        "cache": cache,
        "continuous": cont,
        "walltime_note": (
            "CPU walltime: impl=auto runs the jnp paged attend (the Pallas "
            "flash-decode kernel in interpret mode is per-page emulation — "
            "compiled-kernel walltime comparison is TPU-only, as for "
            "table14); the scan-fusion win measured here is the removed "
            "per-token dispatch + host sync."),
    }
    out = out or os.path.join(ROOT, "BENCH_decode.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"dispatch speedup (per-token loop / scan-fused): "
          f"{report['dispatch_speedup']:.1f}x | walltime "
          f"{report['walltime_speedup']:.2f}x | cache "
          f"{cache['bytes_ratio_used']:.2f}x smaller (used pages)")
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    return [
        {"name": "per_token_loop", **r["per_token_loop"]},
        {"name": "scan_fused", **r["scan_fused"]},
        {"name": "continuous", **r["continuous"]},
        {"name": "summary", "dispatch_speedup": r["dispatch_speedup"],
         "walltime_speedup": r["walltime_speedup"],
         "greedy_bit_identical": int(r["greedy_bit_identical"]),
         "cache_bytes_ratio_used": r["cache"]["bytes_ratio_used"]},
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--impl", default="auto",
                    help="decode attend impl: auto | kernels")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_decode.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, impl=args.impl)


if __name__ == "__main__":
    main()
