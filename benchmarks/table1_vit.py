"""Paper Table 1 (+ Table 9): ViT classification — e2e backprop vs
DiffusionBlocks vs Forward-Forward. DB must track e2e; FF must collapse
(paper: 60.25 / 59.30 / 7.85 on CIFAR-100)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.vit import ViTDiffusionBlocks
from repro.data import GaussianMixtureImages
from repro.optim import adamw, apply_updates


CFG = ModelConfig(name="vit-bench", family="dense", n_layers=6, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=10,
                  norm="layernorm", mlp="gelu", rope_theta=0.0)


def _train(vit, params, loss_fn, data, steps, lr=2e-3, seed=0):
    init, update = adamw(lr)
    st = init(params)
    rng = jax.random.PRNGKey(seed)
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, x, y, r: loss_fn(p, x, y, r)[0]))
    for i in range(steps):
        x, y = next(data)
        rng, r = jax.random.split(rng)
        loss, grads = grad_fn(params, x, y, r)
        upd, st, _ = update(grads, st, params)
        params = apply_updates(params, upd)
    return params


def _accuracy(pred, y):
    return float((np.asarray(pred) == np.asarray(y)).mean())


def _forward_forward(images, labels, test_x, test_y, num_classes, steps,
                     d=64, n_layers=4, lr=2e-3, seed=0):
    """Forward-Forward baseline (Hinton 2022): layer-local goodness training
    with label overlaid on the input; classify by total goodness."""
    rngk = jax.random.PRNGKey(seed)
    flat = images.reshape(images.shape[0], -1)
    din = flat.shape[-1] + num_classes
    dims = [din] + [d] * n_layers
    ws = [jax.random.normal(jax.random.fold_in(rngk, i),
                            (dims[i], dims[i + 1])) / np.sqrt(dims[i])
          for i in range(n_layers)]

    def overlay(x, y):
        onehot = jax.nn.one_hot(y, num_classes)
        return jnp.concatenate([x, onehot], -1)

    def layer_fwd(w, h):
        h = h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
        return jax.nn.relu(h @ w)

    def goodness_loss(w, h_pos, h_neg):
        gp = jnp.sum(jnp.square(layer_fwd(w, h_pos)), -1)
        gn = jnp.sum(jnp.square(layer_fwd(w, h_neg)), -1)
        theta = 2.0
        return jnp.mean(jax.nn.softplus(theta - gp)
                        + jax.nn.softplus(gn - theta))

    rng = np.random.RandomState(seed)
    init, update = adamw(lr)
    sts = [init(w) for w in ws]
    gfn = jax.jit(jax.value_and_grad(goodness_loss))
    n = flat.shape[0]
    for it in range(steps):
        idx = rng.randint(0, n, 32)
        x, y = flat[idx], labels[idx]
        y_neg = (y + rng.randint(1, num_classes, 32)) % num_classes
        h_pos, h_neg = overlay(x, y), overlay(x, y_neg)
        for li in range(n_layers):
            _, g = gfn(ws[li], h_pos, h_neg)
            upd, sts[li], _ = update(g, sts[li], ws[li])
            ws[li] = apply_updates(ws[li], upd)
            h_pos = jax.lax.stop_gradient(layer_fwd(ws[li], h_pos))
            h_neg = jax.lax.stop_gradient(layer_fwd(ws[li], h_neg))

    tflat = test_x.reshape(test_x.shape[0], -1)
    goods = []
    for c in range(num_classes):
        h = overlay(tflat, jnp.full((tflat.shape[0],), c))
        total = 0.0
        for w in ws:
            h = layer_fwd(w, h)
            total = total + jnp.sum(jnp.square(h), -1)
        goods.append(total)
    pred = jnp.argmax(jnp.stack(goods, -1), -1)
    return _accuracy(pred, test_y)


def run(quick: bool = True):
    steps = 150 if quick else 600
    g = GaussianMixtureImages(num_classes=10, image_size=16, noise_scale=2.0,
                              seed=0)
    data_rng = np.random.RandomState(1)

    def data():
        while True:
            x, y = g.sample(data_rng, 32)
            yield jnp.asarray(x), jnp.asarray(y)

    test_x, test_y = g.sample(np.random.RandomState(99), 256)
    test_x, test_y = jnp.asarray(test_x), jnp.asarray(test_y)

    db = DBConfig(num_blocks=3, overlap_gamma=0.05)
    vit = ViTDiffusionBlocks(CFG, db, image_size=16, patch=4, channels=3)

    # e2e baseline
    p = vit.init(jax.random.PRNGKey(0))
    p = _train(vit, p, lambda pp, x, y, r: vit.e2e_loss(pp, x, y, r),
               data(), steps)
    pred_e2e, _ = vit.predict_e2e(p, test_x)
    acc_e2e = _accuracy(pred_e2e, test_y)

    # DiffusionBlocks (block-cycling)
    p = vit.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    it = data()
    from repro.optim import adamw as _ad
    init, update = _ad(2e-3)
    st = init(p)
    grad_fns = [jax.jit(jax.value_and_grad(
        lambda pp, x, y, r, b=b: vit.block_loss(pp, b, x, y, r)[0]))
        for b in range(db.num_blocks)]
    key = jax.random.PRNGKey(1)
    for i in range(steps):
        x, y = next(it)
        key, r = jax.random.split(key)
        b = rng.randint(0, db.num_blocks)
        loss, grads = grad_fns[b](p, x, y, r)
        upd, st, _ = update(grads, st, p)
        p = apply_updates(p, upd)
    pred_db, _ = vit.predict(p, test_x, jax.random.PRNGKey(7), num_steps=8)
    acc_db = _accuracy(pred_db, test_y)

    # Forward-Forward
    train_x, train_y = g.sample(np.random.RandomState(2), 2048)
    acc_ff = _forward_forward(jnp.asarray(train_x), jnp.asarray(train_y),
                              test_x, test_y, 10, steps)

    return [
        {"name": "ViT-e2e", "accuracy": acc_e2e, "layers_with_grads": 6},
        {"name": "ViT+DiffusionBlocks", "accuracy": acc_db,
         "layers_with_grads": 2},
        {"name": "Forward-Forward", "accuracy": acc_ff,
         "layers_with_grads": 1},
    ]
