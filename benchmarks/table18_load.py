"""Table 18 (beyond-paper): serving capacity under offered load — the
repo's first end-to-end serving benchmark (ROADMAP open item 5).

A traffic-replay harness (``benchmarks/loadgen.py``) drives the continuous
batcher with Poisson and BURSTY arrivals, heavy-tailed prompt/output
lengths, a shared-system-prompt population (prefix-cache hits under load),
and mixed conditioned/unconditioned requests, at several offered loads
bracketing the engine's calibrated capacity:

  TTFT p50/p99    submit -> first streamed segment, per offered load. Rises
                  sharply past saturation (queueing delay dominates).
  TPOT p50/p99    steady-state inter-token pace after the first segment.
                  Stays roughly flat under load — slots decode at the same
                  segment cadence; admission waits, decoding doesn't.
  saturation knee the highest offered load whose p99 TTFT stays within 3x
                  the lightest-load p99 (per arrival mode).
  transport       in-process replay isolates scheduler capacity; one HTTP
                  point replays the same trace through the asyncio SSE
                  frontend (client-observed latency, loopback socket).

Bit-parity gate (CI): before measuring, streamed SSE output is asserted
bit-identical to the non-streaming JSON path AND to static ``generate()``
for the same PRNGKey (single-slot servers, sequential requests — see
``docs/api.md`` for why parity is defined that way).

CPU caveat: absolute capacity numbers are CPU-of-the-day figures for a tiny
model; the CURVE SHAPE (flat TPOT, TTFT knee, Poisson vs bursty gap) is the
measurement. Writes ``BENCH_load.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os

import jax
import numpy as np

try:
    from benchmarks.loadgen import (find_knee, offered_rate, replay_http,
                                    replay_inproc, summarize, synth_workload)
except ImportError:                      # run as a script: benchmarks/ on path
    from loadgen import (find_knee, offered_rate, replay_http,
                         replay_inproc, summarize, synth_workload)

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, generate
from repro.launch.server import InferenceServer, request_json, stream_generate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(name="bench-load-vlm", family="vlm", n_layers=4,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=32, cross_attn_every=2, n_image_tokens=4)
MAX_PROMPT, MAX_NEW_CAP = 24, 12
CB_KW = dict(num_slots=4, page_size=4, max_prompt=MAX_PROMPT,
             max_len=MAX_PROMPT + MAX_NEW_CAP, seg_len=4, chunk_size=8,
             precision="fp32", prefix_cache=True)
WL_KW = dict(vocab=CFG.vocab_size, max_prompt=MAX_PROMPT,
             max_new_cap=MAX_NEW_CAP, sys_len=8, sys_frac=0.5,
             cond_frac=0.3)


def _build():
    dbm = DiffusionBlocksModel(CFG, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(99)
    registry = {f"cond{i}": {"image_embs":
                             rs.randn(CFG.n_image_tokens, CFG.d_model)
                             .astype(np.float32)}
                for i in range(3)}
    return dbm, params, registry


def _parity_check(dbm, params, n_prompts: int, max_new: int, seed: int):
    """Acceptance gate: SSE reassembly == non-streaming JSON == static
    ``generate`` for the same PRNGKey. Single-slot servers, ONE request in
    flight at a time — greedy denoising draws its start noise per slot from
    the rng stream, so this is the geometry under which bit-parity is
    defined (matches tests/test_server.py)."""
    rs = np.random.RandomState(seed)
    prompts = [rs.randint(0, CFG.vocab_size, size=int(rs.randint(3, 12)))
               for _ in range(n_prompts)]
    one_slot = dict(CB_KW, num_slots=1, prefix_cache=False)

    async def serve_all(stream: bool):
        cb = ContinuousBatcher(dbm, params, **one_slot)
        server = InferenceServer(cb, rng=jax.random.PRNGKey(seed))
        await server.start()
        out = []
        try:
            for p in prompts:
                if stream:
                    r = await stream_generate("127.0.0.1", server.port, p,
                                              max_new)
                    assert r["status"] == 200, r
                    out.append(r["ids"])
                else:
                    code, obj = await request_json(
                        "127.0.0.1", server.port, "POST", "/v1/generate",
                        {"prompt": [int(t) for t in p], "max_new": max_new,
                         "stream": False})
                    assert code == 200, obj
                    out.append(obj["ids"])
        finally:
            await server.aclose()
        return out

    sse = asyncio.run(serve_all(True))
    plain = asyncio.run(serve_all(False))
    direct = [int(t) for t in np.asarray(
        generate(dbm, params, np.asarray(prompts[0])[None], max_new,
                 rng=jax.random.PRNGKey(seed), precision="fp32",
                 page_size=4, chunk_size=8))[0, len(prompts[0]):]]
    assert sse == plain, "SSE stream != non-streaming greedy path"
    assert sse[0] == direct, "streamed output != static generate()"
    return {"checked": n_prompts, "max_new": max_new,
            "sse_equals_nonstreaming": True,
            "first_equals_static_generate": True}


def _inproc_point(dbm, params, registry, items, seed):
    cb = ContinuousBatcher(dbm, params, **CB_KW)
    aux = {k: v for k, v in registry.items()}
    recs = replay_inproc(cb, items, aux_registry=aux,
                         rng=jax.random.PRNGKey(seed))
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1
    return recs


def _http_point(dbm, params, registry, items, seed):
    async def main():
        cb = ContinuousBatcher(dbm, params, **CB_KW)
        server = InferenceServer(cb, aux_registry=registry,
                                 rng=jax.random.PRNGKey(seed))
        await server.start()
        try:
            return await replay_http("127.0.0.1", server.port, items)
        finally:
            await server.aclose()

    return asyncio.run(main())


def run(quick: bool = True, out: str = None):
    dbm, params, registry = _build()
    cond_names = tuple(sorted(registry))
    rs = np.random.RandomState(0)

    parity = _parity_check(dbm, params, n_prompts=3 if quick else 5,
                           max_new=7, seed=5)

    # warm up the num_slots=4 engine (parity ran single-slot servers, so the
    # batched programs compile here) — discard the records
    warm = synth_workload(rs, 6, arrival="poisson", rate=1000.0,
                          cond_names=cond_names, **WL_KW)
    for it in warm:
        it["t"] = 0.0
    _inproc_point(dbm, params, registry, warm, seed=0)

    # calibrate engine capacity: the whole trace arrives at t=0, so the
    # measured request rate is the scheduler's zero-queueing-slack ceiling
    n_cal = 16 if quick else 32
    calib_items = synth_workload(rs, n_cal, arrival="poisson", rate=1000.0,
                                 cond_names=cond_names, **WL_KW)
    for it in calib_items:
        it["t"] = 0.0
    cal = summarize(_inproc_point(dbm, params, registry, calib_items,
                                  seed=1))
    assert cal["errors"] == 0, cal
    capacity_rps = cal["completed"] / cal["makespan_s"]

    mults = (0.4, 0.9, 1.8) if quick else (0.3, 0.6, 0.9, 1.2, 1.8)
    n_pt = 24 if quick else 60
    sweep, knees = [], {}
    for mode in ("poisson", "bursty"):
        pts = []
        for i, m in enumerate(mults):
            rate = m * capacity_rps
            items = synth_workload(rs, n_pt, arrival=mode, rate=rate,
                                   cond_names=cond_names, **WL_KW)
            recs = _inproc_point(dbm, params, registry, items,
                                 seed=100 + i)
            s = summarize(recs, offered_rps=offered_rate(items))
            assert s["errors"] == 0 and s["completed"] == n_pt, s
            s.update(mode=mode, transport="inproc",
                     load_mult=round(m, 2))
            pts.append(s)
            print(f"[{mode} inproc] offered {s['offered_rps']:.2f} rps "
                  f"({m:.1f}x cap): p50/p99 TTFT "
                  f"{s['p50_ttft_ms']:.0f}/{s['p99_ttft_ms']:.0f} ms, "
                  f"p50/p99 TPOT {s['p50_tpot_ms']:.1f}/"
                  f"{s['p99_tpot_ms']:.1f} ms, {s['tok_s']:.0f} tok/s")
        sweep.extend(pts)
        knees[mode] = find_knee(pts)

    # one HTTP/SSE point at moderate load: the same trace shape through the
    # asyncio frontend — client-observed latency over loopback
    http_items = synth_workload(rs, 12 if quick else 24, arrival="poisson",
                                rate=0.8 * capacity_rps,
                                cond_names=cond_names, **WL_KW)
    http_recs = _http_point(dbm, params, registry, http_items, seed=7)
    http_s = summarize(http_recs, offered_rps=offered_rate(http_items))
    assert http_s["errors"] == 0, http_s
    http_s.update(mode="poisson", transport="http", load_mult=0.8)
    sweep.append(http_s)
    print(f"[poisson http]   offered {http_s['offered_rps']:.2f} rps: "
          f"p50/p99 TTFT {http_s['p50_ttft_ms']:.0f}/"
          f"{http_s['p99_ttft_ms']:.0f} ms")

    report = {
        "meta": {
            "model": CFG.name, "family": CFG.family,
            "backend": jax.default_backend(), "quick": bool(quick),
            "num_slots": CB_KW["num_slots"], "seg_len": CB_KW["seg_len"],
            "chunk_size": CB_KW["chunk_size"],
            "page_size": CB_KW["page_size"],
            "prefix_cache": CB_KW["prefix_cache"],
            "workload": {**WL_KW, "cond_names": list(cond_names)},
        },
        "parity": parity,
        "calibration": {**cal, "capacity_rps": round(capacity_rps, 3)},
        "sweep": sweep,
        "knee": knees,
        "note": ("CPU figures for a tiny model; the measurement is the "
                 "curve shape — flat TPOT vs offered load, the p99-TTFT "
                 "knee, and the Poisson/bursty gap — not absolute rps."),
    }
    out = out or os.path.join(ROOT, "BENCH_load.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"capacity {capacity_rps:.2f} rps | knee: "
          + ", ".join(f"{m} {k['knee_rps']}" for m, k in knees.items()))
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = []
    for s in r["sweep"]:
        rows.append({
            "name": f"{s['transport']}_{s['mode']}_{s['load_mult']}x",
            "offered_rps": s["offered_rps"],
            "p50_ttft_ms": s["p50_ttft_ms"], "p99_ttft_ms": s["p99_ttft_ms"],
            "p50_tpot_ms": s["p50_tpot_ms"], "p99_tpot_ms": s["p99_tpot_ms"],
            "tok_s": s["tok_s"], "completed": s["completed"],
        })
    rows.append({"name": "summary",
                 "capacity_rps": r["calibration"]["capacity_rps"],
                 "knee_poisson_rps": r["knee"]["poisson"]["knee_rps"],
                 "knee_bursty_rps": r["knee"]["bursty"]["knee_rps"],
                 "parity_bit_identical":
                     int(r["parity"]["sse_equals_nonstreaming"])})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace (CI smoke)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_load.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
