"""Table 14 (beyond-paper): fwd+bwd micro-benchmark of the custom-VJP Pallas
kernels vs the reference autodiff path (``jax.grad`` of ``kernels/ref.py``).

Two measurements per kernel, both on the current backend:

  walltime   mean fwd+bwd step time. On TPU the Pallas path is the compiled
             Mosaic kernel; on the CPU dev container it runs in INTERPRET
             mode (per-tile emulation), whose dispatch overhead dominates —
             walltime there characterizes the oracle, not the hardware path.
  bytes      ``compile().memory_analysis()`` temp bytes of the jitted
             fwd+bwd program — a MEASURED property of the compiled program
             on every backend. This is where the fused backward pays off:
             the custom VJP stores only (q, k, v, o, lse) and recomputes
             score tiles, while reference autodiff saves the (Sq, Sk)
             softmax (attention) / the broadcast intermediates (elementwise)
             as residuals. On bandwidth-bound accelerators bytes ≈ time.

Before this PR the comparison could not be run at all: differentiating
through ``pallas_call`` raises (no autodiff rule) — the kernels were
forward-only demos.

Writes ``BENCH_kernels.json`` at the repo root. ``--quick`` shrinks shapes
and reps for the CI smoke lane.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.edm_loss import edm_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adaln import (fused_euler, fused_gate_residual,
                                       fused_ln_modulate)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def timeit(fn, reps: int) -> float:
    jax.block_until_ready(fn())           # compile + warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps


def measure_pair(name, f_kernel, f_ref, args, reps):
    """Both callables: args -> scalar loss. Measures jitted value_and_grad."""
    argnums = tuple(range(len(args)))
    jk = jax.jit(jax.value_and_grad(f_kernel, argnums=argnums))
    jr = jax.jit(jax.value_and_grad(f_ref, argnums=argnums))
    row = {"name": name}
    row["fwdbwd_ms_kernel"] = timeit(lambda: jk(*args), reps) * 1e3
    row["fwdbwd_ms_ref"] = timeit(lambda: jr(*args), reps) * 1e3
    row["walltime_speedup"] = row["fwdbwd_ms_ref"] / row["fwdbwd_ms_kernel"]
    mk = jk.lower(*args).compile().memory_analysis()
    mr = jr.lower(*args).compile().memory_analysis()
    row["temp_bytes_kernel"] = int(mk.temp_size_in_bytes)
    row["temp_bytes_ref"] = int(mr.temp_size_in_bytes)
    row["bytes_speedup"] = (row["temp_bytes_ref"]
                            / max(row["temp_bytes_kernel"], 1))
    print(f"  {name:24s} kernel {row['fwdbwd_ms_kernel']:9.1f}ms "
          f"ref {row['fwdbwd_ms_ref']:9.1f}ms | temp "
          f"{row['temp_bytes_kernel']/1e6:8.1f}MB vs "
          f"{row['temp_bytes_ref']/1e6:8.1f}MB "
          f"({row['bytes_speedup']:.2f}x less)")
    return row


def run(quick: bool = True, out: str = None):
    interp = _interpret()
    if quick:
        reps, (B, H, S, hd), (Be, Se, de) = 1, (1, 2, 128, 32), (2, 256, 128)
    else:
        reps, (B, H, S, hd), (Be, Se, de) = 3, (2, 8, 512, 64), (8, 1024, 512)
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    rows = []
    print(f"backend={jax.default_backend()} interpret={interp} "
          f"attn=(B{B},H{H},S{S},hd{hd}) elt=(B{Be},S{Se},d{de})")

    # ---- flash attention (causal + the DB concat training mask) ----------
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    rows.append(measure_pair(
        "flash_attention/causal",
        lambda q, k, v: jnp.sum(flash_attention(
            q, k, v, causal=True, interpret=interp)),
        lambda q, k, v: jnp.sum(ref.mha_reference(q, k, v, causal=True)),
        (q, k, v), reps))

    from repro.nn.attention import db_concat_mask
    Sh = S // 2
    mask = db_concat_mask(Sh)(jnp.arange(S), jnp.arange(S))
    rows.append(measure_pair(
        "flash_attention/db_concat",
        lambda q, k, v: jnp.sum(flash_attention(
            q, k, v, mask_kind="db_concat", mask_seq=Sh, interpret=interp)),
        lambda q, k, v: jnp.sum(ref.mha_reference_masked(q, k, v, mask)),
        (q, k, v), reps))

    # ---- fused elementwise trio ------------------------------------------
    x = jax.random.normal(ks[3], (Be, Se, de))
    sc = 0.1 * jax.random.normal(ks[4], (Be, de))
    sh = 0.1 * jax.random.normal(ks[5], (Be, de))
    rows.append(measure_pair(
        "fused_ln_modulate",
        lambda x, sc, sh: jnp.sum(fused_ln_modulate(
            x, sc, sh, interpret=interp)),
        lambda x, sc, sh: jnp.sum(ref.ln_modulate_reference(x, sc, sh)),
        (x, sc, sh), reps))

    br = jax.random.normal(ks[6], (Be, Se, de))
    rows.append(measure_pair(
        "fused_gate_residual",
        lambda r, b2, g: jnp.sum(fused_gate_residual(
            r, b2, g, interpret=interp)),
        lambda r, b2, g: jnp.sum(ref.gate_residual_reference(r, b2, g)),
        (x, br, sc), reps))

    sig = jnp.linspace(0.5, 3.0, Be)
    rows.append(measure_pair(
        "fused_euler",
        lambda z, f: jnp.sum(fused_euler(
            z, f, sig, sig * 0.3, 0.5, interpret=interp)),
        lambda z, f: jnp.sum(ref.euler_reference(z, f, sig, sig * 0.3, 0.5)),
        (x, br), reps))

    rows.append(measure_pair(
        "edm_loss",
        lambda f, z, y: edm_loss(f, z, y, sig, 0.5, interpret=interp),
        lambda f, z, y: ref.edm_loss_reference(f, z, y, sig, 0.5),
        (br, x, jax.random.normal(ks[7], (Be, Se, de))), reps))

    geomean = lambda xs: math.exp(sum(math.log(max(x, 1e-9)) for x in xs)
                                  / len(xs))
    # The headline is the ATTENTION rows' measured residual-memory speedup:
    # reference autodiff must store the (Sq, Sk) softmax for the backward —
    # a residual XLA cannot fuse away — while the custom VJP keeps only
    # (q, k, v, o, lse) and recomputes score tiles. The elementwise rows'
    # temp bytes are reported too, but XLA already fuses those references on
    # CPU (their payoff is HBM round-trips on TPU, see kernel docstrings),
    # and at --quick shapes everything fits in cache.
    attn = [r["bytes_speedup"] for r in rows
            if r["name"].startswith("flash_attention")]
    wall_speedups = [r["walltime_speedup"] for r in rows]
    report = {
        "table": "table14_kernel_grads",
        "backend": jax.default_backend(),
        "pallas_mode": "interpret" if interp else "mosaic",
        "quick": bool(quick),
        "shapes": {"attention": [B, H, S, hd],
                   "elementwise": [Be, Se, de]},
        "fwdbwd_speedup_vs_ref_autodiff": geomean(attn),
        "speedup_metric": ("attention fwd+bwd temp bytes of the compiled "
                           "program (measured via memory_analysis; the S² "
                           "softmax residual autodiff stores and the custom "
                           "VJP does not)"),
        "walltime_speedup_geomean": geomean(wall_speedups),
        "walltime_note": (
            "CPU walltime runs the Pallas kernels in interpret mode "
            "(per-tile emulation; dispatch overhead dominates) — the "
            "compiled walltime comparison is TPU-only."
            if interp else "compiled Mosaic kernels"),
        "kernels": rows,
    }
    out = out or os.path.join(ROOT, "BENCH_kernels.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"fwd+bwd speedup vs reference autodiff "
          f"(measured temp bytes, geomean): "
          f"{report['fwdbwd_speedup_vs_ref_autodiff']:.2f}x")
    print("wrote", out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / 1 rep (CI smoke)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
