"""Table 17 (beyond-paper): conditioned-request serving — aux image/audio
conditioning through the batched engine vs the per-request dry-run path.

The paper's claim is that DiffusionBlocks scales beyond text-only workloads
(VLM / audio-conditioned generation). Until PR 5 the serving stack only
batched UNCONDITIONED text: conditioned requests fell back to the
single-request dense path (one jitted dispatch + host sync per token, one
request at a time, encoder re-run per request serve). This benchmark
measures what threading ``aux_inputs`` through the engine buys:

  engine       continuous batcher, conditioning-aware prefix cache ON:
               the modality frontend runs ONCE per request at admission
               (``model.encode_conditioning`` → ``set_conditioning``),
               conditioned + unconditioned slots share one compiled
               program, prefix pages are keyed by (tokens, conditioning
               fingerprint). Reported: tok/s, mean TTFT, prefix hits /
               shared tokens / CoW copies.
  dryrun       the per-request reference: DENSE caches, jitted per-token
               commit + serve_step loops, requests served one at a time.
               Reported: tok/s, mean TTFT.

Greedy parity is asserted per family: a single conditioned request served
through the continuous engine (prefix cache on) must be BIT-identical to
the dry-run path. Cross-conditioning isolation is asserted on the
workload: prefix hits only ever come from requests with the same
conditioning fingerprint.

Writes ``BENCH_conditioned.json`` at the repo root. ``--quick`` shrinks
shapes for the CI smoke lane (and fails loudly on parity regressions).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _models(quick: bool):
    d = 64
    vlm = ModelConfig(name="bench-vlm", family="vlm", n_layers=4, d_model=d,
                      n_heads=4, n_kv_heads=2, d_ff=2 * d, vocab_size=64,
                      cross_attn_every=2, n_image_tokens=8)
    audio = ModelConfig(name="bench-audio", family="audio", n_layers=2,
                        d_model=d, n_heads=4, n_kv_heads=4, d_ff=2 * d,
                        vocab_size=64, n_encoder_layers=2,
                        n_audio_frames=12, rope_theta=0.0, norm="layernorm",
                        mlp="gelu", is_encoder_decoder=True)
    return {"vlm": ("image_embs", vlm), "audio": ("audio_embs", audio)}


class DryrunServer:
    """Per-request dense reference: jitted per-token commit and serve_step
    (the pre-engine conditioned path — one request at a time, 1 dispatch +
    host sync per token). The jitted programs are built ONCE and reused
    across requests, so the comparison charges the dry-run path for its
    serial dispatches, not for recompilation."""

    def __init__(self, dbm, cond_tokens: int):
        self.dbm = dbm
        clens = jnp.full((1,), cond_tokens, jnp.int32)
        # params only feed the sigma embedding (None here) and the frontend
        # (skipped in decode mode), so the ctx template needs none
        ctx = dbm.make_ctx(None, 1, "decode", None, None,
                           cond_lengths=clens)
        ctx.positions = None

        @jax.jit
        def commit(params, cache, pos, tok):
            return dbm.commit_token(params, cache, pos, tok, ctx)

        @jax.jit
        def step(params, cache, pos, rng):
            return dbm.serve_step(params, cache, pos, rng,
                                  cond_lengths=clens)

        self._commit, self._step = commit, step

    def serve(self, params, prompt, max_new, aux, rng):
        """Returns (tokens, ttft_s, walltime_s)."""
        model = self.dbm.model
        S0 = prompt.size
        t0 = time.time()
        cond = model.encode_conditioning(          # encoder per request
            params, {k: jnp.asarray(v)[None] for k, v in aux.items()})
        cache = model.init_cache(1, S0 + max_new, jnp.float32)
        cache = model.set_conditioning(params, cache, cond)
        for t in range(S0):                        # 1 dispatch per token
            cache = self._commit(params, cache, t,
                                 jnp.asarray(prompt[t]).reshape(1, 1))
        toks, ttft = [], None
        for t in range(max_new):
            rng, rs_ = jax.random.split(rng)
            tok, cache = self._step(params, cache, S0 + t, rs_)
            toks.append(int(tok[0]))               # host sync per token
            if ttft is None:
                ttft = time.time() - t0
        return toks, ttft, time.time() - t0


def _workload(rs, vocab, aux_key, Sk, d, n_reqs, prompt_len):
    """Conditioned request mix: 2 distinct conditionings, repeated prompts
    under the SAME conditioning (prefix hits) and the SAME prompt under the
    OTHER conditioning (must NOT hit)."""
    conds = [4 * rs.randn(Sk, d).astype(np.float32) for _ in range(2)]
    sys_prompt = rs.randint(0, vocab, size=prompt_len - 4)
    reqs = []
    for i in range(n_reqs):
        sfx = rs.randint(0, vocab, size=4)
        prompt = np.concatenate([sys_prompt, sfx])
        cond = conds[i % 2]
        reqs.append((prompt, {aux_key: cond}, i % 2))
    return reqs


def run(quick: bool = True, out: str = None):
    if quick:
        n_reqs, prompt_len, max_new, slots, chunk = 6, 24, 6, 2, 8
    else:
        n_reqs, prompt_len, max_new, slots, chunk = 12, 48, 12, 3, 16
    page_size = 8
    report = {"table": "table17_conditioned",
              "backend": jax.default_backend(), "quick": bool(quick),
              "config": {"n_reqs": n_reqs, "prompt_len": prompt_len,
                         "max_new": max_new, "slots": slots,
                         "chunk_size": chunk, "page_size": page_size},
              "families": {}}

    for fam, (aux_key, cfg) in _models(quick).items():
        dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2,
                                                 overlap_gamma=0.1))
        params = dbm.init(jax.random.PRNGKey(0))
        if fam == "vlm":     # open the (zero-init) cross gate: image matters
            params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
                params["units"]["cross"]["xgate"])
        rs = np.random.RandomState(3)
        Sk = dbm.model.max_cond_tokens
        reqs = _workload(rs, cfg.vocab_size, aux_key, Sk, cfg.d_model,
                         n_reqs, prompt_len)
        print(f"== {fam}: {n_reqs} conditioned requests "
              f"(prompt {prompt_len}, +{max_new} tokens, {Sk} cond tokens, "
              f"2 distinct conditionings)")

        def make_cb():
            return ContinuousBatcher(
                dbm, params, num_slots=slots, page_size=page_size,
                max_prompt=prompt_len, max_len=prompt_len + max_new,
                seg_len=8, chunk_size=chunk, precision="fp32",
                prefix_cache=True)

        def serve_engine():
            cb = make_cb()
            for prompt, aux, _ in reqs:
                cb.submit(prompt, max_new, aux_inputs=aux)
            t0 = time.time()
            done = cb.run(jax.random.PRNGKey(11))
            dt = time.time() - t0
            return cb, done, dt

        serve_engine()                              # warm compiled programs
        cb, done, dt_eng = serve_engine()
        n_tok = sum(len(r.out) for r in done)
        ttfts = [r.ttft for r in done if r.ttft is not None]
        shared = sum(r.shared_tokens for r in done)
        # cross-conditioning isolation: a hit implies an earlier request
        # with the SAME fingerprint and the same prefix
        fp_prompts = {}
        for (prompt, _, ci), r in zip(reqs, done):
            if r.shared_tokens:
                seen = fp_prompts.get(r.cond_fp, [])
                assert any(np.array_equal(p[:r.shared_tokens],
                                          prompt[:r.shared_tokens])
                           for p in seen), \
                    f"{fam}: shared tokens without a same-conditioning donor"
            fp_prompts.setdefault(r.cond_fp, []).append(prompt)
        eng_row = {"walltime_s": dt_eng, "tok_s": n_tok / dt_eng,
                   "mean_ttft_s": float(np.mean(ttfts)),
                   "prefix_hits": int(cb.prefix.hits),
                   "shared_prompt_tokens": int(shared),
                   "cow_copies": int(cb.cow_copies)}
        print(f"  engine {eng_row['tok_s']:8.1f} tok/s | mean TTFT "
              f"{eng_row['mean_ttft_s']*1e3:7.1f}ms | "
              f"{eng_row['prefix_hits']} prefix hits, {shared} shared "
              f"prompt tokens, {eng_row['cow_copies']} CoW copies")

        # per-request dry-run reference over the same workload (compiled
        # once — the comparison charges serial dispatches, not retraces).
        # TTFT is measured against the WORKLOAD submission time, as for the
        # engine: on a one-request-at-a-time server, request i's first
        # token waits behind requests 0..i-1.
        dryrun = DryrunServer(dbm, Sk)

        def serve_dryrun():
            t0, ttfts, n = time.time(), [], 0
            outs = []
            for i, (prompt, aux, _) in enumerate(reqs):
                waited = time.time() - t0
                toks, ttft, _ = dryrun.serve(params, prompt, max_new, aux,
                                             jax.random.PRNGKey(100 + i))
                outs.append(toks)
                ttfts.append(waited + ttft)
                n += len(toks)
            return outs, ttfts, n, time.time() - t0

        serve_dryrun()                              # warm
        _, dr_ttfts, dr_tok, dt_dry = serve_dryrun()
        dry_row = {"walltime_s": dt_dry, "tok_s": dr_tok / dt_dry,
                   "mean_ttft_s": float(np.mean(dr_ttfts))}
        print(f"  dryrun {dry_row['tok_s']:8.1f} tok/s | mean TTFT "
              f"{dry_row['mean_ttft_s']*1e3:7.1f}ms  (per-request dense "
              f"loop, 1 dispatch + host sync per token)")

        # greedy parity: single conditioned request, engine == dryrun
        prompt, aux, _ = reqs[0]
        ref, _, _ = dryrun.serve(params, prompt, max_new, aux,
                                 jax.random.PRNGKey(55))
        cb1 = ContinuousBatcher(
            dbm, params, num_slots=1, page_size=page_size,
            max_prompt=prompt_len, max_len=prompt_len + max_new, seg_len=8,
            chunk_size=chunk, precision="fp32", prefix_cache=True)
        cb1.submit(prompt, max_new, aux_inputs=aux)
        got = cb1.run(jax.random.PRNGKey(55))[0].out
        parity = got == ref
        print(f"  greedy engine == dryrun: {parity}")
        assert parity, f"{fam}: conditioned engine diverged from dryrun"
        assert eng_row["prefix_hits"] > 0, \
            f"{fam}: same-conditioning repeats must hit the prefix cache"

        report["families"][fam] = {
            "engine": eng_row, "dryrun": dry_row,
            "throughput_speedup": eng_row["tok_s"] / dry_row["tok_s"],
            "ttft_speedup": dry_row["mean_ttft_s"] / eng_row["mean_ttft_s"],
            "greedy_identical": bool(parity),
        }
        fr = report["families"][fam]
        print(f"  speedup: {fr['throughput_speedup']:.2f}x throughput, "
              f"{fr['ttft_speedup']:.2f}x TTFT")

    out = out or os.path.join(ROOT, "BENCH_conditioned.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = []
    for fam, fr in r["families"].items():
        rows.append({"name": f"{fam}_engine", **fr["engine"]})
        rows.append({"name": f"{fam}_dryrun", **fr["dryrun"]})
        rows.append({"name": f"{fam}_summary",
                     "throughput_speedup": fr["throughput_speedup"],
                     "ttft_speedup": fr["ttft_speedup"],
                     "greedy_identical": int(fr["greedy_identical"])})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(quick=not args.full, out=args.out)


if __name__ == "__main__":
    main()
