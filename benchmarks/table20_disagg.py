"""Table 20 (beyond-paper): disaggregated prefill/decode serving — request
migration parity, decode-TPOT isolation, and chaos-mode fault tolerance
(ROADMAP open item 2, disaggregation half).

A ``DisaggRouter`` splits the engine into prefill workers (prompt ingest)
and decode workers (token generation), migrating each request at the
prefill/decode boundary as a host byte-copy (``handoff="copy"``) or a
page-table handle on one shared pool (``handoff="pages"``). Three points:

  parity gate     ASSERTED: a request migrated prefill->decode produces
                  bit-identical greedy output to the same request on one
                  unified batcher — conditioned (cross-attending vlm) AND
                  unconditioned, both handoff modes. Likewise a request
                  whose decode worker is KILLED mid-stream: the failover
                  (page-handle re-migration or re-prefill from delivered
                  tokens, plus rng-stream adoption by the idle receiver)
                  reproduces the uninterrupted output exactly.
  tpot point      ASSERTED at the scheduler level: the same mixed
                  ingest+interactive burst puts ``ingest_dispatches`` > 0
                  prompt-chunk calls on the unified batcher's loop
                  (long-prompt chunks interleave with every decode
                  segment) but ZERO on the disaggregated decode worker —
                  its dispatch stream is pure decode, which is the
                  protection mechanism itself.
                  Wall-clock TPOT percentiles for both are reported
                  informationally only: this harness threads both workers
                  onto one CPU core, so wall-clock shows core contention,
                  not the isolation of a per-worker-device deployment.
  chaos point     ASSERTED: with seeded ``worker_die`` kills (both roles)
                  and ``handoff_drop`` payload losses injected, every
                  request still completes with zero errors, full token
                  counts, and whole page pools (no leaked page or slot) —
                  for both handoff modes.

CPU caveat: absolute latencies are CPU-of-the-day figures for a tiny
model; the measurements are the parity bits, the completion/leak
invariants, and the dispatch-level decode-isolation contrast. Writes
``BENCH_disagg.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

try:
    from benchmarks.loadgen import (at_time_zero, mixed_trace, replay_inproc,
                                    replay_threaded, summarize)
except ImportError:                      # run as a script: benchmarks/ on path
    from loadgen import (at_time_zero, mixed_trace, replay_inproc,
                         replay_threaded, summarize)

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector
from repro.launch.router import DisaggRouter
from repro.launch.serve import ContinuousBatcher

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(name="bench-disagg-vlm", family="vlm", n_layers=4,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=32, cross_attn_every=2, n_image_tokens=4)
MAX_PROMPT, MAX_NEW_CAP = 24, 12
# chunk_size 4 = six ingest dispatches per max-length prompt: on a unified
# batcher every one of them interleaves with a decode segment, which is
# exactly the interference disaggregation removes
CB_KW = dict(num_slots=4, page_size=4, max_prompt=MAX_PROMPT,
             max_len=MAX_PROMPT + MAX_NEW_CAP, seg_len=4, chunk_size=4,
             precision="fp32")


def _build():
    dbm = DiffusionBlocksModel(CFG, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(99)
    registry = {f"cond{i}": {"image_embs":
                             rs.randn(CFG.n_image_tokens, CFG.d_model)
                             .astype(np.float32)}
                for i in range(3)}
    return dbm, params, registry


def _pool_whole(router):
    """No leaked page anywhere: every non-trash page is free or mapped."""
    if router.pool is not None:
        free, refs, tot = (len(router.pool.free_pages),
                           len(router.pool.page_refs),
                           router.pool.total_pages)
        assert free + refs == tot - 1, ("shared pool leak", free, refs, tot)
    else:
        for w in router.workers:
            free, refs, tot = (len(w.cb.free_pages), len(w.cb.page_refs),
                               w.cb.total_pages)
            assert free + refs == tot - 1, (w.name, free, refs, tot)
    assert not router._handoffs, "payload stranded in the handoff queue"


def _unified_sequential(dbm, params, reqs, seed):
    """Ground truth: each request alone on one unified batcher, one shared
    rng stream across the whole sequence."""
    cb = ContinuousBatcher(dbm, params, **dict(CB_KW, num_slots=2))
    rng = jax.random.PRNGKey(seed)
    outs = []
    for prompt, max_new, aux in reqs:
        cb.submit(prompt, max_new, aux_inputs=aux)
        fin = []
        while cb.has_work():
            rng, f = cb.step(rng, strict=False)
            fin.extend(f)
        assert len(fin) == 1 and fin[0].error is None, fin
        outs.append(list(fin[0].out))
    return outs


def _router_sequential(dbm, params, reqs, *, handoff, seed, die_at=None):
    """The same requests, one at a time, through a disaggregated router.
    ``decode0`` is seeded with the unified baseline's rng so the migrated
    decode consumes the identical stream (prefill consumes none).
    ``die_at``: kill decode0 on its ``die_at``-th engine step — the first
    request dies mid-decode and must fail over to decode1, which adopts
    the dead worker's rng stream."""
    router = DisaggRouter(dbm, params, n_prefill=1,
                          n_decode=2 if die_at is not None else 1,
                          handoff=handoff, **dict(CB_KW, num_slots=2))
    done = {}
    router.finish_cb = lambda r: done.setdefault(r.rid, r)
    router.decode_workers[0].runner.rng = jax.random.PRNGKey(seed)
    if die_at is not None:
        router.decode_workers[0].cb.faults = FaultInjector(
            {"worker_die": {"at": [die_at]}}, seed=0)
    router.start()
    outs = []
    try:
        for prompt, max_new, aux in reqs:
            rid = router.submit(prompt, max_new, aux_inputs=aux)
            t0 = time.time()
            while rid not in done and time.time() - t0 < 180:
                time.sleep(0.005)
            assert rid in done, ("router request never finished", rid)
            r = done[rid]
            assert r.error is None, r.error
            outs.append(list(r.out))
    finally:
        router.stop(30)
    _pool_whole(router)
    return outs, router.stats()


def _parity(dbm, params, registry):
    """The two acceptance gates: clean-migration parity and mid-decode
    failover parity, conditioned + unconditioned, both handoff modes."""
    rs = np.random.RandomState(7)
    out = {"migration": {}, "failover": {}}
    for aux_name in (None, "cond0"):
        aux = registry[aux_name] if aux_name else None
        reqs = [(rs.randint(0, CFG.vocab_size, size=n).astype(np.int32),
                 8, aux) for n in (9, 13)]
        base = _unified_sequential(dbm, params, reqs, seed=11)
        pop = aux_name or "unconditioned"
        for handoff in ("copy", "pages"):
            got, stats = _router_sequential(dbm, params, reqs,
                                            handoff=handoff, seed=11)
            assert got == base, ("migration parity", pop, handoff, got, base)
            assert stats["migrations"] >= len(reqs), stats
            out["migration"][f"{pop}/{handoff}"] = True
            # kill decode0 on its 2nd step: 4 of 8 tokens delivered, the
            # remainder must come out of the failover bit-identical
            got, stats = _router_sequential(dbm, params, reqs,
                                            handoff=handoff, seed=11,
                                            die_at=2)
            assert got == base, ("failover parity", pop, handoff, got, base)
            assert stats["failovers"] >= 1, stats
            out["failover"][f"{pop}/{handoff}"] = True
    out["bit_identical"] = True
    return out


def _tpot_contrast(dbm, params, n):
    """Identical ingest+interactive burst, unified vs disaggregated.

    The ASSERTED contrast is at the scheduler level, where it is
    deterministic: on the unified batcher every long-prompt chunk dispatch
    runs in the same step loop as the interactive decode segments
    (``ingest_dispatches`` > 0 on the batcher serving decode), while the
    disaggregated decode worker makes ZERO ingest dispatches — its decode
    segments are never interleaved with prompt chunks, which is the
    protection mechanism itself. Wall-clock TPOT percentiles are reported
    for both but NOT asserted: this harness runs both workers as threads
    on one CPU, so they contend for the same core and wall-clock shows the
    contention, not the isolation a per-worker-device deployment gets."""
    rs = np.random.RandomState(3)
    items = at_time_zero(mixed_trace(
        rs, n, rate=1000.0, vocab=CFG.vocab_size, max_prompt=MAX_PROMPT,
        max_new_cap=MAX_NEW_CAP, long_frac=0.4, long_new=2, short_prompt=4))

    def split(recs):
        inter = summarize([r for r in recs if r["cls"] == "interactive"])
        ingest = summarize([r for r in recs if r["cls"] == "ingest"])
        return {"interactive": inter, "ingest": ingest}

    cb = ContinuousBatcher(dbm, params, **CB_KW)
    uni = split(replay_inproc(cb, items, rng=jax.random.PRNGKey(5)))
    assert uni["interactive"]["errors"] == 0, uni
    uni_mix = {"ingest_dispatches": cb.ingest_dispatches,
               "decode_dispatches": cb.decode_dispatches}

    router = DisaggRouter(dbm, params, n_prefill=1, n_decode=1,
                          handoff="copy", **CB_KW)
    router.start()
    try:
        recs = replay_threaded(router, items, timeout_s=300)
    finally:
        router.stop(30)
    _pool_whole(router)
    dis = split(recs)
    assert dis["interactive"]["errors"] == 0, dis
    dec_cb = router.decode_workers[0].cb
    pre_cb = router.prefill_workers[0].cb
    dis_mix = {"decode_worker": {"ingest_dispatches": dec_cb.ingest_dispatches,
                                 "decode_dispatches": dec_cb.decode_dispatches},
               "prefill_worker": {"ingest_dispatches": pre_cb.ingest_dispatches,
                                  "decode_dispatches": pre_cb.decode_dispatches}}
    # the isolation gate: ingest never touches the decode worker's loop
    assert uni_mix["ingest_dispatches"] > 0, uni_mix
    assert dis_mix["decode_worker"]["ingest_dispatches"] == 0, dis_mix
    assert dis_mix["prefill_worker"]["ingest_dispatches"] > 0, dis_mix
    return {"unified": uni, "disagg": dis,
            "unified_dispatch_mix": uni_mix, "disagg_dispatch_mix": dis_mix,
            "decode_isolated": True,
            "ingest_on_decode_engine":
                {"unified": uni_mix["ingest_dispatches"], "disagg": 0}}


def _chaos(dbm, params, n, handoff):
    """Seeded kills on BOTH roles + dropped handoff payloads; workers
    restart after 0.75 s. ASSERTED: every request completes in full, zero
    errors, pools whole — the robustness acceptance gate."""
    rs = np.random.RandomState(13)
    items = mixed_trace(rs, n, rate=3.0, vocab=CFG.vocab_size,
                        max_prompt=MAX_PROMPT, max_new_cap=MAX_NEW_CAP,
                        long_frac=0.35, long_new=2, short_prompt=4)
    faults = FaultInjector({"worker_die": {"at": [6, 25]},
                            "handoff_drop": {"every": 3}}, seed=2)
    router = DisaggRouter(dbm, params, n_prefill=1, n_decode=1,
                          handoff=handoff, restart_dead_after_s=0.75,
                          faults=faults, **CB_KW)
    router.start()
    try:
        recs = replay_threaded(router, items, timeout_s=300)
    finally:
        router.stop(60)
    _pool_whole(router)
    stats = router.stats()
    inj = faults.stats()
    assert len(recs) == n and not any(r.get("shed") for r in recs), recs
    errs = [r["error"] for r in recs if r.get("error")]
    assert not errs, errs
    for it, r in zip(items, recs):
        assert r["n"] == it["max_new"], ("short output under chaos",
                                         r["n"], it["max_new"])
    assert inj["worker_die"]["fired"] >= 2, inj
    assert stats["failovers"] >= 1, stats
    assert stats["handoff_drops"] >= 1, stats
    return {"handoff": handoff, "n": n, "completed": len(recs),
            "errors": 0, "pool_whole": True,
            "worker_die_fired": inj["worker_die"]["fired"],
            "handoff_drops": stats["handoff_drops"],
            "failovers": stats["failovers"],
            "re_prefills": stats["re_prefills"],
            "migrations": stats["migrations"],
            "degradations": stats["degradations"],
            "resplits": stats["resplits"],
            "worker_restarts": sum(w["worker_restarts"]
                                   for w in stats["workers"]),
            "summary": summarize(recs)}


def run(quick: bool = True, out: str = None):
    dbm, params, registry = _build()

    parity = _parity(dbm, params, registry)
    print(f"[parity] migration + mid-decode failover bit-identical "
          f"({len(parity['migration'])} migration, "
          f"{len(parity['failover'])} failover populations)")

    tpot = _tpot_contrast(dbm, params, n=16 if quick else 48)
    print(f"[tpot] ingest dispatches on the decode engine: unified "
          f"{tpot['ingest_on_decode_engine']['unified']} vs disagg 0 "
          f"(p99 TPOT unified "
          f"{tpot['unified']['interactive']['p99_tpot_ms']} ms, disagg "
          f"{tpot['disagg']['interactive']['p99_tpot_ms']} ms — 1-core "
          f"wall-clock, informational)")

    chaos = {}
    for handoff in ("copy", "pages"):
        chaos[handoff] = _chaos(dbm, params, n=12 if quick else 32,
                                handoff=handoff)
        c = chaos[handoff]
        print(f"[chaos {handoff}] {c['completed']}/{c['n']} completed | "
              f"{c['worker_die_fired']} kills, {c['handoff_drops']} drops, "
              f"{c['failovers']} failovers, {c['re_prefills']} re-prefills "
              f"| pools whole")

    report = {
        "meta": {
            "model": CFG.name, "family": CFG.family,
            "backend": jax.default_backend(), "quick": bool(quick),
            "num_slots": CB_KW["num_slots"], "page_size": CB_KW["page_size"],
            "seg_len": CB_KW["seg_len"], "chunk_size": CB_KW["chunk_size"],
        },
        "parity": parity,
        "tpot": tpot,
        "chaos": chaos,
        "note": ("CPU figures for a tiny model; the measurements are the "
                 "migration/failover parity bits, the chaos completion and "
                 "pool-wholeness invariants, and the unified-vs-disagg "
                 "interactive TPOT contrast, not absolute latency."),
    }
    out = out or os.path.join(ROOT, "BENCH_disagg.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = [{
        "name": "parity",
        "bit_identical": int(r["parity"]["bit_identical"]),
        "migration_cases": len(r["parity"]["migration"]),
        "failover_cases": len(r["parity"]["failover"]),
    }, {
        "name": "tpot_interactive",
        "unified_p99_tpot_ms":
            r["tpot"]["unified"]["interactive"]["p99_tpot_ms"],
        "disagg_p99_tpot_ms":
            r["tpot"]["disagg"]["interactive"]["p99_tpot_ms"],
        "ingest_on_decode_engine_unified":
            r["tpot"]["ingest_on_decode_engine"]["unified"],
        "ingest_on_decode_engine_disagg": 0,
        "decode_isolated": int(r["tpot"]["decode_isolated"]),
    }]
    for handoff, c in r["chaos"].items():
        rows.append({
            "name": f"chaos_{handoff}", "n": c["n"],
            "completed": c["completed"], "errors": c["errors"],
            "kills": c["worker_die_fired"], "drops": c["handoff_drops"],
            "failovers": c["failovers"], "re_prefills": c["re_prefills"],
            "pool_whole": int(c["pool_whole"]),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small traces (CI smoke)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_disagg.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
