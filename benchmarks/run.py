"""Benchmark orchestrator — one module per paper table.

Prints ``table,name,metric,value`` CSV and writes
experiments/bench_results.json. ``--quick`` (default) keeps everything
CPU-minutes; ``--full`` runs longer training. ``--only tableN`` selects one.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="experiments/bench_results.json")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (table1_vit, table2_dit, table3_mdm, table4_ar,
                            table5_recurrent, table6_noprop,
                            table7_partitioning, table8_blockcount,
                            table12_walltime, table13_blockparallel,
                            table14_kernel_grads, table15_decode,
                            table16_prefill, table17_conditioned,
                            table18_load, table19_slo, table20_disagg,
                            table21_faulttrain, table22_quantkv)
    from benchmarks.common import emit

    tables = {
        "table1_vit_classification": table1_vit.run,
        "table2_dit_generation": table2_dit.run,
        "table3_mdm_text": table3_mdm.run,
        "table4_ar_text": table4_ar.run,
        "table5_recurrent_depth": table5_recurrent.run,
        "table6_noprop": table6_noprop.run,
        "table7_partitioning": table7_partitioning.run,
        "table8_blockcount": table8_blockcount.run,
        "table12_walltime_memory": table12_walltime.run,
        "table13_blockparallel_walltime": table13_blockparallel.run,
        "table14_kernel_grads": table14_kernel_grads.run,
        "table15_decode": table15_decode.run_rows,
        "table16_prefill": table16_prefill.run_rows,
        "table17_conditioned": table17_conditioned.run_rows,
        "table18_load": table18_load.run_rows,
        "table19_slo": table19_slo.run_rows,
        "table20_disagg": table20_disagg.run_rows,
        "table21_faulttrain": table21_faulttrain.run_rows,
        "table22_quantkv": table22_quantkv.run_rows,
    }
    if args.only:
        tables = {k: v for k, v in tables.items() if args.only in k}

    lines = ["table,name,metric,value"]
    results = {}
    failures = []
    for name, fn in tables.items():
        t0 = time.time()
        try:
            rows = fn(quick=quick)
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
            continue
        results[name] = rows
        emit([dict(r) for r in rows], name, lines)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    print("\n".join(lines))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
