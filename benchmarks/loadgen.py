"""Traffic-replay load generator for the serving stack.

Synthesizes realistic request traces and replays them against the engine —
either IN-PROCESS (a submitter thread feeding ``ContinuousBatcher.step()``
directly, isolating scheduler capacity from HTTP overhead) or over HTTP/SSE
through ``repro.launch.server`` (client-observed latency). Traces model the
pathologies a static benchmark misses:

  arrivals        Poisson (exponential inter-arrival gaps at ``rate`` req/s)
                  or BURSTY: geometric-size bursts (mean ``burst_mean``)
                  arriving as a Poisson process at ``rate / burst_mean``
                  bursts/s — same mean offered load, heavy short-term
                  overload.
  lengths         heavy-tailed prompt and output lengths (clipped lognormal)
                  — a few long requests among many short ones.
  populations     a small pool of shared system prompts prepended to a
                  fraction of requests (exercises the prefix cache under
                  load), and mixed conditioned/unconditioned requests drawn
                  from a named conditioning pool.

Metrics per request: TTFT (submit -> first streamed token) and TPOT (mean
inter-token time after the first delivered segment), summarized as p50/p99
versus offered load. ``find_knee`` locates the saturation knee: the highest
offered load whose p99 TTFT stays within ``factor``x the lightest-load p99.
"""
from __future__ import annotations

import asyncio
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------

def _arrival_times(rs, n: int, arrival: str, rate: float,
                   burst_mean: float) -> np.ndarray:
    if arrival == "poisson":
        return np.cumsum(rs.exponential(1.0 / rate, size=n))
    if arrival == "bursty":
        t: List[float] = []
        now = 0.0
        while len(t) < n:
            now += rs.exponential(burst_mean / rate)   # burst-level process
            k = int(rs.geometric(1.0 / burst_mean))    # burst size, mean b
            t.extend([now] * min(k, n - len(t)))
        return np.asarray(t)
    raise ValueError(f"arrival must be 'poisson' or 'bursty', got {arrival!r}")


def synth_workload(rs, n: int, *, arrival: str = "poisson", rate: float = 4.0,
                   burst_mean: float = 4.0, vocab: int = 32,
                   max_prompt: int = 24, max_new_cap: int = 12,
                   prompt_med: float = 8.0, prompt_sigma: float = 0.6,
                   new_med: float = 6.0, new_sigma: float = 0.5,
                   sys_population: int = 3, sys_frac: float = 0.5,
                   sys_len: int = 8, cond_names=(), cond_frac: float = 0.0,
                   classes: Optional[List[Dict]] = None) -> List[Dict]:
    """One trace: n items of ``{"t", "prompt", "max_new", "aux", "cls",
    "priority", "ttft_slo_ms", "tpot_slo_ms"}`` with arrival offsets in
    seconds from trace start.

    ``classes``: optional priority-class mix — a list of
    ``{"name", "frac", "priority", "ttft_slo_ms"?, "tpot_slo_ms"?}`` dicts
    (fracs need not sum to 1; they are normalized). Default: every request
    is standard priority with no SLO."""
    t = _arrival_times(rs, n, arrival, rate, burst_mean)
    sys_prompts = [rs.randint(0, vocab, size=sys_len)
                   for _ in range(sys_population)]
    if classes:
        fracs = np.asarray([c["frac"] for c in classes], float)
        fracs = fracs / fracs.sum()
    items = []
    for i in range(n):
        plen = int(np.clip(rs.lognormal(np.log(prompt_med), prompt_sigma),
                           1, max_prompt))
        if sys_population and rs.rand() < sys_frac:
            sp = sys_prompts[int(rs.randint(sys_population))]
            tail = rs.randint(0, vocab, size=max(1, plen))
            prompt = np.concatenate([sp, tail])[:max_prompt]
        else:
            prompt = rs.randint(0, vocab, size=plen)
        max_new = int(np.clip(rs.lognormal(np.log(new_med), new_sigma),
                              1, max_new_cap))
        aux = (cond_names[int(rs.randint(len(cond_names)))]
               if len(cond_names) and rs.rand() < cond_frac else None)
        cls = (classes[int(rs.choice(len(classes), p=fracs))]
               if classes else None)
        items.append({"t": float(t[i]), "prompt": prompt,
                      "max_new": max_new, "aux": aux,
                      "cls": cls["name"] if cls else "standard",
                      "priority": cls["priority"] if cls else "standard",
                      "ttft_slo_ms": cls.get("ttft_slo_ms") if cls else None,
                      "tpot_slo_ms": cls.get("tpot_slo_ms") if cls else None})
    return items


def at_time_zero(items: List[Dict]) -> List[Dict]:
    """Copy of a trace with every arrival at t=0 — warmup and capacity
    calibration points (the zero-queueing-slack throughput ceiling)."""
    return [dict(it, t=0.0) for it in items]


def mixed_trace(rs, n: int, *, rate: float, vocab: int = 32,
                max_prompt: int = 24, max_new_cap: int = 12,
                long_frac: float = 0.4, long_prompt: Optional[int] = None,
                long_new: int = 2, short_prompt: int = 4,
                arrival: str = "poisson", burst_mean: float = 4.0,
                cond_names=(), cond_frac: float = 0.0) -> List[Dict]:
    """Bimodal ingest-vs-decode trace for prefill/decode interference
    studies: ``long_frac`` of the items are LONG-prompt, short-output
    requests (``cls="ingest"`` — pure prefill load), the rest short-prompt,
    long-output INTERACTIVE requests whose TPOT a co-scheduled ingest chunk
    dispatch would visibly stretch. Same record shape as
    ``synth_workload``."""
    t = _arrival_times(rs, n, arrival, rate, burst_mean)
    long_prompt = long_prompt if long_prompt is not None else max_prompt
    items = []
    for i in range(n):
        is_long = rs.rand() < long_frac
        plen = (long_prompt if is_long
                else int(np.clip(short_prompt + rs.randint(-1, 2),
                                 1, max_prompt)))
        max_new = long_new if is_long else max_new_cap
        aux = (cond_names[int(rs.randint(len(cond_names)))]
               if len(cond_names) and rs.rand() < cond_frac else None)
        items.append({"t": float(t[i]),
                      "prompt": rs.randint(0, vocab, size=plen),
                      "max_new": max_new, "aux": aux,
                      "cls": "ingest" if is_long else "interactive",
                      "priority": "standard",
                      "ttft_slo_ms": None, "tpot_slo_ms": None})
    return items


def offered_rate(items: List[Dict]) -> float:
    """Mean offered load of a trace in requests/s."""
    span = max(it["t"] for it in items)
    return len(items) / span if span > 0 else float("inf")


# ---------------------------------------------------------------------------
# Replay: in-process (batcher.step loop) and HTTP/SSE
# ---------------------------------------------------------------------------

def replay_inproc(cb, items: List[Dict], *, aux_registry=None, rng=None,
                  speed: float = 1.0) -> List[Dict]:
    """Drive one ``ContinuousBatcher`` with a submitter thread sleeping to
    the trace's arrival times while this thread runs the ``step()`` loop.
    Token timestamps come from the batcher's ``token_cb`` (segment
    granularity — exactly what an SSE consumer would observe, minus the
    socket). Returns one record per request."""
    aux_registry = aux_registry or {}
    recs: Dict[int, Dict] = {}
    lock = threading.Lock()

    def rec(rid: int) -> Dict:
        with lock:
            return recs.setdefault(rid, {"times": [], "counts": []})

    def on_tokens(req, toks):
        r = rec(req.rid)
        r["times"].append(time.time())
        r["counts"].append(len(toks))

    prev_cb = cb.token_cb
    cb.token_cb = on_tokens
    t0 = time.time()

    shed: List[Dict] = []
    rid_cls: Dict[int, str] = {}

    def submitter():
        from repro.launch.serve import AdmissionError
        for it in items:
            dt = t0 + it["t"] / speed - time.time()
            if dt > 0:
                time.sleep(dt)
            aux = aux_registry.get(it["aux"]) if it.get("aux") else None
            slo_kw = {}
            if it.get("ttft_slo_ms") is not None:
                slo_kw["ttft_slo_s"] = it["ttft_slo_ms"] / 1e3
            if it.get("tpot_slo_ms") is not None:
                slo_kw["tpot_slo_s"] = it["tpot_slo_ms"] / 1e3
            try:
                rid = cb.submit(np.asarray(it["prompt"], np.int32),
                                it["max_new"], aux_inputs=aux,
                                priority=it.get("priority", "standard"),
                                **slo_kw)
            except AdmissionError as e:
                # shed requests STAY in the record set (survivorship fix:
                # summaries report a shed rate, not quietly rosier TTFTs)
                shed.append({"submit": time.time(), "times": [],
                             "counts": [], "n": 0, "shared_tokens": 0,
                             "error": None, "shed": True,
                             "retry_after": e.retry_after,
                             "cls": it.get("cls", "standard")})
                continue
            rid_cls[rid] = it.get("cls", "standard")

    th = threading.Thread(target=submitter, name="loadgen-submit")
    th.start()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    finished = []
    while th.is_alive() or cb.has_work():
        if cb.has_work():
            rng, fin = cb.step(rng, strict=False)
            finished.extend(fin)
        else:
            time.sleep(0.001)
    th.join()
    cb.token_cb = prev_cb
    out = []
    for req in finished:
        r = rec(req.rid)
        out.append({"submit": req.submit_t, "times": r["times"],
                    "counts": r["counts"], "n": len(req.out),
                    "shared_tokens": req.shared_tokens,
                    "error": req.error, "shed": False,
                    "cls": rid_cls.get(req.rid, "standard"),
                    "deadline_blown": req.deadline_blown,
                    "preempted": req.preempt_count})
    return out + shed


def replay_threaded(engine, items: List[Dict], *, aux_registry=None,
                    speed: float = 1.0, timeout_s: float = 600.0
                    ) -> List[Dict]:
    """Replay a trace against a SELF-RUNNING engine — a started
    ``DisaggRouter`` (or anything exposing ``submit`` plus ``token_cb`` /
    ``finish_cb`` hooks that steps itself on its own threads). The in-proc
    analogue of ``replay_http`` for engines that own their threads; records
    match ``replay_inproc``'s shape. Existing hooks are chained, not
    clobbered, and restored on exit."""
    aux_registry = aux_registry or {}
    lock = threading.Lock()
    recs: Dict[int, Dict] = {}
    finished: Dict[int, object] = {}
    done = threading.Event()
    expect = {"n": None}

    def rec(rid: int) -> Dict:
        with lock:
            return recs.setdefault(rid, {"times": [], "counts": []})

    prev_tok, prev_fin = engine.token_cb, engine.finish_cb

    def on_tokens(req, toks):
        r = rec(req.rid)
        r["times"].append(time.time())
        r["counts"].append(len(toks))
        if prev_tok is not None:
            prev_tok(req, toks)

    def on_finish(req):
        with lock:
            finished[req.rid] = req
            n = expect["n"]
        if n is not None and len(finished) >= n:
            done.set()
        if prev_fin is not None:
            prev_fin(req)

    engine.token_cb = on_tokens
    engine.finish_cb = on_finish
    t0 = time.time()
    shed: List[Dict] = []
    rid_cls: Dict[int, str] = {}
    submitted: List[int] = []
    from repro.launch.serve import AdmissionError
    try:
        for it in items:
            dt = t0 + it["t"] / speed - time.time()
            if dt > 0:
                time.sleep(dt)
            aux = aux_registry.get(it["aux"]) if it.get("aux") else None
            slo_kw = {}
            if it.get("ttft_slo_ms") is not None:
                slo_kw["ttft_slo_s"] = it["ttft_slo_ms"] / 1e3
            if it.get("tpot_slo_ms") is not None:
                slo_kw["tpot_slo_s"] = it["tpot_slo_ms"] / 1e3
            try:
                rid = engine.submit(np.asarray(it["prompt"], np.int32),
                                    it["max_new"], aux_inputs=aux,
                                    priority=it.get("priority", "standard"),
                                    **slo_kw)
            except AdmissionError as e:
                shed.append({"submit": time.time(), "times": [],
                             "counts": [], "n": 0, "shared_tokens": 0,
                             "error": None, "shed": True,
                             "retry_after": e.retry_after,
                             "cls": it.get("cls", "standard")})
                continue
            rid_cls[rid] = it.get("cls", "standard")
            submitted.append(rid)
        with lock:
            expect["n"] = len(submitted)
            all_done = len(finished) >= expect["n"]
        if all_done:
            done.set()
        done.wait(timeout_s)
    finally:
        engine.token_cb = prev_tok
        engine.finish_cb = prev_fin
    out = []
    for rid in submitted:
        req = finished.get(rid)
        r = rec(rid)
        if req is None:
            out.append({"submit": t0, "times": r["times"],
                        "counts": r["counts"], "n": sum(r["counts"]),
                        "shared_tokens": 0, "shed": False,
                        "cls": rid_cls.get(rid, "standard"),
                        "deadline_blown": False, "preempted": 0,
                        "error": f"replay timeout: rid {rid} never "
                                 f"finished within {timeout_s}s"})
            continue
        out.append({"submit": req.submit_t, "times": r["times"],
                    "counts": r["counts"], "n": len(req.out),
                    "shared_tokens": req.shared_tokens,
                    "error": req.error, "shed": False,
                    "cls": rid_cls.get(rid, "standard"),
                    "deadline_blown": req.deadline_blown,
                    "preempted": req.preempt_count})
    return out + shed


async def replay_http(host: str, port: int, items: List[Dict], *,
                      speed: float = 1.0) -> List[Dict]:
    """Replay a trace against a running ``InferenceServer`` over HTTP/SSE;
    timestamps are CLIENT-observed (connection + parse included)."""
    from repro.launch.server import stream_generate

    async def one(it):
        await asyncio.sleep(it["t"] / speed)
        r = await stream_generate(host, port, it["prompt"], it["max_new"],
                                  aux=it.get("aux"),
                                  priority=it.get("priority"),
                                  ttft_slo_ms=it.get("ttft_slo_ms"),
                                  tpot_slo_ms=it.get("tpot_slo_ms"))
        if r["status"] in (429, 503):     # shed: reported, never dropped
            return {"submit": r["submit_t"], "times": [], "counts": [],
                    "n": 0, "error": None, "shed": True,
                    "retry_after": r["retry_after"],
                    "cls": it.get("cls", "standard")}
        ok = (r["status"] == 200 and r["final"] is not None
              and "error" not in r["final"])
        return {"submit": r["submit_t"], "times": r["token_times"],
                "counts": r["token_counts"], "n": len(r["ids"]),
                "shed": False, "cls": it.get("cls", "standard"),
                "deadline_blown": bool((r["final"] or {}).get(
                    "deadline_blown")),
                "error": None if ok else f"status={r['status']} "
                                         f"final={r['final']}"}

    return list(await asyncio.gather(*[one(it) for it in items]))


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def _pct_ms(xs: List[float], q: float) -> Optional[float]:
    return round(float(np.percentile(xs, q)) * 1e3, 3) if xs else None


def summarize(records: List[Dict], *, offered_rps: Optional[float] = None
              ) -> Dict:
    """p50/p99 TTFT and TPOT plus throughput for one replayed trace.

    TTFT: submit -> first delivered segment. TPOT: (last - first segment
    arrival) / tokens delivered after the first segment — the steady-state
    per-token pace a streaming consumer experiences.

    SHED requests (admission control 429s) never produce tokens, so they
    cannot enter the latency percentiles — but they are counted and
    reported as ``shed`` / ``shed_rate`` so an over-capacity sweep cannot
    quietly report survivor-only TTFTs as if the whole offered load was
    served."""
    sheds = [r for r in records if r.get("shed")]
    ok = [r for r in records if not r.get("shed") and not r.get("error")
          and r["times"]]
    ttft = [r["times"][0] - r["submit"] for r in ok]
    tpot = [(r["times"][-1] - r["times"][0]) / (r["n"] - r["counts"][0])
            for r in ok if r["n"] > r["counts"][0]]
    toks = sum(r["n"] for r in ok)
    span = (max(r["times"][-1] for r in ok) - min(r["submit"] for r in ok)
            if ok else 0.0)
    return {
        "n": len(records),
        "completed": len(ok),
        "shed": len(sheds),
        "shed_rate": round(len(sheds) / len(records), 4) if records else None,
        "errors": len(records) - len(ok) - len(sheds),
        "offered_rps": None if offered_rps is None else round(offered_rps, 3),
        "p50_ttft_ms": _pct_ms(ttft, 50),
        "p99_ttft_ms": _pct_ms(ttft, 99),
        "p50_tpot_ms": _pct_ms(tpot, 50),
        "p99_tpot_ms": _pct_ms(tpot, 99),
        "tok_s": round(toks / span, 2) if span > 0 else None,
        "makespan_s": round(span, 3),
    }


def slo_summary(records: List[Dict], classes: List[Dict]) -> Dict:
    """Per-priority-class SLO attainment and goodput for one replayed trace.

    For each class: shed rate, TTFT percentiles over served requests, the
    fraction of NON-shed requests whose TTFT met the class SLO
    (``slo_attainment`` — shed requests are excluded from attainment but
    reported beside it), and goodput (SLO-meeting completions per second
    over the trace makespan)."""
    span_all = [r for r in records if r.get("times")]
    span = (max(r["times"][-1] for r in span_all)
            - min(r["submit"] for r in records)) if span_all else 0.0
    out = {}
    for cls in classes:
        name, slo = cls["name"], cls.get("ttft_slo_ms")
        rs = [r for r in records if r.get("cls") == name]
        sheds = [r for r in rs if r.get("shed")]
        served = [r for r in rs if not r.get("shed") and r["times"]]
        ttft = [r["times"][0] - r["submit"] for r in served]
        met = (ttft if slo is None
               else [t for t in ttft if t * 1e3 <= slo])
        out[name] = {
            "n": len(rs),
            "shed": len(sheds),
            "shed_rate": round(len(sheds) / len(rs), 4) if rs else None,
            "served": len(served),
            "deadline_blown": sum(bool(r.get("deadline_blown"))
                                  for r in served),
            "preempted": sum(int(r.get("preempted") or 0) for r in served),
            "p50_ttft_ms": _pct_ms(ttft, 50),
            "p99_ttft_ms": _pct_ms(ttft, 99),
            "ttft_slo_ms": slo,
            "slo_attainment": (round(len(met) / len(served), 4)
                               if served else None),
            "goodput_rps": round(len(met) / span, 3) if span > 0 else None,
        }
    return out


def find_knee(points: List[Dict], factor: float = 3.0) -> Dict:
    """Saturation knee over one arrival mode's sweep: the highest offered
    load whose p99 TTFT stays within ``factor``x the lightest-load p99.
    ``points``: summaries with ``offered_rps`` and ``p99_ttft_ms`` set."""
    pts = sorted((p for p in points if p["p99_ttft_ms"] is not None),
                 key=lambda p: p["offered_rps"])
    if not pts:
        return {"knee_rps": None, "saturated": None}
    budget = factor * pts[0]["p99_ttft_ms"]
    within = [p for p in pts if p["p99_ttft_ms"] <= budget]
    return {
        "knee_rps": within[-1]["offered_rps"] if within else None,
        "saturated": pts[-1]["p99_ttft_ms"] > budget,
        "p99_budget_ms": round(budget, 3),
    }
