"""Shared benchmark machinery.

Every benchmark mirrors one paper table on CPU-feasible synthetic data
(offline container). Model sizes are reduced; the COMPARISONS (DB vs e2e vs
other block-wise baselines, partitioning ablations, block-count sweeps) are
the paper's, and the expected ordering of results is asserted against the
paper's claims in EXPERIMENTS.md.

Metric stand-ins (documented in EXPERIMENTS.md):
  FID        -> Gaussian-mixture fidelity: mean distance to nearest mode +
                mode-coverage entropy (repro.data.MixtureImagesContinuous)
  MAUVE      -> legal-transition rate of generated text under the true
                Markov chain
  PPL(teacher)-> negative log2-likelihood of generated text under the true
                chain (the generating process IS the perfect teacher)
  BPC        -> Monte-Carlo NELBO in bits/char (exact MDM metric)
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db, train_e2e
from repro.data import MarkovLM

TINY_LM = ModelConfig(name="bench-lm", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=32)


def lm_data_iter(lm: MarkovLM, batch: int, seq: int, seed: int):
    rng = np.random.RandomState(seed)
    while True:
        yield jnp.asarray(lm.sample(rng, batch, seq))


def train_lm_db(db: DBConfig, steps: int, lm: MarkovLM, seed: int = 0,
                cfg: ModelConfig = TINY_LM, lr: float = 2e-3):
    dbm = DiffusionBlocksModel(cfg, db)
    tcfg = TrainConfig(steps=steps, lr=lr, warmup_steps=steps // 10,
                       log_every=0)
    params, hist = train_db(dbm, tcfg, lm_data_iter(lm, 16, 32, seed),
                            jax.random.PRNGKey(seed), log=lambda *_: None)
    return dbm, params, hist


def train_lm_e2e(steps: int, lm: MarkovLM, seed: int = 0,
                 cfg: ModelConfig = TINY_LM, lr: float = 2e-3):
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1))
    tcfg = TrainConfig(steps=steps, lr=lr, warmup_steps=steps // 10,
                       log_every=0)
    params, hist = train_e2e(dbm, tcfg, lm_data_iter(lm, 16, 32, seed),
                             jax.random.PRNGKey(seed), log=lambda *_: None)
    return dbm, params, hist


def generation_metrics(dbm, params, lm: MarkovLM, n_prompts: int = 4,
                       prompt_len: int = 8, max_new: int = 24,
                       steps_per_block: int = 2) -> Dict:
    from repro.launch.serve import generate
    prompts = jnp.asarray(lm.sample(np.random.RandomState(123), n_prompts,
                                    prompt_len))
    out = np.array(generate(dbm, params, prompts, max_new,
                            steps_per_block=steps_per_block))
    gen = out[:, prompt_len - 1:]
    return {
        "mauve_proxy": lm.transition_accuracy(gen),
        "teacher_nll": -lm.log_likelihood(gen),
    }


def e2e_generation_metrics(dbm, params, lm: MarkovLM, n_prompts: int = 4,
                           prompt_len: int = 8, max_new: int = 24) -> Dict:
    """Standard AR sampling for the e2e baseline (greedy via full forward)."""
    prompts = jnp.asarray(lm.sample(np.random.RandomState(123), n_prompts,
                                    prompt_len))
    toks = prompts
    for _ in range(max_new):
        S = toks.shape[1]
        ctx = dbm.make_ctx(params, S, "train")
        logits, _, _ = dbm.model.forward(params, toks, ctx)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
    gen = np.array(toks[:, prompt_len - 1:])
    return {
        "mauve_proxy": lm.transition_accuracy(gen),
        "teacher_nll": -lm.log_likelihood(gen),
    }


def timeit(fn: Callable, n: int = 5) -> float:
    fn()  # warm up / compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def emit(rows: List[Dict], table: str, out: List[str]):
    for r in rows:
        name = r.pop("name")
        for k, v in r.items():
            out.append(f"{table},{name},{k},{v}")
