"""Paper Table 12 + App. G: wall-time and memory accounting.

Measures per-iteration wall time of (a) the e2e train step and (b) one
DB block step; the paper's claim is per-block ≈ e2e/B, aggregated ≈ e2e.
Also reports EXACT gradient+optimizer state bytes (from the pytrees) for
e2e vs one block — the B× memory reduction, measured rather than asserted."""
from __future__ import annotations

import jax

from benchmarks import common as CM
from repro.configs import DBConfig
from repro.configs.base import TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import (extract_block_view, make_db_train_step,
                                 make_e2e_train_step)
from repro.data import MarkovLM


def run(quick: bool = True):
    B = 3
    lm = MarkovLM(vocab_size=32, seed=2)
    data = CM.lm_data_iter(lm, 16, 64, 0)
    tokens = next(data)
    dbm = DiffusionBlocksModel(CM.TINY_LM, DBConfig(num_blocks=B,
                                                    overlap_gamma=0.05))
    params = dbm.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(steps=100, lr=1e-3)
    rng = jax.random.PRNGKey(1)

    init_e, step_e = make_e2e_train_step(dbm, tcfg)
    opt_e = init_e(params)
    t_e2e = CM.timeit(lambda: jax.block_until_ready(
        step_e(params, opt_e, tokens, rng, None)[2]), n=5)
    grads_bytes_e2e = CM.tree_bytes(params)            # grads shaped like params
    opt_bytes_e2e = CM.tree_bytes(opt_e.mu) * 2

    init_b, step_b = make_db_train_step(dbm, 0, tcfg)
    opt_b = init_b(params)
    t_blk = CM.timeit(lambda: jax.block_until_ready(
        step_b(params, opt_b, tokens, rng, None)[2]), n=5)
    start, size = dbm.ranges[0]
    view = extract_block_view(params, start, size)
    grads_bytes_blk = CM.tree_bytes(view)
    opt_bytes_blk = CM.tree_bytes(opt_b.mu) * 2

    return [
        {"name": "e2e", "step_seconds": t_e2e,
         "grad_bytes": grads_bytes_e2e, "opt_bytes": opt_bytes_e2e},
        {"name": "db-per-block", "step_seconds": t_blk,
         "grad_bytes": grads_bytes_blk, "opt_bytes": opt_bytes_blk},
        {"name": "db-aggregated", "step_seconds": t_blk * B,
         "grad_bytes": grads_bytes_blk, "opt_bytes": opt_bytes_blk},
        {"name": "memory-reduction-factor",
         "grad_plus_opt": (grads_bytes_e2e + opt_bytes_e2e)
         / (grads_bytes_blk + opt_bytes_blk)},
    ]
