"""Paper Table 3: masked diffusion LM (MD4-style) on synthetic text —
MDM (e2e) vs +DiffusionBlocks (masking-schedule partitioning, App. D).
Metric: Monte-Carlo NELBO in bits/char."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.masked import MaskedDiffusionBlocks
from repro.data import MarkovLM
from repro.optim import adamw, apply_updates

CFG = ModelConfig(name="mdm-bench", family="dense", n_layers=6, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=33,
                  norm="layernorm", mlp="gelu")


def run(quick: bool = True):
    steps = 350 if quick else 1000
    lm = MarkovLM(vocab_size=32, branching=2, seed=4)
    it_rng = np.random.RandomState(1)

    def batch():
        return jnp.asarray(lm.sample(it_rng, 16, 32))

    test = jnp.asarray(lm.sample(np.random.RandomState(77), 16, 32))
    rows = []
    for name, B, blockwise in [("MDM", 1, False),
                               ("MDM+DiffusionBlocks", 3, True)]:
        db = DBConfig(num_blocks=B, overlap_gamma=0.0)
        mdm = MaskedDiffusionBlocks(CFG, db)
        params = mdm.init(jax.random.PRNGKey(0))
        init, update = adamw(2e-3)
        st = init(params)
        rng = jax.random.PRNGKey(1)
        grad_fns = [jax.jit(jax.value_and_grad(
            lambda p, t, r, b=b: mdm.block_loss(p, b, t, r)[0]))
            for b in range(B)]
        e2e_fn = jax.jit(jax.value_and_grad(
            lambda p, t, r: mdm.e2e_loss(p, t, r)[0]))
        brng = np.random.RandomState(0)
        for i in range(steps):
            rng, r = jax.random.split(rng)
            if blockwise:
                _, g = grad_fns[brng.randint(0, B)](params, batch(), r)
            else:
                _, g = e2e_fn(params, batch(), r)
            upd, st, _ = update(g, st, params)
            params = apply_updates(params, upd)
        bpc = float(mdm.nelbo_bpc(params, test, jax.random.PRNGKey(5),
                                  n_samples=8, blockwise=blockwise))
        gen = mdm.generate(params, jax.random.PRNGKey(6), 8, 32)
        rows.append({"name": name, "bpc": bpc,
                     "gen_legal_rate": lm.transition_accuracy(np.array(gen)),
                     "layers_with_grads": CFG.n_layers // B,
                     "entropy_floor_bpc": -lm.log_likelihood(
                         np.array(test))})
    return rows
