"""Table 16 (beyond-paper): chunked-prefill benchmark — prompt ingest in
chunks of C tokens vs the per-token prompt scan, plus shared-prefix page
cache savings.

Measured on the current backend (dense family, ragged batch):

  prefill steps   serial attention steps (scan iterations) per prompt. The
                  per-token scan pays one per prompt token; the chunked
                  engine pays ceil(S / C) — the dispatch-depth reduction
                  that dominates time-to-first-token. Backend-independent.
  prefill tok/s   end-to-end prefill walltime after warmup (whole ragged
                  batch / walltime). On CPU the win is the removed serial
                  step overhead; the intra-chunk attention is the same math
                  vectorized.
  TTFT            continuous serving: mean time from submit to first
                  generated token over a queued ragged workload, chunked
                  vs per-token scheduling (same decode segments).
  prefix cache    two requests sharing a long system prompt: the second
                  request's prefill steps cover only its non-shared suffix;
                  shared tokens and copy-on-write page copies are recorded.

Greedy parity is asserted: chunked prefill followed by the fused decode scan
must produce the SAME tokens as the per-token prefill scan.

CPU caveat (as for tables 14/15): ``--impl kernels`` runs the Pallas
flash-prefill kernel in INTERPRET mode on CPU — per-page emulation dominates
walltime there, so the default is the jnp attend path; the compiled-kernel
walltime comparison is TPU-only. Step counts and prefix-cache savings are
backend-independent measurements.

Writes ``BENCH_prefill.json`` at the repo root. ``--quick`` shrinks shapes
for the CI smoke lane (and fails loudly on parity regressions).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, get_engine
from repro.nn import cache as KVC

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _time_prefill(eng, dbm, params, prompts, plens, page_size, reps):
    B, S0 = prompts.shape
    pps = KVC.pages_for(S0 + 1, page_size)
    table = KVC.identity_page_table(B, pps)

    def once():
        kv = dbm.model.init_paged_cache(B, 1 + B * pps, page_size, eng.pol)
        s0 = eng.prefill_steps
        t0 = time.time()
        kv, lengths = eng.run_prefill(params, kv, table,
                                      jnp.zeros((B,), jnp.int32),
                                      prompts, plens)
        jax.block_until_ready((kv, lengths))
        return time.time() - t0, eng.prefill_steps - s0

    once()                                    # warm the compiled program
    times, steps = zip(*(once() for _ in range(reps)))
    return float(np.median(times)), int(steps[0])


def run(quick: bool = True, out: str = None, impl: str = "auto"):
    if quick:
        layers, d_model, B, S, chunk, max_new, reps = 6, 64, 4, 64, 16, 6, 2
        cont_prompt, cont_reqs, slots, seg = 32, 6, 2, 8
    else:
        layers, d_model, B, S, chunk, max_new, reps = 6, 64, 4, 512, 128, 8, 3
        cont_prompt, cont_reqs, slots, seg = 256, 6, 2, 8
    page_size = 16
    cfg = ModelConfig(name="bench-prefill", family="dense", n_layers=layers,
                      d_model=d_model, n_heads=4, n_kv_heads=2,
                      d_ff=2 * d_model, vocab_size=256)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=3,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(1)
    prompts = jnp.asarray(rs.randint(0, cfg.vocab_size, size=(B, S)))
    plens_np = rs.randint(max(2, S // 2), S + 1, size=B)      # ragged
    plens = jnp.asarray(plens_np, jnp.int32)
    n_prompt_tok = int(plens_np.sum())
    print(f"backend={jax.default_backend()} impl={impl} B={B} S={S} "
          f"C={chunk} prompts={[int(p) for p in plens_np]}")

    kw = dict(steps_per_block=1, temperature=0.0, top_k=0, precision="bf16",
              impl=impl)
    eng_tok = get_engine(dbm, prefill="per-token", **kw)
    eng_chk = get_engine(dbm, prefill="chunked", chunk_size=chunk, **kw)

    rows = {}
    for name, eng in (("per_token", eng_tok), ("chunked", eng_chk)):
        dt, steps = _time_prefill(eng, dbm, params, prompts, plens,
                                  page_size, reps)
        rows[name] = {"walltime_s": dt, "prefill_tok_s": n_prompt_tok / dt,
                      "serial_steps": steps,
                      "steps_per_prompt": steps}   # steps are batch-shared
        print(f"  {name:10s} {rows[name]['prefill_tok_s']:9.1f} prefill "
              f"tok/s | {steps:4d} serial steps for S={S}")
    step_ratio = rows["per_token"]["serial_steps"] / \
        rows["chunked"]["serial_steps"]
    walltime_ratio = rows["per_token"]["walltime_s"] / \
        rows["chunked"]["walltime_s"]
    print(f"  serial prefill steps: {step_ratio:.1f}x fewer "
          f"(ceil(S/C) vs S) | walltime {walltime_ratio:.2f}x")
    assert step_ratio >= 10, "chunked prefill must cut steps >= 10x"

    # ---- greedy parity: chunked prefill + fused decode == per-token -------
    o_tok = eng_tok.generate(params, prompts, max_new,
                             jax.random.PRNGKey(7), prompt_lengths=plens_np,
                             page_size=page_size)
    o_chk = eng_chk.generate(params, prompts, max_new,
                             jax.random.PRNGKey(7), prompt_lengths=plens_np,
                             page_size=page_size)
    parity = bool(np.array_equal(np.asarray(o_tok), np.asarray(o_chk)))
    print(f"  greedy chunked == per-token prefill: {parity}")
    assert parity, "chunked prefill diverged from the per-token scan"

    # ---- TTFT under continuous load ---------------------------------------
    # ONE fixed request list: both scheduling modes (and their warmups)
    # serve identical prompts, so the TTFT ratio compares scheduling only
    cont_workload = [
        rs.randint(0, cfg.vocab_size,
                   size=int(rs.randint(max(2, cont_prompt // 2),
                                       cont_prompt + 1)))
        for _ in range(cont_reqs)]

    def serve_queue(prefill):
        cb = ContinuousBatcher(
            dbm, params, num_slots=slots, page_size=page_size,
            max_prompt=cont_prompt, max_len=cont_prompt + max_new,
            seg_len=seg, prefill=prefill, chunk_size=chunk,
            precision="bf16", impl=impl)
        for prompt in cont_workload:
            cb.submit(prompt, max_new)
        steps0 = cb.eng.prefill_steps     # engine is memoized across runs
        done = cb.run(jax.random.PRNGKey(11))
        ttfts = [r.ttft for r in done if r.ttft is not None]
        return {"mean_ttft_s": float(np.mean(ttfts)),
                "max_ttft_s": float(np.max(ttfts)),
                "prefill_steps": cb.eng.prefill_steps - steps0,
                "tokens": sum(len(r.out) for r in done)}

    for p in ("chunked", "per-token"):       # warm BOTH modes' programs
        serve_queue(p)
    cont = {p: serve_queue(p) for p in ("chunked", "per-token")}
    for p, r in cont.items():
        print(f"  continuous {p:10s} mean TTFT {r['mean_ttft_s']*1e3:7.1f}ms"
              f"  (max {r['max_ttft_s']*1e3:.1f}ms)")

    # ---- shared-prefix page cache -----------------------------------------
    # the prompt length is deliberately NOT page-aligned and the shared
    # system prompt extends INTO the final PARTIAL page: the second request
    # maps that boundary page read-only and copy-on-writes it, so the CoW
    # path is measured too
    sfx = page_size // 2 - 2
    prompt_total = cont_prompt - 3          # 253 % 16 != 0
    sys_len = prompt_total - sfx
    sys_prompt = rs.randint(0, cfg.vocab_size, size=sys_len)
    cb = ContinuousBatcher(
        dbm, params, num_slots=slots, page_size=page_size,
        max_prompt=cont_prompt, max_len=cont_prompt + max_new, seg_len=seg,
        prefill="chunked", chunk_size=chunk, prefix_cache=True,
        precision="bf16", impl=impl)
    cb.submit(np.concatenate([sys_prompt,
                              rs.randint(0, cfg.vocab_size, size=sfx)]),
              max_new)
    cb.run(jax.random.PRNGKey(12))
    steps_first = cb.eng.prefill_steps
    cb.submit(np.concatenate([sys_prompt,
                              rs.randint(0, cfg.vocab_size, size=sfx)]),
              max_new)
    done2 = cb.run(jax.random.PRNGKey(13))
    second = done2[0]
    steps_second = cb.eng.prefill_steps - steps_first
    prefix = {
        "prompt_tokens": prompt_total,
        "system_prefix_tokens": sys_len,
        "second_request_shared_tokens": int(second.shared_tokens),
        "second_request_prefill_steps": int(steps_second),
        "full_prefill_steps": -(-prompt_total // chunk),
        "cow_copies": int(cb.cow_copies),
        "cache_hits": int(cb.prefix.hits),
    }
    print(f"  prefix cache: 2nd request shared "
          f"{prefix['second_request_shared_tokens']}/{prompt_total} prompt "
          f"tokens, prefilled its suffix in {steps_second} step(s) vs "
          f"{prefix['full_prefill_steps']} cold "
          f"({prefix['cow_copies']} CoW copies)")
    assert second.shared_tokens > 0, "second request must hit the cache"
    assert steps_second < prefix["full_prefill_steps"], \
        "shared prefix must shrink the second request's prefill"

    report = {
        "table": "table16_prefill",
        "backend": jax.default_backend(),
        "pallas_mode": ("interpret" if _interpret() else "mosaic")
        if impl in ("kernels", "pallas") else "jnp (impl=auto)",
        "quick": bool(quick),
        "config": {"layers": layers, "d_model": d_model, "batch": B,
                   "prompt_max": S, "chunk_size": chunk,
                   "prompt_lengths": [int(p) for p in plens_np],
                   "max_new": max_new, "page_size": page_size, "impl": impl},
        "per_token": rows["per_token"],
        "chunked": rows["chunked"],
        "step_speedup": step_ratio,
        "walltime_speedup": walltime_ratio,
        "greedy_identical": parity,
        "continuous_ttft": cont,
        "prefix_cache": prefix,
        "walltime_note": (
            "CPU walltime: impl=auto runs the jnp paged attend (the Pallas "
            "flash-prefill kernel in interpret mode is per-page emulation — "
            "compiled-kernel walltime comparison is TPU-only, as for tables "
            "14/15); the structural win measured here is the serial-step "
            "reduction (ceil(S/C) vs S attention steps before the first "
            "token)."),
    }
    out = out or os.path.join(ROOT, "BENCH_prefill.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"prefill step speedup {step_ratio:.1f}x | walltime "
          f"{walltime_ratio:.2f}x | prefix cache saved "
          f"{prefix['second_request_shared_tokens']} of {prompt_total} "
          f"prompt tokens on the hit")
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    return [
        {"name": "per_token", **r["per_token"]},
        {"name": "chunked", **r["chunked"]},
        {"name": "continuous_chunked", **r["continuous_ttft"]["chunked"]},
        {"name": "continuous_per_token",
         **r["continuous_ttft"]["per-token"]},
        {"name": "prefix_cache", **r["prefix_cache"]},
        {"name": "summary", "step_speedup": r["step_speedup"],
         "walltime_speedup": r["walltime_speedup"],
         "greedy_identical": int(r["greedy_identical"])},
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--impl", default="auto",
                    help="prefill attend impl: auto | kernels")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_prefill.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, impl=args.impl)


if __name__ == "__main__":
    main()
