"""Table 22 (beyond-paper): quantized KV serving — int8 pages with per-page
scales fused into the decode path.

Four measured sections, one report (``BENCH_quantkv.json``):

  pool bytes   bf16 vs int8 pool at EQUAL page count, counted with the
               mixed-dtype-aware ``KVC.cache_bytes`` (int8 pages + fp32
               per-page scales). Gate: >= 1.8x reduction.
  roofline     bytes-bound decode speedup. PREDICTED from the roofline
               memory term of the two COMPILED decode programs (HLO
               bytes-accessed / HBM_BW, the same methodology as
               ``repro.roofline``); the naive KV-stream ratio (pool bytes
               only) is recorded beside it. MEASURED as the walltime decode
               throughput ratio at a memory-dominated operating point (a
               large fully-mapped pool, tiny model). Gate: measured >= 0.8x
               predicted — the quantized program must deliver at least 80%
               of its bytes-bound headroom. Exceeding the prediction is NOT
               a failure: on CPU the int8 path also removes the bf16->f32
               conversion cost that the byte model charges equally to both
               sides (see ``notes`` in the report).
  capacity     pages affordable under one fixed BYTE budget, bf16 vs int8
               (measured from allocated-pool byte counts, scales included),
               then a loadgen burst curve on real batchers built at those
               page counts: peak concurrent in-flight requests (admission
               reserves a request's full page span, so this is the
               scheduler-visible capacity) and p99 TTFT vs burst size.
               Gate: >= 1.8x pages AND >= 1.8x measured peak in-flight.
  divergence   output-divergence bound vs bf16 for ALL FOUR cache-state
               families (dense, vlm, hybrid, audio): per-step greedy top-1
               agreement under TEACHER FORCING (both runs see identical
               prefixes and per-step noise, so each step isolates the KV
               dequantization error instead of compounding a single early
               flip), max/mean logit delta, and the free-running greedy
               prefix-match length. Gate: top-1 agreement >= 99%.

CPU caveat (as for table14/15): walltimes here run the jnp paged attend
(``impl=auto``); the Pallas kernels in interpret mode are per-page emulation
and their walltime is TPU-only territory. Byte counts, page capacity and
divergence are backend-independent measurements.

Writes ``BENCH_quantkv.json`` at the repo root. ``--quick`` shrinks shapes
for the CI smoke lane (and fails loudly on any gate regression).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import precision as precision_mod
from repro.configs import DBConfig
from repro.configs.base import ModelConfig, SSMConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, get_engine
from repro.nn import cache as KVC
from repro.roofline import hw

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCH = ModelConfig(name="bench-quantkv", family="dense", n_layers=6,
                    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                    vocab_size=256)

# the four cache-state families (mirrors tests/test_disagg.py)
FAMILY_CFGS = {
    "dense": ModelConfig(name="qkv-dense", family="dense", n_layers=4,
                         d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                         vocab_size=32),
    "vlm": ModelConfig(name="qkv-vlm", family="vlm", n_layers=4,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab_size=32, cross_attn_every=2, n_image_tokens=4),
    "hybrid": ModelConfig(name="qkv-hybrid", family="hybrid", n_layers=4,
                          d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                          vocab_size=32, attn_every=2,
                          ssm=SSMConfig(d_state=8, d_conv=4, expand=2,
                                        head_dim=16, chunk_size=8)),
    "audio": ModelConfig(name="qkv-audio", family="audio", n_layers=2,
                         d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                         vocab_size=32, n_encoder_layers=2, n_audio_frames=6,
                         rope_theta=0.0, norm="layernorm", mlp="gelu",
                         is_encoder_decoder=True),
}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _shape_bytes(dbm, n_pages, page_size, policy) -> int:
    """Pool bytes WITHOUT allocating: cache_bytes over eval_shape structs."""
    tree = jax.eval_shape(
        lambda: dbm.model.init_paged_cache(1, n_pages, page_size, policy))
    return KVC.cache_bytes(tree)


# ---------------------------------------------------------------------------
# Section 1+2: pool bytes and roofline-vs-measured decode speedup
# ---------------------------------------------------------------------------

def _decode_probe(dbm, params, kvd, *, B, seq, page_size, n, reps):
    """Compile + time the fused decode scan on a fully-mapped pool."""
    eng = get_engine(dbm, precision="bf16", kv_dtype=kvd)
    pps = KVC.pages_for(seq, page_size)
    kv = dbm.model.init_paged_cache(B, 1 + B * pps, page_size, eng.pol)
    table = KVC.identity_page_table(B, pps)
    # timing-only state: every page mapped, decode appends at the tail
    lengths = jnp.full((B,), seq - n - 1, jnp.int32)
    stop_at = jnp.full((B,), seq, jnp.int32)
    clens = jnp.zeros((B,), jnp.int32)
    rng = jax.random.PRNGKey(1)
    args = (params, kv, table, lengths, stop_at, rng, clens)
    ca = eng._decode.lower(*args, n=n).compile().cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    jax.block_until_ready(eng._decode(*args, n=n))      # warm
    return {
        "pool_bytes": int(KVC.cache_bytes(kv)),
        "pool_bytes_by_dtype": KVC.cache_bytes_by_dtype(kv),
        "hlo_bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "memory_s": float(ca.get("bytes accessed", 0.0)) / hw.HBM_BW,
        "_time": lambda: jax.block_until_ready(eng._decode(*args, n=n)),
    }


def bytes_and_roofline(dbm, params, *, B, seq, page_size, n, reps):
    probes = {}
    for kvd in (None, "int8"):
        probes["bf16" if kvd is None else "int8"] = _decode_probe(
            dbm, params, kvd, B=B, seq=seq, page_size=page_size, n=n,
            reps=reps)
    # interleave the timed reps (CPU frequency drift, as table15)
    times = {k: [] for k in probes}
    for _ in range(reps):
        for k, p in probes.items():
            t0 = time.time()
            p["_time"]()
            times[k].append(time.time() - t0)
    rows = {}
    for k, p in probes.items():
        dt = float(np.median(times[k]))
        rows[k] = {kk: v for kk, v in p.items() if not kk.startswith("_")}
        rows[k]["walltime_s"] = dt
        rows[k]["tok_s"] = B * n / dt

    bytes_ratio = rows["bf16"]["pool_bytes"] / rows["int8"]["pool_bytes"]
    predicted = rows["bf16"]["memory_s"] / rows["int8"]["memory_s"]
    measured = rows["int8"]["tok_s"] / rows["bf16"]["tok_s"]
    out = {
        "bf16": rows["bf16"], "int8": rows["int8"],
        "pool_bytes_ratio": bytes_ratio,
        "kv_stream_predicted_speedup": bytes_ratio,
        "roofline_predicted_speedup": predicted,
        "measured_speedup": measured,
        "measured_over_predicted": measured / predicted,
        "within_20pct": bool(abs(measured / predicted - 1.0) <= 0.2),
        "hbm_bw": hw.HBM_BW,
    }
    print(f"  pool bytes      bf16 {rows['bf16']['pool_bytes']/1e6:.2f}MB vs "
          f"int8 {rows['int8']['pool_bytes']/1e6:.2f}MB "
          f"({bytes_ratio:.2f}x smaller)")
    print(f"  decode speedup  predicted {predicted:.2f}x (roofline, compiled "
          f"HLO bytes) / {bytes_ratio:.2f}x (KV stream only)  measured "
          f"{measured:.2f}x ({rows['bf16']['tok_s']:.1f} -> "
          f"{rows['int8']['tok_s']:.1f} tok/s)")
    assert bytes_ratio >= 1.8, \
        f"int8 pool only {bytes_ratio:.2f}x smaller than bf16 (< 1.8x)"
    assert measured >= 0.8 * predicted, \
        (f"measured decode speedup {measured:.2f}x delivers < 80% of the "
         f"roofline bytes-bound prediction {predicted:.2f}x")
    return out


# ---------------------------------------------------------------------------
# Section 3: capacity at a fixed byte budget (loadgen burst curve)
# ---------------------------------------------------------------------------

def _burst_point(cb, rs, k, *, vocab, s0, max_new, seed):
    """Replay a t=0 burst of k requests in-process; returns the loadgen
    summary plus the measured peak concurrent in-flight slot count."""
    try:                              # package import (benchmarks.run)
        from benchmarks import loadgen
    except ImportError:               # script mode: python benchmarks/...
        import loadgen
    items = [{"t": 0.0,
              "prompt": rs.randint(0, vocab, size=s0),
              "max_new": max_new, "aux": None, "cls": "standard",
              "priority": "standard", "ttft_slo_ms": None,
              "tpot_slo_ms": None} for _ in range(k)]
    peak = {"v": 0}
    orig_step = cb.step

    def step(rng, **kw):
        peak["v"] = max(peak["v"], int(cb.active.sum()))
        return orig_step(rng, **kw)

    cb.step = step
    try:
        recs = loadgen.replay_inproc(cb, items,
                                     rng=jax.random.PRNGKey(seed))
    finally:
        cb.step = orig_step
    s = loadgen.summarize(recs)
    return {"burst": k, "peak_inflight": peak["v"],
            "completed": s["completed"], "p99_ttft_ms": s["p99_ttft_ms"],
            "makespan_s": s["makespan_s"]}


def capacity_curve(dbm, params, *, page_size, s0, max_new, budget_pages,
                   seed):
    """Equal BYTE budget -> page counts per dtype -> measured burst curve."""
    pps = KVC.pages_for(s0 + max_new, page_size)
    p_bf16 = 1 + budget_pages * pps
    budget = _shape_bytes(dbm, p_bf16, page_size, "bf16")
    # largest int8 pool that fits the SAME byte budget (scales included)
    per_page = (_shape_bytes(dbm, 3, page_size, "bf16_kvint8")
                - _shape_bytes(dbm, 2, page_size, "bf16_kvint8"))
    p_int8 = 2 + (budget - _shape_bytes(dbm, 2, page_size, "bf16_kvint8")) \
        // per_page
    pools = {"bf16": (None, int(p_bf16)), "int8": ("int8", int(p_int8))}
    out = {"byte_budget": int(budget), "pages_per_request": pps,
           "page_size": page_size}
    rs = np.random.RandomState(seed)
    slots = 2 * ((p_int8 - 1) // pps)
    bursts = sorted({2, (p_bf16 - 1) // pps, (p_int8 - 1) // pps, slots})
    for name, (kvd, pages) in pools.items():
        cb = ContinuousBatcher(
            dbm, params, num_slots=slots, page_size=page_size,
            max_prompt=s0, max_len=s0 + max_new, seg_len=max_new // 2,
            precision="bf16", kv_dtype=kvd, total_pages=pages)
        curve = [_burst_point(cb, rs, k, vocab=dbm.cfg.vocab_size, s0=s0,
                              max_new=max_new, seed=seed + k)
                 for k in bursts]
        out[name] = {
            "total_pages": pages,
            "pool_bytes": int(KVC.cache_bytes(cb.kv)),
            "capacity_pages": (pages - 1) // pps,
            "peak_inflight": max(pt["peak_inflight"] for pt in curve),
            "curve": curve,
        }
        print(f"  {name:5s} budget pool: {pages:3d} pages "
              f"({out[name]['pool_bytes']/1e3:.1f}KB), peak in-flight "
              f"{out[name]['peak_inflight']} of {max(bursts)} offered")
    assert out["int8"]["pool_bytes"] <= budget, \
        "int8 pool overflows the byte budget"
    out["page_capacity_ratio"] = (out["int8"]["capacity_pages"]
                                  / out["bf16"]["capacity_pages"])
    out["inflight_ratio"] = (out["int8"]["peak_inflight"]
                             / max(out["bf16"]["peak_inflight"], 1))
    print(f"  capacity ratio  pages {out['page_capacity_ratio']:.2f}x, "
          f"measured peak in-flight {out['inflight_ratio']:.2f}x")
    assert out["page_capacity_ratio"] >= 1.8, \
        (f"int8 fits only {out['page_capacity_ratio']:.2f}x the requests "
         f"of bf16 at equal bytes (< 1.8x)")
    assert out["inflight_ratio"] >= 1.8, \
        (f"measured peak in-flight ratio {out['inflight_ratio']:.2f}x "
         f"< 1.8x — the scheduler is not realizing the extra pages")
    return out


# ---------------------------------------------------------------------------
# Section 4: output divergence vs bf16, all four families
# ---------------------------------------------------------------------------

def family_divergence(family, *, B, s0, steps, seed, impl="auto"):
    """Teacher-forced per-step logit comparison bf16 vs bf16+int8-KV."""
    cfg = FAMILY_CFGS[family]
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    aux = None
    if family == "vlm":
        params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
            params["units"]["cross"]["xgate"])
        aux = {"image_embs": 4.0 * np.random.RandomState(3).randn(
            B, cfg.n_image_tokens, cfg.d_model).astype(np.float32)}
    elif family == "audio":
        aux = {"audio_embs": 4.0 * np.random.RandomState(3).randn(
            B, cfg.n_audio_frames, cfg.d_model).astype(np.float32)}
    rs = np.random.RandomState(seed)
    prompts = jnp.asarray(rs.randint(0, cfg.vocab_size, size=(B, s0)),
                          jnp.int32)
    page_size = 4
    pps = KVC.pages_for(s0 + steps, page_size)
    table = KVC.identity_page_table(B, pps)

    def run_policy(kvd, forced):
        """forced=None: free-running greedy. forced=(B, steps): commit the
        given tokens instead (teacher forcing). Returns (logits, tokens)."""
        eng = get_engine(dbm, precision="bf16", kv_dtype=kvd, impl=impl)
        kv = dbm.model.init_paged_cache(B, 1 + B * pps, page_size, eng.pol)
        lengths = jnp.zeros((B,), jnp.int32)
        if aux is not None:
            cond = dbm.model.encode_conditioning(params, aux)
            kv = dbm.model.set_conditioning(params, kv, cond)
            clens = jnp.full((B,), cond.shape[1], jnp.int32)
        else:
            clens = jnp.zeros((B,), jnp.int32)
        kv, lengths = eng.run_prefill(params, kv, table, lengths, prompts,
                                      jnp.full((B,), s0, jnp.int32), clens)

        pol = eng.pol

        def logit_fn(params, kv, lengths, rs):
            # mirrors serve_step_paged: same rng split, same denoise chain
            act = jnp.ones_like(lengths, bool)
            ctx = dbm._paged_ctx(params, lengths, table, act, pol, impl,
                                 clens)
            r_noise, _ = jax.random.split(rs)
            d = dbm.denoise_next_token(params, kv, None, r_noise, ctx, 1)
            return dbm.model.logits(params, d)[:, 0].astype(jnp.float32)

        def commit_fn(params, kv, lengths, tok):
            act = jnp.ones_like(lengths, bool)
            ctx = dbm._paged_ctx(params, lengths, table, act, pol, impl,
                                 clens)
            kv = dbm.commit_token(params, kv, None, tok[:, None], ctx)
            return kv, lengths + 1

        logit_j = jax.jit(logit_fn)
        commit_j = jax.jit(commit_fn)
        rng = jax.random.PRNGKey(seed + 7)
        logits, toks = [], []
        for t in range(steps):
            rng, rstep = jax.random.split(rng)
            lg = logit_j(params, kv, lengths, rstep)
            tok = (jnp.argmax(lg, -1) if forced is None
                   else jnp.asarray(forced[:, t]))
            kv, lengths = commit_j(params, kv, lengths, tok)
            logits.append(np.asarray(lg))
            toks.append(np.asarray(jnp.argmax(lg, -1)))
        return np.stack(logits, 1), np.stack(toks, 1)     # (B, steps, V)

    base_logits, base_toks = run_policy(None, None)       # free-running bf16
    tf_logits, tf_toks = run_policy("int8", base_toks)    # teacher-forced
    _, free_toks = run_policy("int8", None)               # free-running int8

    agree = float(np.mean(tf_toks == base_toks))
    delta = np.abs(tf_logits - base_logits)
    mism = np.argmax(np.any(free_toks != base_toks, 0))
    prefix = int(mism if np.any(free_toks != base_toks) else steps)
    row = {
        "positions": int(base_toks.size),
        "top1_agreement": agree,
        "max_logit_delta": float(delta.max()),
        "mean_logit_delta": float(delta.mean()),
        "greedy_prefix_match_steps": prefix,
        "steps": steps,
    }
    print(f"  {family:7s} top-1 agreement {agree:.4f} over "
          f"{row['positions']} positions, max|dlogit| "
          f"{row['max_logit_delta']:.4f}, free-running greedy matches "
          f"{prefix}/{steps} steps")
    return row


# ---------------------------------------------------------------------------

def run(quick: bool = True, out: str = None, impl: str = "auto"):
    if quick:
        B, seq, n, reps = 4, 2048, 4, 3
        div_B, div_s0, div_steps = 4, 8, 12
        budget_pages = 4
    else:
        B, seq, n, reps = 4, 4096, 4, 5
        div_B, div_s0, div_steps = 8, 8, 25
        budget_pages = 4
    page_size = 16
    dbm = DiffusionBlocksModel(BENCH, DBConfig(num_blocks=3,
                                               overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    print(f"backend={jax.default_backend()} impl={impl} quick={quick}")

    print("pool bytes + roofline decode speedup "
          f"(B={B}, {seq} tokens/slot mapped):")
    roof = bytes_and_roofline(dbm, params, B=B, seq=seq,
                              page_size=page_size, n=n, reps=reps)

    print("capacity at a fixed byte budget:")
    cap = capacity_curve(dbm, params, page_size=8, s0=12, max_new=12,
                         budget_pages=budget_pages, seed=5)

    print("output divergence vs bf16 (teacher-forced greedy):")
    div = {}
    for family in FAMILY_CFGS:
        div[family] = family_divergence(family, B=div_B, s0=div_s0,
                                        steps=div_steps, seed=13, impl=impl)
    pooled = (sum(d["top1_agreement"] * d["positions"] for d in div.values())
              / sum(d["positions"] for d in div.values()))
    div["pooled_top1_agreement"] = pooled
    print(f"  pooled top-1 agreement {pooled:.4f}")
    assert pooled >= 0.99, \
        f"pooled greedy top-1 agreement {pooled:.4f} < 0.99 vs bf16"
    if not quick:
        for family in FAMILY_CFGS:
            assert div[family]["top1_agreement"] >= 0.99, \
                (family, div[family])

    report = {
        "table": "table22_quantkv",
        "backend": jax.default_backend(),
        "pallas_mode": ("interpret" if _interpret() else "mosaic")
        if impl in ("kernels", "pallas") else "jnp (impl=auto)",
        "quick": bool(quick),
        "config": {"B": B, "seq": seq, "decode_steps": n, "reps": reps,
                   "page_size": page_size, "impl": impl},
        "roofline": roof,
        "capacity": cap,
        "divergence": div,
        "notes": (
            "Predicted speedup is the roofline memory term of the two "
            "compiled decode programs (HLO bytes accessed / HBM_BW, as in "
            "repro.roofline); the gate is measured >= 0.8x predicted. On "
            "CPU the measured speedup can EXCEED the prediction: int8 "
            "storage also removes bf16->f32 conversion cost that the byte "
            "model charges to both sides. Walltime comparisons for the "
            "Pallas kernels themselves are TPU-only (interpret mode on "
            "CPU); divergence, capacity and byte counts are "
            "backend-independent."),
    }
    out = out or os.path.join(ROOT, "BENCH_quantkv.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"pool {roof['pool_bytes_ratio']:.2f}x smaller | decode "
          f"{roof['measured_speedup']:.2f}x measured vs "
          f"{roof['roofline_predicted_speedup']:.2f}x predicted | capacity "
          f"{cap['page_capacity_ratio']:.2f}x | pooled agreement "
          f"{pooled:.4f}")
    print("wrote", out)
    return report


def run_rows(quick: bool = True):
    """benchmarks.run adapter: flatten the report into emit()-style rows."""
    r = run(quick=quick)
    rows = [
        {"name": "pool_bytes",
         "bf16": r["roofline"]["bf16"]["pool_bytes"],
         "int8": r["roofline"]["int8"]["pool_bytes"],
         "ratio": r["roofline"]["pool_bytes_ratio"]},
        {"name": "decode_speedup",
         "predicted": r["roofline"]["roofline_predicted_speedup"],
         "measured": r["roofline"]["measured_speedup"],
         "within_20pct": int(r["roofline"]["within_20pct"])},
        {"name": "capacity",
         "bf16_pages": r["capacity"]["bf16"]["capacity_pages"],
         "int8_pages": r["capacity"]["int8"]["capacity_pages"],
         "ratio": r["capacity"]["page_capacity_ratio"],
         "inflight_ratio": r["capacity"]["inflight_ratio"]},
    ]
    for family in FAMILY_CFGS:
        d = r["divergence"][family]
        rows.append({"name": f"divergence_{family}",
                     "top1_agreement": d["top1_agreement"],
                     "max_logit_delta": d["max_logit_delta"]})
    rows.append({"name": "divergence_pooled",
                 "top1_agreement": r["divergence"]["pooled_top1_agreement"]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--impl", default="auto",
                    help="decode attend impl: auto | kernels")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_quantkv.json"))
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, impl=args.impl)


if __name__ == "__main__":
    main()
