"""Paper Tables 8/10/11: effect of block count B.

Image-generation variant (Tables 8/10): DiT synthetic, B ∈ {1,2,3,6} —
fidelity vs layers-per-step. LM variant (Table 11): AR synthetic, same Bs —
generation quality. Relative speed = B (exact: L/B layers get gradients)."""
from __future__ import annotations


from benchmarks import common as CM
from benchmarks import table2_dit as T2
from repro.configs import DBConfig
from repro.data import MarkovLM


def run(quick: bool = True):
    steps = 220 if quick else 1000
    rows = []
    for B in (1, 2, 3, 6):
        out = T2.run(quick=quick, db_blocks=max(B, 1), steps=steps)
        row = out[1] if B > 1 else out[0]
        rows.append({"name": f"dit-B={B}",
                     "fid_proxy_dist": row["fid_proxy_dist"],
                     "mode_coverage": row["mode_coverage"],
                     "layers_per_block": 6 // B, "relative_speed": float(B)})
    # Table 11: LM
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)
    for B in (2, 3, 6):
        db = DBConfig(num_blocks=B, overlap_gamma=0.0)
        dbm, p, hist = CM.train_lm_db(db, steps, lm, seed=0)
        m = CM.generation_metrics(dbm, p, lm)
        rows.append({"name": f"lm-B={B}", "mauve_proxy": m["mauve_proxy"],
                     "teacher_nll": m["teacher_nll"],
                     "layers_per_block": 6 // B,
                     "relative_speed": float(B)})
    return rows
