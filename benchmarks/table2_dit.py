"""Paper Table 2: DiT image generation — DiT (e2e, B=1) vs +DiffusionBlocks
(B=3). Metrics: mixture fidelity (FID stand-in) + inference layer-evals
(the paper's 3× inference-cost reduction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.dit import DiTDiffusionBlocks
from repro.data import MixtureImagesContinuous
from repro.optim import adamw, apply_updates

CFG = ModelConfig(name="dit-bench", family="dense", n_layers=6, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=0,
                  norm="layernorm", mlp="gelu", rope_theta=0.0)


def train(dit, steps, data_it, lr=2e-3, seed=0, blockwise=True):
    params = dit.init(jax.random.PRNGKey(seed))
    init, update = adamw(lr)
    st = init(params)
    rng = jax.random.PRNGKey(seed + 1)
    nb = dit.db.num_blocks
    grad_fns = [jax.jit(jax.value_and_grad(
        lambda p, y, r, b=b: dit.block_loss(p, b, y, r)[0]))
        for b in range(nb)]
    e2e_fn = jax.jit(jax.value_and_grad(
        lambda p, y, r: dit.e2e_loss(p, y, r)[0]))
    brng = np.random.RandomState(seed)
    for i in range(steps):
        y = next(data_it)
        rng, r = jax.random.split(rng)
        if blockwise:
            _, grads = grad_fns[brng.randint(0, nb)](params, y, r)
        else:
            _, grads = e2e_fn(params, y, r)
        upd, st, _ = update(grads, st, params)
        params = apply_updates(params, upd)
    return params


def run(quick: bool = True, db_blocks: int = 3, steps=None, seed: int = 0,
        partition: str = "equiprob", distribution=None):
    steps = steps or (250 if quick else 1200)
    mix = MixtureImagesContinuous(n_tokens=8, dim=16, n_modes=4, seed=3)
    it_rng = np.random.RandomState(1)

    def data():
        while True:
            yield jnp.asarray(mix.sample(it_rng, 32)[0])

    rows = []
    for name, B, blockwise in [("DiT", 1, False),
                               ("DiT+DiffusionBlocks", db_blocks, True)]:
        db = DBConfig(num_blocks=B, overlap_gamma=0.05, loss="l2",
                      partition=partition)
        dit = DiTDiffusionBlocks(CFG, db, data_dim=16, n_tokens=8,
                                 distribution=distribution if B > 1 else None)
        params = train(dit, steps, data(), seed=seed, blockwise=blockwise)
        samples, layer_evals = dit.sample(params, jax.random.PRNGKey(9), 256,
                                          num_steps=18, blockwise=blockwise)
        dist, cover = mix.fidelity(np.asarray(samples))
        rows.append({"name": name, "fid_proxy_dist": dist,
                     "mode_coverage": cover,
                     "inference_layer_evals": layer_evals,
                     "layers_with_grads": CFG.n_layers // B})
    return rows
