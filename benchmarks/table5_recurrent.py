"""Paper Table 5: recurrent-depth (Huginn) — K-iteration truncated-BPTT
baseline vs DiffusionBlocks single-pass denoiser training. Metrics: teacher
NLL of teacher-forced predictions + measured train-step wall time (the K×
compute elimination)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core.recurrent import RecurrentDepthModel
from repro.data import MarkovLM
from repro.optim import adamw, apply_updates

CFG = ModelConfig(name="huginn-bench", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                  vocab_size=32)


def _train(model, loss_name, steps, lm, seed=0, lr=2e-3):
    params = model.init(jax.random.PRNGKey(seed))
    init, update = adamw(lr)
    st = init(params)
    loss_fn = getattr(model, loss_name)
    grad = jax.jit(jax.value_and_grad(lambda p, t, r: loss_fn(p, t, r)[0]))
    rng = jax.random.PRNGKey(seed + 1)
    it = np.random.RandomState(1)
    # timed steps (post-compile)
    toks0 = jnp.asarray(lm.sample(it, 8, 32))
    grad(params, toks0, rng)  # compile
    t0 = time.time()
    n_timed = 0
    for i in range(steps):
        toks = jnp.asarray(lm.sample(it, 8, 32))
        rng, r = jax.random.split(rng)
        loss, g = grad(params, toks, r)
        upd, st, _ = update(g, st, params)
        params = apply_updates(params, upd)
        n_timed += 1
    dt = (time.time() - t0) / max(n_timed, 1)
    return params, float(loss), dt


def run(quick: bool = True):
    steps = 120 if quick else 600
    K = 8 if quick else 32
    lm = MarkovLM(vocab_size=32, branching=2, seed=6)
    test = jnp.asarray(lm.sample(np.random.RandomState(88), 8, 32))
    rows = []

    base = RecurrentDepthModel(CFG, DBConfig(num_blocks=1), prelude=1,
                               coda=1, recurrence=K, bptt_k=4)
    p, loss, dt = _train(base, "baseline_loss", steps, lm, seed=0)
    lb, _ = base.baseline_loss(p, test, jax.random.PRNGKey(0))
    rows.append({"name": f"Huginn(K={K},tbptt=4)", "final_ce": float(lb),
                 "step_seconds": dt, "fwd_passes_per_step": K})

    dbm = RecurrentDepthModel(CFG, DBConfig(num_blocks=1), prelude=1,
                              coda=1, recurrence=K, bptt_k=4)
    p, loss, dt = _train(dbm, "db_loss", steps, lm, seed=0)
    logits = dbm.db_generate_logits(p, test, num_steps=K)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ce = -jnp.take_along_axis(logp, test[..., None], -1).mean()
    rows.append({"name": "Huginn+DiffusionBlocks", "final_ce": float(ce),
                 "step_seconds": dt, "fwd_passes_per_step": 1})
    return rows
