"""Paper Table 6: comparison with NoProp on classification.

NoProp-DT baseline (Li et al. 2025, reimplemented): T discrete denoising
steps, each with its OWN block trained independently to predict the clean
label embedding from z_t at a FIXED discrete noise level (cosine alphas) —
discrete-time, no continuous σ-conditioning, uniform time partition.
DiffusionBlocks = continuous-time + equi-probability partitioning on the
same backbone. Paper: DB 46.88 > NoProp-DT 46.06 >> NoProp-CT 21.31."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.core.vit import ViTDiffusionBlocks
from repro.data import GaussianMixtureImages
from repro.optim import adamw, apply_updates
from benchmarks.table1_vit import CFG, _accuracy, _train


def _noprop_dt(g, steps, T=3, d=64, seed=0, lr=2e-3):
    """Each step t has an independent MLP block predicting the clean label
    embedding from (features, z_t); inference chains them."""
    num_classes = g.num_classes
    rng = jax.random.PRNGKey(seed)
    feat_dim = g.image_size * g.image_size * g.channels
    keys = jax.random.split(rng, 3 * T + 2)
    emb = jax.random.normal(keys[-1], (num_classes, d))
    emb = emb / jnp.linalg.norm(emb, axis=-1, keepdims=True)
    blocks = []
    for t in range(T):
        w1 = jax.random.normal(keys[3 * t], (feat_dim + d, 256)) \
            / np.sqrt(feat_dim + d)
        w2 = jax.random.normal(keys[3 * t + 1], (256, d)) / 16.0
        blocks.append({"w1": w1, "w2": w2})
    head = jax.random.normal(keys[-2], (d, num_classes)) / np.sqrt(d)
    # cosine alphas (NoProp-DT discrete schedule)
    ts = (np.arange(T + 1)) / T
    abar = np.cos((ts + 0.008) / 1.008 * np.pi / 2) ** 2

    def block_fwd(blk, x, z):
        h = jnp.concatenate([x, z], -1)
        return jnp.tanh(h @ blk["w1"]) @ blk["w2"]

    params = {"blocks": blocks, "head": head, "emb": emb}
    init, update = adamw(lr)
    st = init(params)
    it = np.random.RandomState(seed)

    def loss_fn(p, x, y, t, eps):
        e = p["emb"] / (jnp.linalg.norm(p["emb"], axis=-1,
                                        keepdims=True) + 1e-6)
        ye = e[y]
        z_t = np.sqrt(abar[t + 1]) * ye + np.sqrt(1 - abar[t + 1]) * eps
        pred = block_fwd(p["blocks"][t], x, z_t)
        logits = pred @ p["head"]
        ce = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                  y[:, None], -1).mean()
        return jnp.mean((pred - ye) ** 2) + ce

    grad = jax.jit(jax.value_and_grad(loss_fn), static_argnums=(3,))
    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        x, y = g.sample(it, 32)
        x = jnp.asarray(x.reshape(32, -1))
        y = jnp.asarray(y)
        t = it.randint(0, T)
        key, r = jax.random.split(key)
        eps = jax.random.normal(r, (32, d))
        _, grads = grad(params, x, y, t, eps)
        upd, st, _ = update(grads, st, params)
        params = apply_updates(params, upd)

    def predict(x):
        z = jax.random.normal(jax.random.PRNGKey(0), (x.shape[0], d))
        for t in reversed(range(T)):
            pred = block_fwd(params["blocks"][t], x, z)
            z = np.sqrt(abar[t]) * pred + np.sqrt(1 - abar[t]) * 0.0
        return jnp.argmax(pred @ params["head"], -1)
    return predict


def run(quick: bool = True):
    steps = 150 if quick else 600
    g = GaussianMixtureImages(num_classes=10, image_size=16, noise_scale=2.0,
                              seed=0)
    test_x, test_y = g.sample(np.random.RandomState(99), 256)
    rows = []

    # Backprop baseline (same backbone as table1 e2e)
    db = DBConfig(num_blocks=3, overlap_gamma=0.1)
    vit = ViTDiffusionBlocks(CFG, db, image_size=16, patch=4, channels=3)
    it_rng = np.random.RandomState(1)

    def data():
        while True:
            x, y = g.sample(it_rng, 32)
            yield jnp.asarray(x), jnp.asarray(y)

    p = _train(vit, vit.init(jax.random.PRNGKey(0)),
               lambda pp, x, y, r: vit.e2e_loss(pp, x, y, r), data(), steps)
    pred, _ = vit.predict_e2e(p, jnp.asarray(test_x))
    rows.append({"name": "Backprop", "accuracy": _accuracy(pred, test_y),
                 "continuous": 0, "blockwise": 0})

    # NoProp-DT
    predict = _noprop_dt(g, steps * 2, T=3)
    pred = predict(jnp.asarray(test_x.reshape(len(test_x), -1)))
    rows.append({"name": "NoProp-DT", "accuracy": _accuracy(pred, test_y),
                 "continuous": 0, "blockwise": 1})

    # DiffusionBlocks (continuous + blockwise) — reuse table1 training
    from benchmarks import table1_vit
    t1 = table1_vit.run(quick=quick)
    db_acc = [r for r in t1 if r["name"] == "ViT+DiffusionBlocks"][0][
        "accuracy"]
    rows.append({"name": "DiffusionBlocks", "accuracy": db_acc,
                 "continuous": 1, "blockwise": 1})
    return rows
