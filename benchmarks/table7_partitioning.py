"""Paper Table 7: block partitioning strategy ablation — equi-probability vs
uniform σ-partitioning × layer distributions, on the DiT synthetic task
(overlap disabled, as in the paper's ablation)."""
from __future__ import annotations

from benchmarks import table2_dit as T2


def run(quick: bool = True):
    steps = 220 if quick else 1000
    rows = []
    for partition in ("uniform", "equiprob"):
        for dist in ([2, 2, 2], [1, 4, 1]):
            out = T2.run(quick=quick, db_blocks=3, steps=steps,
                         partition=partition, distribution=dist)
            db_row = [r for r in out if "DiffusionBlocks" in r["name"]][0]
            rows.append({
                "name": f"{partition}-{'-'.join(map(str, dist))}",
                "fid_proxy_dist": db_row["fid_proxy_dist"],
                "mode_coverage": db_row["mode_coverage"],
            })
    return rows
