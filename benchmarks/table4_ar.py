"""Paper Table 4: autoregressive LM — AR (e2e) vs +DiffusionBlocks (B=4).
Metrics: MAUVE stand-in (legal-transition rate of generations) and teacher
NLL (the generating Markov chain is the exact teacher)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as CM
from repro.configs import DBConfig
from repro.data import MarkovLM


def run(quick: bool = True):
    steps = 400 if quick else 1200
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)
    rows = []

    dbm_e, p_e, hist_e = CM.train_lm_e2e(steps, lm, seed=0)
    m = CM.e2e_generation_metrics(dbm_e, p_e, lm)
    rows.append({"name": "AR", **m, "final_ce": hist_e[-1][2],
                 "layers_with_grads": CM.TINY_LM.n_layers})

    db = DBConfig(num_blocks=4, overlap_gamma=0.1)
    dbm, p, hist = CM.train_lm_db(db, steps, lm, seed=0)
    m = CM.generation_metrics(dbm, p, lm)
    last = float(np.mean([l for _, _, l in hist[-20:]]))
    rows.append({"name": "AR+DiffusionBlocks", **m, "final_ce": last,
                 "layers_with_grads": CM.TINY_LM.n_layers // 4})
    return rows
