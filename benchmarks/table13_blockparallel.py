"""Beyond-paper Table 13: block-parallel training walltime.

The paper measures the B× MEMORY reduction (Table 12); this table measures
the throughput side the independence result also buys: a fixed budget of
per-block updates executed (a) by the sequential block-cycling ``train_db``
loop — one jitted call per block update — and (b) by the block-parallel
engine, which advances all B blocks per batch in one jitted call (shard_map
across a pod-per-block mesh when the host has ≥ B devices, the round-robin
scan schedule otherwise).

Run standalone with 8 virtual devices:

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.table13_blockparallel

Reported: wall-clock for the budget (post-compile), speedup, and per-block
final losses of both runs (they train the same per-block objective, so the
trajectories must land in the same place within tolerance).
"""
from __future__ import annotations

import os

if __name__ == "__main__":      # script entry: force pods before jax init
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from benchmarks import common as CM
from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import make_db_train_step
from repro.data import MarkovLM
from repro.parallel import BlockParallelTrainer

# paper §5.4 AR setup (B=4, γ=0.1, CE) at benchmark-reduced dims
BENCH_AR = ModelConfig(name="bench-ar4", family="dense", n_layers=8,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab_size=32,
                       source="paper §5.4 (AR, B=4), reduced dims")
BENCH_DB = DBConfig(num_blocks=4, overlap_gamma=0.1, loss="ce")


def _per_block_tail_loss(history, num_blocks: int, tail: int = 4):
    """Mean of each block's last ``tail`` losses."""
    out = np.zeros(num_blocks)
    for b in range(num_blocks):
        ls = [l for _, blk, l in history if blk == b]
        out[b] = float(np.mean(ls[-tail:]))
    return out


def run(quick: bool = True):
    B = BENCH_DB.num_blocks
    budget = 144 if quick else 480          # total per-block updates
    lm = MarkovLM(vocab_size=32, seed=2)
    tcfg = TrainConfig(steps=budget, lr=2e-3, warmup_steps=4, log_every=0)
    dbm = DiffusionBlocksModel(BENCH_AR, BENCH_DB)
    params = dbm.init(jax.random.PRNGKey(0))
    data = CM.lm_data_iter(lm, 16, 64, 0)
    tokens = next(data)

    # -- sequential block-cycling: one jitted call per block update ---------
    steppers, opts = [], []
    for b in range(B):
        init_opt, step = make_db_train_step(dbm, b, tcfg)
        steppers.append(step)
        opts.append(init_opt(params))
    for b in range(B):                       # compile outside the clock
        jax.block_until_ready(steppers[b](params, opts[b], tokens,
                                          jax.random.PRNGKey(1), None)[2])
    p_seq, hist_seq = params, []
    rng = jax.random.PRNGKey(1)
    t0 = time.time()
    for it in range(budget):
        b = it % B                           # round-robin cycling
        rng, rs = jax.random.split(rng)
        p_seq, opts[b], loss, _ = steppers[b](p_seq, opts[b], next(data),
                                              rs, None)
        hist_seq.append((it, b, float(loss)))
    jax.block_until_ready(p_seq)
    t_seq = time.time() - t0

    # -- block-parallel: all B blocks per batch in one jitted call ----------
    trainer = BlockParallelTrainer(dbm, tcfg)
    state = trainer.init_state(params)
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    state_w, _, _ = trainer.step(state, tokens, rngs)     # compile
    jax.block_until_ready(state_w.stacks)
    state, hist_par = trainer.init_state(params), []
    rng, it = jax.random.PRNGKey(1), 0
    t0 = time.time()
    for bt in range(budget // B):
        rng, rs = jax.random.split(rng)
        state, losses, _ = trainer.step(state, next(data),
                                        jax.random.split(rs, B))
        for b, l in enumerate(np.asarray(losses)):
            hist_par.append((it, b, float(l)))
            it += 1
    jax.block_until_ready(state.stacks)
    t_par = time.time() - t0

    tail_seq = _per_block_tail_loss(hist_seq, B)
    tail_par = _per_block_tail_loss(hist_par, B)
    gap = np.abs(tail_par - tail_seq)
    if trainer.mode == "shard_map":
        # the acceptance bar: with a pod per block the same update budget
        # must cost less wall-clock than sequential cycling, and land at the
        # same per-block losses (absolute CE gap; the periphery sees B
        # averaged updates instead of B individual ones, so the transient
        # differs but the destination must not)
        assert t_par < t_seq, (t_par, t_seq)
        assert float(gap.max()) < 0.35, (tail_seq, tail_par)

    rows = [
        {"name": "sequential-cycling", "walltime_s": t_seq,
         "updates_per_s": budget / t_seq},
        {"name": f"block-parallel/{trainer.mode}", "walltime_s": t_par,
         "updates_per_s": budget / t_par},
        {"name": "speedup", "x": t_seq / t_par,
         "devices": jax.device_count(), "blocks": B},
    ]
    for b in range(B):
        rows.append({"name": f"block{b}-final-loss", "sequential": tail_seq[b],
                     "parallel": tail_par[b], "abs_diff": float(gap[b])})
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
