# Block-parallel training: the paper's B× memory story turned into a B×
# throughput story — every gradient-isolated block advances concurrently on
# its own ``pod`` mesh group (see engine.py for the periphery sync policies).
from repro.parallel.engine import (PERIPHERY_POLICIES, BlockParallelTrainer,
                                   train_db_parallel)
from repro.parallel.state import (BlockParallelState, block_view,
                                  merge_params, split_periphery,
                                  stack_block_views, uniform_block_size)
