"""Block-parallel DiffusionBlocks training across devices.

The paper's §3 independence result says block b's objective never reads
another block's gradients — the only shared state is the periphery
(embeddings / readout / final norm / σ-conditioning). This engine turns that
structural fact into wall-clock parallelism: a 2-D (``pod`` × ``data``) mesh
gives every block its own pod group, and ONE jitted ``shard_map`` call per
batch advances all B blocks — per-block score-matching losses, per-block
AdamW moments, zero cross-pod optimizer collectives.

Periphery sync policies (``periphery=``):

  ``replicate+psum-mean``   every block computes periphery gradients on the
        full batch; they are psum-averaged across pods each step and one
        AdamW update is applied identically everywhere (data-parallel
        semantics for the shared params; the replication invariant is exact).
        Highest fidelity, one psum of periphery-sized grads per step.
  ``owner-broadcast``       only the OWNER block (B-1, the lowest-noise
        block — the same block whose checkpoint supplies the periphery in
        ``repro.checkpoint.load_blocks``) contributes periphery gradients;
        the psum then just broadcasts them. Cheaper semantics when the
        low-noise block dominates readout quality; other blocks' periphery
        preferences are ignored.
  ``freeze-after-warmup``   psum-mean for the first ``freeze_steps`` updates,
        then the periphery stops moving entirely — blocks become FULLY
        independent (the psum still executes but its result is discarded by
        a select, keeping one compiled program). Zero effective cross-block
        coupling after warmup; final loss depends on the warmup being long
        enough to settle the embedding geometry.

Degradation: when the host has fewer devices than blocks (or the block sizes
are unequal) the same math runs as a round-robin ``lax.scan`` over blocks on
the default device — one block's activations in memory at a time, identical
per-block losses — so CPU CI (``--xla_force_host_platform_device_count=8``)
and a laptop both run the one code path they can.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding

from repro import precision as precision_mod
from repro.configs.base import TrainConfig
from repro.core import partition as P
from repro.core.blocks import DiffusionBlocksModel
from repro.core.training import GuardConfig
from repro.optim import adamw, apply_updates, clip_by_global_norm, global_norm
from repro.optim.schedules import warmup_cosine
from repro.parallel.state import (BlockParallelState, split_periphery,
                                  stack_block_views, uniform_block_size)
from repro.sharding import rules

PERIPHERY_POLICIES = ("replicate+psum-mean", "owner-broadcast",
                      "freeze-after-warmup")
_POLICY_ALIASES = {"mean": "replicate+psum-mean", "psum-mean":
                   "replicate+psum-mean", "owner": "owner-broadcast",
                   "broadcast": "owner-broadcast", "freeze":
                   "freeze-after-warmup"}


def _split_optimizer(tcfg: TrainConfig, lr_scale: float = 1.0):
    """Same AdamW/schedule as ``make_db_train_step``'s, but with clipping
    hoisted out: the engine clips each block's FULL view grads (stack +
    periphery, matching the sequential per-block step) before the periphery
    reduction splits them across two optimizers.

    ``lr_scale`` compensates the periphery's 1-vs-B update-count gap: the
    sequential trainer applies one periphery AdamW update per BLOCK update
    (B per batch-equivalent), the parallel engine one per BATCH. With
    ``lr_scale = B`` the periphery rate is scaled by B and the warmup/cosine
    schedule is evaluated at the equivalent block-update count, so the
    periphery trajectory tracks the sequential cadence to first order."""
    base = warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.steps)
    if lr_scale == 1.0:
        lr = base
    else:
        def lr(step):
            return lr_scale * base(step.astype(jnp.float32) * lr_scale)
    return adamw(lr, tcfg.b1, tcfg.b2, tcfg.eps,
                 weight_decay=tcfg.weight_decay, grad_clip=None)


class BlockParallelTrainer:
    """Trains all B blocks concurrently; see module docstring.

    ``mode`` is ``"shard_map"`` when every block got a pod group, else
    ``"round_robin"``. ``devices`` restricts the mesh (e.g. ``devices=
    jax.devices()[:B]`` forces data=1 for bit-reproducible comparisons).
    """

    def __init__(self, dbm: DiffusionBlocksModel, tcfg: TrainConfig,
                 periphery: str = "replicate+psum-mean",
                 freeze_steps: Optional[int] = None, impl: str = "auto",
                 devices=None, jit: bool = True, precision=None,
                 periphery_lr_scale=None, guard: Optional[GuardConfig] = None):
        self.dbm, self.tcfg, self.impl = dbm, tcfg, impl
        self.precision = precision_mod.get_policy(precision)
        self.policy = _POLICY_ALIASES.get(periphery, periphery)
        if self.policy not in PERIPHERY_POLICIES:
            raise ValueError(f"unknown periphery policy {periphery!r}; "
                             f"one of {PERIPHERY_POLICIES}")
        self.B = dbm.num_blocks
        self.u = uniform_block_size(dbm.ranges)
        self.guard = GuardConfig() if guard is None else guard
        self.guard_ewma = jnp.full((self.B,), -1.0, jnp.float32)
        self.anomaly_streak = np.zeros(self.B, np.int64)
        self.anomalies = np.zeros(self.B, np.int64)
        self.last_ok = np.ones(self.B, bool)
        self.freeze_steps = (tcfg.warmup_steps if freeze_steps is None
                             else freeze_steps)
        self.mesh = rules.block_parallel_mesh(self.B, devices)
        self.mode = "shard_map" if self.mesh is not None else "round_robin"
        self.qranges = jnp.asarray(P.block_qranges(dbm.db))        # (B, 2)
        self.block_ids = jnp.arange(self.B)
        if periphery_lr_scale in (None, "none"):
            self.periphery_lr_scale = 1.0
        elif periphery_lr_scale == "auto":
            self.periphery_lr_scale = float(self.B)
        else:
            self.periphery_lr_scale = float(periphery_lr_scale)
        self._opt_init, self._opt_update = _split_optimizer(tcfg)
        self._popt_init, self._popt_update = _split_optimizer(
            tcfg, self.periphery_lr_scale)
        self._step_fn = self._build_step(jit)
        if self.mesh is not None:
            sp = NamedSharding(self.mesh, rules.block_state_specs()["stacked"])
            self.qranges = jax.device_put(self.qranges, sp)
            self.block_ids = jax.device_put(self.block_ids, sp)

    # ------------------------------------------------------------------
    def _build_step(self, jit: bool):
        dbm, tcfg, u, B = self.dbm, self.tcfg, self.u, self.B
        policy, impl, freeze_steps = self.policy, self.impl, self.freeze_steps
        pol = self.precision
        guard = self.guard
        opt_update = self._opt_update
        popt_update = self._popt_update
        pod_ax = rules.BLOCK_AXIS if self.mode == "shard_map" else None
        data_size = self.mesh.shape["data"] if self.mesh is not None else 1
        data_ax = "data" if (self.mode == "shard_map" and data_size > 1) \
            else None

        def block_grads(view, tokens, rng, q_lo, q_hi, loss_mult):
            if data_ax is not None:
                # each data shard must draw its OWN σ/ε for its batch slice
                rng = jax.random.fold_in(rng, jax.lax.axis_index(data_ax))

            def loss_fn(v):
                vc = precision_mod.cast_params_for_compute(pol, v,
                                                           dbm.cfg.family)
                loss, metrics = dbm.block_loss(vc, 0, tokens, rng, impl=impl,
                                               unit_range=(0, u),
                                               sigma_qrange=(q_lo, q_hi),
                                               precision=pol)
                # the grad_nan injection point: NaN loss_mult → NaN grads;
                # the multiply by the usual 1.0 is bit-exact
                return loss * loss_mult, metrics

            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(view)
            if data_ax is not None:
                grads = jax.lax.pmean(grads, data_ax)
                loss = jax.lax.pmean(loss, data_ax)
            if tcfg.grad_clip is not None:
                grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
            else:
                gnorm = global_norm(grads)
            return loss, grads, gnorm

        def local_update(stacks, stack_opt, periph, periph_opt, tokens,
                         rngs, qranges, block_ids, loss_mult, active, ewma,
                         upd_periph):
            """Advance the (locally held) blocks; scan keeps only ONE block's
            activations live at a time — under shard_map each pod holds one
            block (scan length 1); in round-robin mode the scan IS the
            schedule. Per-block ANOMALY GUARD: a non-finite or spiking loss
            (or ``active=0``, a dead pod) skips that block's stack update and
            masks its periphery contribution out of the psum; the clean path
            is bit-identical to the unguarded engine (selects of the same
            values, scale exactly 1.0)."""

            def body(acc, xs):
                stack_b, opt_b, rng_b, qr_b, bid, mult_b, act_b, ewma_b = xs
                view = {**periph, **stack_b}
                loss, grads, gnorm = block_grads(view, tokens, rng_b,
                                                 qr_b[0], qr_b[1], mult_b)
                ok, ewma_b = guard.classify(loss, gnorm, ewma_b, act_b > 0)
                g_stack = {k: grads[k] for k in stack_b}
                g_per = {k: grads[k] for k in periph}
                if policy == "owner-broadcast":
                    w = (bid == B - 1).astype(jnp.float32)
                else:
                    w = jnp.float32(1.0 / B)
                w = jnp.where(ok, w, 0.0)
                acc_g, acc_n, acc_w = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + w * jnp.where(ok, g.astype(jnp.float32),
                                                   0.0), acc_g, g_per)
                acc_n = acc_n + ok.astype(jnp.int32)
                acc_w = acc_w + w
                updates, opt_b2, _ = opt_update(g_stack, opt_b, stack_b)
                stack_b2 = apply_updates(stack_b, updates)
                sel = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
                stack_b = jax.tree_util.tree_map(sel, stack_b2, stack_b)
                opt_b = jax.tree_util.tree_map(sel, opt_b2, opt_b)
                return (acc_g, acc_n, acc_w), (stack_b, opt_b, loss, gnorm,
                                               ok, ewma_b)

            acc0 = (jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), periph),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))
            acc, (stacks, stack_opt, losses, gnorms, oks, ewma) = \
                jax.lax.scan(body, acc0, (stacks, stack_opt, rngs, qranges,
                                          block_ids, loss_mult, active, ewma))
            acc_g, acc_n, acc_w = acc
            if pod_ax is not None:
                acc_g = jax.lax.psum(acc_g, pod_ax)
                acc_n = jax.lax.psum(acc_n, pod_ax)
                acc_w = jax.lax.psum(acc_w, pod_ax)
            # renormalize the periphery mean over the SURVIVING blocks. In
            # the owner policy acc_g already carries exactly the owner's
            # grads (w ∈ {0,1}), so the scale stays 1; in the mean policies
            # B/n_ok re-weights the (1/B)Σ_ok sum to a true mean — exactly
            # 1.0 when every block is clean (bit-parity with the old path).
            if policy == "owner-broadcast":
                scale = jnp.float32(1.0)
                per_ok = acc_w > 0
            else:
                scale = B / jnp.maximum(acc_n.astype(jnp.float32), 1.0)
                per_ok = acc_n > 0
            g_per = jax.tree_util.tree_map(lambda a: a * scale, acc_g)
            updates, new_popt, _ = popt_update(g_per, periph_opt, periph)
            new_periph = apply_updates(periph, updates)
            do_per = per_ok & upd_periph
            sel_p = lambda new, old: jnp.where(do_per, new, old)  # noqa: E731
            new_periph = jax.tree_util.tree_map(sel_p, new_periph, periph)
            new_popt = jax.tree_util.tree_map(sel_p, new_popt, periph_opt)
            if policy == "freeze-after-warmup":
                frozen = periph_opt.step >= freeze_steps
                keep = lambda old, new: jnp.where(frozen, old, new)  # noqa: E731
                new_periph = jax.tree_util.tree_map(keep, periph, new_periph)
                new_popt = jax.tree_util.tree_map(keep, periph_opt, new_popt)
            return (stacks, stack_opt, new_periph, new_popt, losses, gnorms,
                    oks, ewma)

        fn = local_update
        if self.mode == "shard_map":
            specs = rules.block_state_specs()
            sp, rp, tk = specs["stacked"], specs["replicated"], specs["tokens"]
            fn = shard_map(local_update, mesh=self.mesh,
                           in_specs=(sp, sp, rp, rp, tk, sp, sp, sp, sp, sp,
                                     sp, rp),
                           out_specs=(sp, sp, rp, rp, sp, sp, sp, sp),
                           check_rep=False)
        return jax.jit(fn) if jit else fn

    # ------------------------------------------------------------------
    def init_state(self, params) -> BlockParallelState:
        stacks = stack_block_views(params, self.dbm.ranges)
        _, periph = split_periphery(params)
        stack_opt = jax.vmap(self._opt_init)(stacks)
        periph_opt = self._popt_init(periph)
        if self.mesh is not None:
            specs = rules.block_state_specs()
            sp = NamedSharding(self.mesh, specs["stacked"])
            rp = NamedSharding(self.mesh, specs["replicated"])
            stacks = jax.device_put(stacks, sp)
            stack_opt = jax.device_put(stack_opt, sp)
            periph = jax.device_put(periph, rp)
            periph_opt = jax.device_put(periph_opt, rp)
        return BlockParallelState(stacks, periph, stack_opt, periph_opt)

    def step(self, state: BlockParallelState, tokens, rngs, loss_mult=None,
             active=None, update_periphery: bool = True):
        """One batch → one update of EVERY block. ``rngs``: (B, 2) per-block
        PRNG keys. Returns (state', per-block losses (B,), grad norms (B,)).

        ``loss_mult`` (B,) scales each block's loss inside the grad (the
        ``grad_nan`` injection point; default all-ones is bit-neutral).
        ``active`` (B,) masks blocks out entirely (dead pods / orphan-only
        degraded passes): an inactive block gets no stack update and no
        periphery contribution. ``update_periphery=False`` freezes the
        periphery for this call (used by the supervisor's orphan round-robin
        passes so the mesh remains the single periphery writer).

        Guard outcomes land on the trainer: ``last_ok`` (B,) bool,
        cumulative ``anomalies``, consecutive ``anomaly_streak`` (only
        blocks that actually ran are counted), and the per-block loss EWMA
        ``guard_ewma`` advances only on clean steps."""
        B = self.B
        loss_mult = (jnp.ones((B,), jnp.float32) if loss_mult is None
                     else jnp.asarray(loss_mult, jnp.float32))
        active = (jnp.ones((B,), jnp.float32) if active is None
                  else jnp.asarray(active, jnp.float32))
        if self.mesh is not None:
            specs = rules.block_state_specs()
            tokens = jax.device_put(
                tokens, NamedSharding(self.mesh, specs["tokens"]))
            sp = NamedSharding(self.mesh, specs["stacked"])
            loss_mult = jax.device_put(loss_mult, sp)
            active = jax.device_put(active, sp)
        (stacks, stack_opt, periph, periph_opt, losses, gnorms, oks,
         ewma) = self._step_fn(
            state.stacks, state.stack_opt, state.periph, state.periph_opt,
            tokens, rngs, self.qranges, self.block_ids, loss_mult, active,
            self.guard_ewma, jnp.asarray(bool(update_periphery)))
        self.guard_ewma = ewma
        oks_np = np.asarray(oks).astype(bool)
        ran = np.asarray(active) > 0
        bad = ran & ~oks_np
        self.last_ok = oks_np | ~ran
        self.anomalies += bad
        self.anomaly_streak = np.where(
            bad, self.anomaly_streak + 1,
            np.where(ran, 0, self.anomaly_streak))
        return (BlockParallelState(stacks, periph, stack_opt, periph_opt),
                losses, gnorms)

    # ------------------------------------------------------------------
    def guard_state(self) -> dict:
        """JSON-serializable guard state (manifest payload)."""
        return {"ewma": [float(x) for x in np.asarray(self.guard_ewma)],
                "streak": [int(x) for x in self.anomaly_streak],
                "anomalies": [int(x) for x in self.anomalies]}

    def set_guard_state(self, gs: Optional[dict]) -> None:
        if not gs:
            return
        self.guard_ewma = jnp.asarray(np.asarray(gs["ewma"], np.float32))
        self.anomaly_streak = np.asarray(gs["streak"], np.int64)
        self.anomalies = np.asarray(gs["anomalies"], np.int64)

    def block_trees(self, state: BlockParallelState, b: int):
        """(stack_view, opt_view) for block ``b`` — host-side slices of the
        stacked state (checkpoint payloads, rewind templates)."""
        stack = jax.device_get(jax.tree_util.tree_map(
            lambda x: x[b], state.stacks))
        opt = jax.device_get(jax.tree_util.tree_map(
            lambda x: x[b], state.stack_opt))
        return stack, opt

    def write_block(self, state: BlockParallelState, b: int, stack_view,
                    opt_view) -> BlockParallelState:
        """Overwrite ONE block's stacked slice + optimizer moments (rewind /
        pod re-adoption) — every other block's state is untouched."""
        stacks = jax.tree_util.tree_map(
            lambda whole, blk: whole.at[b].set(
                jnp.asarray(blk, whole.dtype)), state.stacks, stack_view)
        stack_opt = jax.tree_util.tree_map(
            lambda whole, blk: whole.at[b].set(
                jnp.asarray(blk, whole.dtype)), state.stack_opt, opt_view)
        self.anomaly_streak[b] = 0
        self.guard_ewma = self.guard_ewma.at[b].set(-1.0)
        return BlockParallelState(stacks, state.periph, stack_opt,
                                  state.periph_opt)

    # ------------------------------------------------------------------
    def train(self, data_iter, rng, params=None, log=print,
              ckpt_dir: Optional[str] = None):
        """Counterpart of ``train_db``: ``tcfg.steps`` is the TOTAL budget of
        per-block updates, so the engine runs ceil(steps / B) batches and the
        returned history carries one (it, block, loss) entry per block-update
        — directly comparable to the sequential trajectory. A batch advances
        ALL blocks, so a budget not divisible by B executes up to B-1 extra
        updates in the final batch; the history is truncated to ``steps``
        entries either way."""
        tcfg = self.tcfg
        rng, r0 = jax.random.split(rng)
        if params is None:
            params = self.dbm.init(r0)
        state = self.init_state(params)
        history, it = [], 0
        batches = math.ceil(tcfg.steps / self.B)
        for bt in range(batches):
            tokens = next(data_iter)
            rng, rs = jax.random.split(rng)
            state, losses, gnorms = self.step(state, tokens,
                                              jax.random.split(rs, self.B))
            losses = np.asarray(losses)
            for b in range(self.B):
                if it < tcfg.steps:
                    history.append((it, b, float(losses[b])))
                it += 1
            if tcfg.log_every and bt % tcfg.log_every == 0:
                log(f"[db-par/{self.mode}/{self.policy}] batch={bt} "
                    f"loss={losses.mean():.4f} "
                    f"gn={float(np.asarray(gnorms).mean()):.2f}")
        if ckpt_dir:
            self.save_checkpoint(state, ckpt_dir, step=it)
        return self.full_params(state), history

    # ------------------------------------------------------------------
    def full_params(self, state: BlockParallelState) -> dict:
        """Assemble the full params tree from the mesh-resident state. The
        engine enforces contiguous equal-sized blocks, so flattening each
        (B, u, ...) stacked leaf back to (B·u, ...) IS the full unit stack
        (``merge_params`` is the general-template form used by the tests)."""
        stacks = jax.device_get(state.stacks)
        periph = jax.device_get(state.periph)
        return {**{k: jax.tree_util.tree_map(
            lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]), v)
            for k, v in stacks.items()}, **periph}

    def save_checkpoint(self, state: BlockParallelState, ckpt_dir: str,
                        step: int = 0):
        """Per-block params + per-block optimizer moments + the periphery
        optimizer — each pod's block is recoverable independently."""
        from repro.checkpoint import (save_block, save_block_opt, save_pytree)
        params = self.full_params(state)
        for b, (start, size) in enumerate(self.dbm.ranges):
            save_block(ckpt_dir, params, b, start, size, step)
            opt_b = jax.device_get(jax.tree_util.tree_map(
                lambda x: x[b], state.stack_opt))
            save_block_opt(ckpt_dir, b, opt_b, step)
        save_pytree(os.path.join(ckpt_dir, "periphery.opt.npz"),
                    jax.device_get(state.periph_opt), {"step": step})

    def restore(self, params_template, ckpt_dir: str) -> BlockParallelState:
        """Rebuild mesh-resident state from per-block checkpoints; blocks or
        optimizer files that are missing keep their fresh initialization."""
        from repro.checkpoint import load_block_opt, load_blocks, load_pytree
        params = load_blocks(ckpt_dir, params_template, self.dbm.ranges)
        state = self.init_state(params)
        opt_slices = []
        for b in range(self.B):
            tmpl = jax.tree_util.tree_map(lambda x: x[b], state.stack_opt)
            loaded = load_block_opt(ckpt_dir, b, tmpl)
            opt_slices.append(tmpl if loaded is None else loaded)
        stack_opt = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *opt_slices)
        periph_opt = state.periph_opt
        ppath = os.path.join(ckpt_dir, "periphery.opt.npz")
        if os.path.exists(ppath):
            periph_opt = load_pytree(ppath, periph_opt)
        if self.mesh is not None:
            specs = rules.block_state_specs()
            stack_opt = jax.device_put(
                stack_opt, NamedSharding(self.mesh, specs["stacked"]))
            periph_opt = jax.device_put(
                periph_opt, NamedSharding(self.mesh, specs["replicated"]))
        return BlockParallelState(state.stacks, state.periph, stack_opt,
                                  periph_opt)


def train_db_parallel(dbm: DiffusionBlocksModel, tcfg: TrainConfig, data_iter,
                      rng, params=None, log=print,
                      periphery: str = "replicate+psum-mean",
                      devices=None, ckpt_dir: Optional[str] = None,
                      impl: str = "auto", precision=None,
                      periphery_lr_scale=None):
    """Functional wrapper mirroring ``train_db``'s signature.
    ``periphery_lr_scale``: None (off), "auto" (scale by B), or a float —
    compensates the periphery's 1-update-per-batch vs the sequential
    trainer's 1-update-per-block-update cadence."""
    trainer = BlockParallelTrainer(dbm, tcfg, periphery=periphery,
                                   devices=devices, impl=impl,
                                   precision=precision,
                                   periphery_lr_scale=periphery_lr_scale)
    return trainer.train(data_iter, rng, params=params, log=log,
                         ckpt_dir=ckpt_dir)
