"""Block-parallel training state: every block's unit slice, stacked.

Layout (``BlockParallelState``):

  stacks      {stack_key: tree}; each leaf is (B, u, ...) — block b's unit
              slice at index b along the leading axis (u = units per block).
              Built from the full params with ``extract_block_view`` (the
              same machinery the sequential trainer slices with), so block b
              of the stack IS the view ``make_db_train_step(dbm, b)`` trains.
  periph      the shared periphery (embeddings / readout / final norm /
              σ-conditioning): ONE copy, kept replicated across pods by the
              engine's sync policy.
  stack_opt   AdamW state for the stacked views; leaves carry the same
              leading (B, ...) block axis (independent moments per block).
  periph_opt  AdamW state for the single periphery copy.

The stacked form requires equal block sizes (``unit_ranges`` default when
B | n_units — true for every paper config); ``stack_block_views`` raises
``ValueError`` otherwise — catch it and use the sequential ``train_db`` path
for non-uniform partitions.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.training import (STACK_KEYS, extract_block_view,
                                 write_back_block_view)


class BlockParallelState(NamedTuple):
    stacks: Any
    periph: Any
    stack_opt: Any
    periph_opt: Any


def split_periphery(params: dict) -> Tuple[dict, dict]:
    """(stacks, periphery) partition of a full params tree."""
    stacks = {k: v for k, v in params.items() if k in STACK_KEYS}
    periph = {k: v for k, v in params.items() if k not in STACK_KEYS}
    return stacks, periph


def uniform_block_size(ranges: List[Tuple[int, int]]) -> int:
    sizes = {s for _, s in ranges}
    if len(sizes) != 1:
        raise ValueError(
            f"block-parallel training needs equal-sized blocks, got unit "
            f"ranges {ranges}; use sequential train_db or pass a uniform "
            f"``distribution``")
    return sizes.pop()


def stack_block_views(params: dict, ranges: List[Tuple[int, int]]) -> dict:
    """Stack every block's unit slice into (B, u, ...) leaves."""
    uniform_block_size(ranges)
    per_block = []
    for start, size in ranges:
        view = extract_block_view(params, start, size)
        per_block.append({k: view[k] for k in view if k in STACK_KEYS})
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_block)


def block_view(stacks: dict, periph: dict, b) -> dict:
    """Reassemble block b's training view (what ``block_loss`` applies)."""
    one = jax.tree_util.tree_map(lambda x: x[b], stacks)
    return {**periph, **one}


def merge_params(params_template: dict, stacks: dict, periph: dict,
                 ranges: List[Tuple[int, int]]) -> dict:
    """Write every block's stacked slice + the shared periphery back into a
    full params tree (inverse of ``stack_block_views``, via the sequential
    trainer's ``write_back_block_view``)."""
    params = params_template
    for b, (start, size) in enumerate(ranges):
        params = write_back_block_view(params, block_view(stacks, periph, b),
                                       start)
    return params
