from repro.roofline import hw
from repro.roofline.analysis import (analyze, format_row, model_flops,
                                     parse_collective_bytes, wire_bytes)
