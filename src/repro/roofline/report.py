"""Generate the EXPERIMENTS.md roofline table from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}µs"


def table(recs: List[Dict], multi_pod: bool = False) -> str:
    rows = [r for r in recs if r.get("multi_pod") == multi_pod
            and not r.get("skipped")]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "useful FLOPs | coll. bytes/chip | peak GB/chip (CPU-lowered) | "
           "analytic GB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        peak = r.get("peak_bytes_per_chip", 0) / 1e9
        ana = r.get("analytic_min_bytes_per_chip", {}).get("total", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(r['compute_s'])} "
            f"| {fmt_t(r['memory_s'])} | {fmt_t(r['collective_s'])} "
            f"| **{r['dominant'][:-2]}** "
            f"| {r.get('useful_flops_ratio', 0):.3f} "
            f"| {r['wire_bytes_per_chip']/1e6:.1f}MB "
            f"| {peak:.1f} | {ana:.2f} "
            f"| {'✓' if r.get('analytic_fits_hbm', r.get('fits_hbm')) else '✗'} |")
    return "\n".join(out)


def skipped(recs: List[Dict]) -> str:
    out = []
    for r in recs:
        if r.get("skipped"):
            out.append(f"- {r['arch']} × {r['shape']}: {r['reason']}")
    return "\n".join(sorted(set(out)))


def collective_breakdown(recs: List[Dict], top: int = 6) -> str:
    rows = [r for r in recs if not r.get("skipped")
            and not r.get("multi_pod")]
    rows.sort(key=lambda r: -r["collective_s"])
    out = ["| arch × shape | AG | AR | RS | A2A | CP |",
           "|---|---|---|---|---|---|"]
    for r in rows[:top]:
        c = r["collective_bytes_per_chip"]
        out.append(
            f"| {r['arch']} × {r['shape']} "
            f"| {c['all-gather']/1e6:.0f}MB | {c['all-reduce']/1e6:.0f}MB "
            f"| {c['reduce-scatter']/1e6:.0f}MB | {c['all-to-all']/1e6:.0f}MB "
            f"| {c['collective-permute']/1e6:.0f}MB |")
    return "\n".join(out)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print("## Single-pod (16×16) roofline\n")
    print(table(recs, multi_pod=False))
    print("\n## Multi-pod (2×16×16) compile proof\n")
    print(table(recs, multi_pod=True))
    print("\n## Skipped\n")
    print(skipped(recs))
    print("\n## Collective breakdown (most collective-bound)\n")
    print(collective_breakdown(recs))
