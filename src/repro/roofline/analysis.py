"""Roofline analysis from compiled dry-run artifacts.

Inputs: ``compiled.cost_analysis()`` (per-device HLO FLOPs / bytes accessed)
and the stablehlo/HLO text, from which collective operand/result sizes are
parsed (cost_analysis does not attribute collective bytes).

Terms (seconds, per chip — SPMD modules are per-device):
    compute    = flops / PEAK_FLOPS_BF16
    memory     = bytes_accessed / HBM_BW
    collective = wire_bytes / ICI_BW

wire_bytes heuristic per op (ring algorithms, n→∞ limit):
    all-gather / collective-permute / all-to-all: result bytes ×1
    reduce-scatter: input bytes ≈ result ×1 (counted from result of the op's
        operand shape when available, else result)
    all-reduce: result bytes ×2 (reduce-scatter + all-gather phases)
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# `  %name = bf16[8,128]{1,0} all-reduce(...)` and tuple results
_RE_OP = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+(" +
    "|".join(COLLECTIVES) + r")\b")
_RE_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes per collective kind from HLO text (per device)."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for m in _RE_OP.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            size = sum(_shape_bytes(t, d)
                       for t, d in _RE_SHAPE.findall(tuple_part))
        else:
            size = _shape_bytes(dtype, dims)
        out[kind] += size
        counts[kind] += 1
    out_counts = {f"n_{k}": counts[k] for k in COLLECTIVES}
    return {**out, **out_counts}


def wire_bytes(coll: Dict[str, float]) -> float:
    total = 0.0
    for k in COLLECTIVES:
        factor = 2.0 if k == "all-reduce" else 1.0
        total += factor * coll.get(k, 0.0)
    return total


def analyze(compiled, hlo_text: Optional[str] = None,
            model_flops_per_step: Optional[float] = None,
            chips: int = 256) -> Dict:
    """Returns the roofline record for one (arch × shape × mesh) dry-run."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):           # older API returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    if hlo_text is None:
        hlo_text = compiled.as_text()
    coll = parse_collective_bytes(hlo_text)
    wire = wire_bytes(coll)

    t_compute = flops / hw.PEAK_FLOPS_BF16
    t_memory = bytes_accessed / hw.HBM_BW
    t_coll = wire / hw.ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))
    peak_bytes = (mem_rec.get("argument_size_in_bytes", 0)
                  + mem_rec.get("output_size_in_bytes", 0)
                  + mem_rec.get("temp_size_in_bytes", 0)
                  - mem_rec.get("alias_size_in_bytes", 0))

    rec = {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": {k: coll[k] for k in COLLECTIVES},
        "collective_counts": {k: coll[f"n_{k}"] for k in COLLECTIVES},
        "wire_bytes_per_chip": wire,
        **terms,
        "dominant": dominant,
        "memory": mem_rec,
        "peak_bytes_per_chip": peak_bytes,
        "fits_hbm": peak_bytes <= hw.HBM_PER_CHIP,
        "chips": chips,
    }
    if model_flops_per_step:
        useful = model_flops_per_step / chips       # per chip
        rec["model_flops_per_chip"] = useful
        rec["useful_flops_ratio"] = useful / max(flops, 1.0)
    return rec


def extrapolate(rec1: Dict, rec2: Dict, n_units: int,
                mem_rec: Optional[Dict] = None) -> Dict:
    """Linear unit-count extrapolation of two probe records (1 and 2 units):
    cost(n) = cost(1) + (n-1)·(cost(2) - cost(1)). Layer stacks are
    homogeneous, so per-unit cost is constant; the intercept captures
    embed/readout/loss/optimizer fixed costs. Memory metrics come from the
    rolled full-size compile (mem_rec)."""
    out = dict(rec2)

    def lin(a, b):
        return a + (n_units - 1) * (b - a)

    for k in ("hlo_flops_per_chip", "hlo_bytes_per_chip",
              "wire_bytes_per_chip"):
        out[k] = lin(rec1[k], rec2[k])
    out["collective_bytes_per_chip"] = {
        k: lin(rec1["collective_bytes_per_chip"][k],
               rec2["collective_bytes_per_chip"][k])
        for k in rec1["collective_bytes_per_chip"]}
    out["collective_counts"] = {
        k: int(lin(rec1["collective_counts"][k],
                   rec2["collective_counts"][k]))
        for k in rec1["collective_counts"]}
    out["compute_s"] = out["hlo_flops_per_chip"] / hw.PEAK_FLOPS_BF16
    out["memory_s"] = out["hlo_bytes_per_chip"] / hw.HBM_BW
    out["collective_s"] = out["wire_bytes_per_chip"] / hw.ICI_BW
    out["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: out[k])
    if mem_rec is not None:
        for k in ("memory", "peak_bytes_per_chip", "fits_hbm"):
            out[k] = mem_rec[k]
    out["extrapolated_from_probes"] = True
    if out.get("model_flops_per_chip"):
        out["useful_flops_ratio"] = (out["model_flops_per_chip"]
                                     / max(out["hlo_flops_per_chip"], 1.0))
    return out


def model_flops(cfg, shape, train: bool = True,
                db_concat: bool = False) -> float:
    """MODEL_FLOPS = 6·N(_active)·D for training, 2·N·D for inference
    (forward only), per step over the GLOBAL batch."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if db_concat:
            tokens *= 2          # clean‖noisy concat doubles processed tokens
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def format_row(name: str, rec: Dict) -> str:
    return (f"{name:48s} comp={rec['compute_s']*1e3:9.3f}ms "
            f"mem={rec['memory_s']*1e3:9.3f}ms "
            f"coll={rec['collective_s']*1e3:9.3f}ms "
            f"dom={rec['dominant'][:-2]:10s} "
            f"useful={rec.get('useful_flops_ratio', 0):6.3f} "
            f"fits={rec['fits_hbm']}")
