"""TPU v5e hardware constants (per chip) for the roofline terms."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link
# 2D torus: 4 links/chip; a ring collective drives ~2 links concurrently
# (one per direction). Documented assumption — see DESIGN.md §6.
ICI_LINKS_EFFECTIVE = 2
ICI_BW = ICI_BW_PER_LINK * ICI_LINKS_EFFECTIVE   # 100 GB/s per chip
HBM_PER_CHIP = 16e9             # v5e: 16 GB
