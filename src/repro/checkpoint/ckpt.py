"""Checkpointing: npz-based pytree save/restore with path-flattened keys,
plus BLOCK-WISE checkpoints — each DiffusionBlocks block saves/restores its
unit slice independently, which is what block-parallel training across pods
needs (each pod writes only its block; a merge step assembles the full model).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz has no bf16; widen (load_pytree
            arr = arr.astype(np.float32)  # casts back to the template dtype)
        flat[key] = arr
    return flat


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def load_pytree(path: str, template) -> Any:
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = jnp.asarray(data[key])
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def load_metadata(path: str) -> Optional[dict]:
    meta = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)
    return None


# ---------------------------------------------------------------------------
# Block-wise checkpoints (DiffusionBlocks)
# ---------------------------------------------------------------------------
STACK_KEYS = ("layers", "units")


def save_block(ckpt_dir: str, params, block: int, start: int, size: int,
               step: int = 0) -> str:
    """Save block ``block``'s unit slice + the shared periphery."""
    from repro.core.training import extract_block_view
    view = extract_block_view(params, start, size)
    path = os.path.join(ckpt_dir, f"block_{block:02d}.npz")
    save_pytree(path, view, {"block": block, "start": start, "size": size,
                             "step": step})
    return path


def save_block_opt(ckpt_dir: str, block: int, opt_state, step: int = 0) -> str:
    """Save one block's optimizer state (AdamW moments + step) — written by
    the block-parallel trainer so each pod's block resumes independently."""
    path = os.path.join(ckpt_dir, f"block_{block:02d}.opt.npz")
    save_pytree(path, opt_state, {"block": block, "step": step})
    return path


def load_block_opt(ckpt_dir: str, block: int, template) -> Optional[Any]:
    """Restore one block's optimizer state; None when absent (fresh init)."""
    path = os.path.join(ckpt_dir, f"block_{block:02d}.opt.npz")
    if not os.path.exists(path):
        return None
    return load_pytree(path, template)


def load_blocks(ckpt_dir: str, params_template, ranges) -> Any:
    """Assemble a full model from per-block checkpoints (shared periphery is
    taken from the highest-numbered block present)."""
    from repro.core.training import (extract_block_view,
                                     write_back_block_view)
    params = params_template
    for b, (start, size) in enumerate(ranges):
        path = os.path.join(ckpt_dir, f"block_{b:02d}.npz")
        if not os.path.exists(path):
            continue
        tmpl = extract_block_view(params, start, size)
        view = load_pytree(path, tmpl)
        params = write_back_block_view(params, view, start)
    return params
