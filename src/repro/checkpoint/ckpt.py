"""Checkpointing: npz-based pytree save/restore with path-flattened keys,
plus BLOCK-WISE checkpoints — each DiffusionBlocks block saves/restores its
unit slice independently, which is what block-parallel training across pods
needs (each pod writes only its block; a merge step assembles the full model).

Crash consistency
-----------------
Every write in this module is ATOMIC: the payload goes to a temp file in the
destination directory, is fsync'd, and only then renamed over the final path
(``os.replace`` — atomic on POSIX). A crash mid-save therefore never leaves a
truncated ``.npz`` under the real name; readers see either the old complete
file or the new complete file. A file that is nonetheless unreadable (torn by
a pre-atomic writer, bit rot, truncation by an injected ``ckpt_corrupt``
fault) raises ``CheckpointCorrupt`` with the offending path — never a raw
zipfile/KeyError traceback.

On top of the atomic primitives, ``CheckpointManager`` provides VERSIONED
GENERATIONS for fault-tolerant training (``repro.launch.trainrunner``): each
save writes a fresh ``gen_NNNNNN/`` directory of npz files, then atomically
publishes ``MANIFEST-NNNNNN.json`` carrying the training step, rng state,
data-loader cursor, guard counters, and a sha256 per file. ``load_latest``
verifies every checksum and falls back to the previous generation when any
file of the newest one is corrupt — a torn or rotted checkpoint is DETECTED,
not silently loaded.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """Base class for checkpoint failures."""


class CheckpointCorrupt(CheckpointError):
    """A checkpoint file exists but cannot be read back (truncated archive,
    missing key, checksum mismatch). The message names the file and the
    remedy: delete it (flat layout) or let the manifest loader fall back to
    the previous generation (managed layout)."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz has no bf16; widen (load_pytree
            arr = arr.astype(np.float32)  # casts back to the template dtype)
        flat[key] = arr
    return flat


def _atomic_write(path: str, write_fn: Callable[[Any], None],
                  mode: str = "wb") -> None:
    """Write via temp-file + fsync + rename so ``path`` is never torn."""
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def tree_digest(tree) -> str:
    """sha256 over every leaf's bytes (sorted by flattened key) — two trees
    share a digest iff they are BIT-identical. The resume-parity gate
    compares params and optimizer state this way."""
    h = hashlib.sha256()
    flat = _flatten(tree)
    for k in sorted(flat):
        arr = np.ascontiguousarray(flat[k])
        h.update(k.encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    if not path.endswith(".npz"):
        path = path + ".npz"
    flat = _flatten(tree)
    _atomic_write(path, lambda f: np.savez(f, **flat))
    if metadata is not None:
        meta = path[:-4] + ".meta.json"
        _atomic_write(meta, lambda f: f.write(json.dumps(metadata)), "w")


def load_pytree(path: str, template) -> Any:
    if not path.endswith(".npz"):
        path = path + ".npz"
    try:
        data = np.load(path)
    except Exception as e:
        raise CheckpointCorrupt(
            f"checkpoint {path!r} is unreadable ({type(e).__name__}: {e}) — "
            f"likely a torn write from a crashed run; delete the file or "
            f"resume from an earlier manifest generation") from e
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        try:
            arr = jnp.asarray(data[key])
        except Exception as e:
            raise CheckpointCorrupt(
                f"checkpoint {path!r} is missing or cannot decode key "
                f"{key!r} ({type(e).__name__}) — the archive is incomplete; "
                f"delete the file or resume from an earlier manifest "
                f"generation") from e
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def load_metadata(path: str) -> Optional[dict]:
    meta = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)
    return None


# ---------------------------------------------------------------------------
# Block-wise checkpoints (DiffusionBlocks)
# ---------------------------------------------------------------------------
STACK_KEYS = ("layers", "units")


def save_block(ckpt_dir: str, params, block: int, start: int, size: int,
               step: int = 0) -> str:
    """Save block ``block``'s unit slice + the shared periphery."""
    from repro.core.training import extract_block_view
    view = extract_block_view(params, start, size)
    path = os.path.join(ckpt_dir, f"block_{block:02d}.npz")
    save_pytree(path, view, {"block": block, "start": start, "size": size,
                             "step": step})
    return path


def save_block_opt(ckpt_dir: str, block: int, opt_state, step: int = 0) -> str:
    """Save one block's optimizer state (AdamW moments + step) — written by
    the block-parallel trainer so each pod's block resumes independently."""
    path = os.path.join(ckpt_dir, f"block_{block:02d}.opt.npz")
    save_pytree(path, opt_state, {"block": block, "step": step})
    return path


def load_block_opt(ckpt_dir: str, block: int, template) -> Optional[Any]:
    """Restore one block's optimizer state; None when absent (fresh init)."""
    path = os.path.join(ckpt_dir, f"block_{block:02d}.opt.npz")
    if not os.path.exists(path):
        return None
    return load_pytree(path, template)


def load_blocks(ckpt_dir: str, params_template, ranges) -> Any:
    """Assemble a full model from per-block checkpoints (shared periphery is
    taken from the highest-numbered block present)."""
    from repro.core.training import (extract_block_view,
                                     write_back_block_view)
    params = params_template
    for b, (start, size) in enumerate(ranges):
        path = os.path.join(ckpt_dir, f"block_{b:02d}.npz")
        if not os.path.exists(path):
            continue
        tmpl = extract_block_view(params, start, size)
        view = load_pytree(path, tmpl)
        params = write_back_block_view(params, view, start)
    return params


# ---------------------------------------------------------------------------
# Versioned manifest generations (fault-tolerant training)
# ---------------------------------------------------------------------------
MANIFEST_PREFIX = "MANIFEST-"


class CheckpointManager:
    """Generational checkpoints under one directory:

        ckpt_dir/
          gen_000001/<name>.npz ...     one npz per named pytree
          MANIFEST-000001.json          published LAST (atomic rename)
          gen_000002/...
          MANIFEST-000002.json

    The manifest carries the caller's ``state`` payload (training step, rng,
    data cursor, guard counters, periphery policy — anything JSON) plus a
    sha256 per file. A generation is only visible once its manifest exists,
    and only loadable when every file passes its checksum, so a crash at ANY
    point of ``save`` (or corruption after it) degrades to "the previous
    generation loads" rather than "the run is poisoned".

    ``faults``: an optional ``repro.launch.faults.FaultInjector``; the
    ``ckpt_corrupt`` hook (consulted once per save) truncates one freshly
    written file AFTER the manifest publish — the exact torn-write the
    checksum fallback exists to catch.
    """

    def __init__(self, ckpt_dir: str, keep: int = 2, faults=None):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.faults = faults
        os.makedirs(ckpt_dir, exist_ok=True)

    # -- layout helpers -----------------------------------------------------
    def _manifest_path(self, gen: int) -> str:
        return os.path.join(self.ckpt_dir, f"{MANIFEST_PREFIX}{gen:06d}.json")

    def _gen_dir(self, gen: int) -> str:
        return os.path.join(self.ckpt_dir, f"gen_{gen:06d}")

    def generations(self):
        """Published generation numbers, ascending (manifest exists)."""
        out = []
        for name in os.listdir(self.ckpt_dir):
            if name.startswith(MANIFEST_PREFIX) and name.endswith(".json"):
                try:
                    out.append(int(name[len(MANIFEST_PREFIX):-5]))
                except ValueError:
                    continue
        return sorted(out)

    # -- save ---------------------------------------------------------------
    def save(self, trees: Dict[str, Any], state: dict) -> int:
        """Write one generation: every named pytree, then the manifest.
        Returns the generation number."""
        gens = self.generations()
        gen = (gens[-1] + 1) if gens else 1
        gdir = self._gen_dir(gen)
        os.makedirs(gdir, exist_ok=True)
        files = {}
        for name, tree in trees.items():
            fname = f"{name}.npz"
            save_pytree(os.path.join(gdir, fname), tree)
            files[fname] = file_sha256(os.path.join(gdir, fname))
        manifest = {"generation": gen, "dir": os.path.basename(gdir),
                    "files": files, "state": state}
        _atomic_write(self._manifest_path(gen),
                      lambda f: f.write(json.dumps(manifest, indent=1)), "w")
        if self.faults is not None:
            # torn write: truncate one file of the generation we just
            # published — load_latest must detect it and fall back
            self.faults.maybe_corrupt(
                "ckpt_corrupt", os.path.join(gdir, sorted(files)[0]))
        self._prune(keep_at_least=gen)
        return gen

    def _prune(self, keep_at_least: int) -> None:
        gens = self.generations()
        for g in gens[:-self.keep]:
            if g == keep_at_least:
                continue
            gdir = self._gen_dir(g)
            try:
                os.unlink(self._manifest_path(g))
                if os.path.isdir(gdir):
                    for f in os.listdir(gdir):
                        os.unlink(os.path.join(gdir, f))
                    os.rmdir(gdir)
            except OSError:
                pass                     # best-effort; never fail a save

    # -- load ---------------------------------------------------------------
    def verify(self, gen: int) -> bool:
        """All files of ``gen`` exist and match their manifest checksums."""
        try:
            with open(self._manifest_path(gen)) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return False
        gdir = self._gen_dir(gen)
        for fname, digest in manifest["files"].items():
            p = os.path.join(gdir, fname)
            if not os.path.exists(p) or file_sha256(p) != digest:
                return False
        return True

    def load_latest(self, templates: Dict[str, Any],
                    log=None) -> Tuple[Optional[Dict[str, Any]],
                                       Optional[dict]]:
        """Newest generation whose every file verifies → (trees, manifest);
        corrupt generations are skipped with a log line. (None, None) when
        nothing loadable exists."""
        for gen in reversed(self.generations()):
            if not self.verify(gen):
                if log:
                    log(f"[ckpt] generation {gen} failed checksum "
                        f"verification; falling back")
                continue
            with open(self._manifest_path(gen)) as f:
                manifest = json.load(f)
            gdir = self._gen_dir(gen)
            trees = {}
            try:
                for name, tmpl in templates.items():
                    trees[name] = load_pytree(
                        os.path.join(gdir, f"{name}.npz"), tmpl)
            except CheckpointCorrupt:
                if log:
                    log(f"[ckpt] generation {gen} unreadable despite "
                        f"checksum pass; falling back")
                continue
            return trees, manifest
        return None, None

    def load_tree(self, gen: int, name: str, template) -> Any:
        """One named pytree from one generation (per-block rewind)."""
        return load_pytree(os.path.join(self._gen_dir(gen), f"{name}.npz"),
                           template)

    def latest_good_generation(self) -> Optional[int]:
        for gen in reversed(self.generations()):
            if self.verify(gen):
                return gen
        return None


# -- rng key serialization (manifest-friendly) ------------------------------
def key_to_json(key) -> list:
    """PRNGKey → JSON list of uint32 words (bit-exact round-trip)."""
    return [int(x) for x in np.asarray(key).ravel()]


def key_from_json(words) -> jax.Array:
    return jnp.asarray(np.asarray(words, np.uint32))
