from repro.checkpoint.ckpt import (CheckpointCorrupt, CheckpointError,
                                   CheckpointManager, file_sha256,
                                   key_from_json, key_to_json, load_block_opt,
                                   load_blocks, load_metadata, load_pytree,
                                   save_block, save_block_opt, save_pytree,
                                   tree_digest)
