from repro.checkpoint.ckpt import (load_blocks, load_metadata, load_pytree,
                                   save_block, save_pytree)
