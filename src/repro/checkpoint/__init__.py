from repro.checkpoint.ckpt import (load_block_opt, load_blocks, load_metadata,
                                   load_pytree, save_block, save_block_opt,
                                   save_pytree)
