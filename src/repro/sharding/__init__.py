from repro.sharding.rules import (BLOCK_AXIS, batch_axes, block_parallel_mesh,
                                  block_state_specs, cache_sharding,
                                  param_shardings, replicated, spec_for_axes,
                                  tokens_sharding)
