from repro.sharding.rules import (batch_axes, cache_sharding,
                                  param_shardings, replicated,
                                  spec_for_axes, tokens_sharding)
