"""Logical-axis → mesh-axis sharding rules (megatron-style tensor parallel on
the ``model`` axis; batch on ``data`` (and ``pod`` in the multi-pod
data-parallel mode); DB block-parallel mode maps blocks to ``pod``).

Parameters carry logical axis names from their ParamSpecs
(repro.nn.init.logical_axes). A leaf is sharded on its FIRST dimension whose
logical axis maps to ``model`` and whose size divides the mesh axis — flat
projection dims (heads·hd, kv·hd, ff, vocab, experts) are all multiples of
the 16-way model axis for every assigned architecture.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis
LOGICAL_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "embed": None,        # d_model replicated (activations row-sharded on data)
    "layers": None,
    "inner": None,
    None: None,
}



def spec_for_axes(axes: Tuple[Optional[str], ...], mesh: Mesh,
                  shape: Optional[Tuple[int, ...]] = None,
                  max_shards: int = 1) -> P:
    """One sharded dim per param (tensor-parallel), rest replicated.

    Divisibility-aware: if the preferred dim does not divide the model axis
    (e.g. grok's 8 experts on a 16-way axis), the NEXT shardable dim is used
    instead of silently replicating — found via the baseline roofline (§Perf
    P4: grok's 309 B expert params were fully replicated)."""
    model_size = mesh.shape.get("model", 1)
    if os.environ.get("REPRO_NO_TP", "0") == "1":
        return P(*([None] * len(axes)))
    parts: list = [None] * len(axes)
    used = 0
    for i, ax in enumerate(axes):
        if used >= max_shards:
            break
        if LOGICAL_RULES.get(ax, None) != "model":
            continue
        if shape is not None and shape[i] % model_size != 0:
            continue                      # try the next shardable dim
        parts[i] = "model"
        used += 1
    return P(*parts)


def param_shardings(axes_tree: Any, mesh: Mesh, shapes_tree: Any = None):
    """NamedSharding tree matching a params tree (shapes enable the
    divisibility-aware dim selection)."""

    def one(axes, shape=None):
        return NamedSharding(mesh, spec_for_axes(axes, mesh, shape))

    if shapes_tree is None:
        return jax.tree_util.tree_map(
            one, axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda a, s: one(a, s.shape), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def zero1_shardings(axes_tree: Any, mesh: Mesh, shapes_tree: Any):
    """ZeRO-1-style optimizer-state sharding (beyond-paper §Perf P1): in
    addition to the tensor-parallel dim, shard the FIRST remaining divisible
    dim over ``data``. Grad reduction then lowers to reduce-scatter +
    all-gather instead of all-reduce, and optimizer memory drops by the data
    axis size."""
    data_size = mesh.shape.get("data", 1)

    def one(axes, s):
        base = spec_for_axes(axes, mesh, s.shape)
        parts = list(base) + [None] * (len(s.shape) - len(base))
        for i, dim in enumerate(s.shape):
            if parts[i] is None and dim % data_size == 0 and dim >= data_size:
                parts[i] = "data"
                break
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(
        one, axes_tree, shapes_tree, is_leaf=lambda x: isinstance(x, tuple))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the batch dimension (data-parallel).

    With REPRO_NO_TP=1 (pure data-parallel mode for sub-1B models, §Perf P3)
    the model axis would otherwise idle — fold it into the batch sharding."""
    axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if os.environ.get("REPRO_NO_TP", "0") == "1" and "model" in mesh.shape:
        axes = axes + ("model",)
    return axes


def tokens_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    axes = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % n != 0:
        # small-batch decode: try data only, else replicate
        if batch % mesh.shape["data"] == 0:
            return NamedSharding(mesh, P("data"))
        return NamedSharding(mesh, P(None))
    return NamedSharding(mesh, P(axes))


def cache_sharding(mesh: Mesh, cache_tree: Any, batch: int):
    """KV caches / SSM states: stacked (units, B, seqlen-or-state...).
    Batch → data(+pod) when divisible; otherwise the cache SEQUENCE dim is
    sharded on data (sequence parallelism for long_500k batch=1); kv-head or
    head dims go to model when divisible."""
    model = mesh.shape.get("model", 1)
    baxes = batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    data = mesh.shape["data"]

    def one(x):
        shape = x.shape
        parts: list = [None] * len(shape)
        # dim 0 = units (replicated); dim 1 = batch
        if len(shape) >= 2:
            if shape[1] % nb == 0:
                parts[1] = baxes
            elif shape[1] % data == 0:
                parts[1] = "data"
            elif len(shape) >= 3 and shape[2] % data == 0:
                parts[2] = "data"            # sequence-parallel cache
        # shard a later dim (kv heads / head_dim / state) on model
        for i in range(2, len(shape)):
            if parts[i] is None and shape[i] % model == 0 and shape[i] >= model:
                parts[i] = "model"
                break
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# DiffusionBlocks block-parallel mode (repro.parallel)
# ---------------------------------------------------------------------------
# Blocks are gradient-isolated (paper §3), so the ``pod`` axis carries one
# block per pod group with ZERO optimizer collectives across it — the only
# cross-pod traffic is the periphery sync chosen by the trainer's policy.
BLOCK_AXIS = "pod"


def block_parallel_mesh(num_blocks: int, devices=None) -> Optional[Mesh]:
    """(pod=num_blocks, data=n//num_blocks) mesh over the first pod·data
    devices, or ``None`` when the host cannot give every block its own pod
    group — the trainer then degrades to the round-robin schedule."""
    devices = list(jax.devices() if devices is None else devices)
    if num_blocks < 1 or len(devices) < num_blocks:
        return None
    data = len(devices) // num_blocks
    grid = np.asarray(devices[:num_blocks * data],
                      dtype=object).reshape(num_blocks, data)
    return Mesh(grid, (BLOCK_AXIS, "data"))


def block_state_specs() -> dict:
    """PartitionSpecs for the block-parallel training state: leaves stacked
    over the leading block axis shard on ``pod``; the shared periphery (and
    its optimizer state) is replicated; tokens are batch-sharded on ``data``
    and replicated across pods (every block trains on the full batch)."""
    return {
        "stacked": P(BLOCK_AXIS),
        "replicated": P(),
        "tokens": P("data"),
    }
