"""LR schedules: linear warmup + cosine decay (paper App. E.1)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant(base_lr: float):
    return lambda step: jnp.full((), base_lr, jnp.float32)
