from repro.optim.adamw import (AdamWState, adamw, apply_updates,
                               clip_by_global_norm, global_norm)
from repro.optim.schedules import constant, warmup_cosine
