"""AdamW built from scratch (no optax offline). Optax-like (init, update)
pair over arbitrary pytrees, with decoupled weight decay and global-norm
clipping. Optimizer state is a pytree shaped like params — it shards with the
same NamedSharding as the params (ZeRO-style for free under pjit).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw(lr: Callable[[jax.Array], jax.Array] | float, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: Optional[float] = None):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(z, params),
                          jax.tree_util.tree_map(z, params))

    def update(grads, state: AdamWState, params):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step, mu, nu), {"grad_norm": gnorm,
                                                   "lr": lr_t}

    return init, update


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
