"""Mixture-of-Experts layer: top-k router + GShard-style capacity-based dense
dispatch. The one-hot dispatch/combine einsums let XLA SPMD lower the token
exchange to all-to-all when experts are sharded on the ``model`` mesh axis
(expert parallelism) and tokens on ``data``.

Tokens are processed in fixed-size *groups* (GShard G×S layout) so the one-hot
dispatch tensor stays O(group × E × capacity) instead of O(T × E × capacity):
with group=512, E=16, cap=1.25 the per-group dispatch tile is ~0.7 M elements.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.nn.init import ParamSpec

GROUP_SIZE = 512


def moe_spec(d: int, ff: int, cfg: MoEConfig, mlp_kind: str):
    E = cfg.num_experts
    spec = {
        "router": {"w": ParamSpec((d, E), ("embed", "experts"))},
        "wi": ParamSpec((E, d, ff), ("experts", "embed", "mlp")),
        "wo": ParamSpec((E, ff, d), ("experts", "mlp", "embed")),
    }
    if mlp_kind == "swiglu":
        spec["wg"] = ParamSpec((E, d, ff), ("experts", "embed", "mlp"))
    return spec


def _top_k_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (G, S, E) -> (sparse gates (G,S,E), aux load-balance loss)."""
    G, S, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)   # renormalize over top-k
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # (G,S,k,E)
    gates = jnp.sum(onehot * topv[..., None], axis=2)      # (G,S,E)
    # Switch-style load-balance aux loss (global over all tokens)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.max(onehot, axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return gates, aux


def moe_fwd(params, x: jax.Array, cfg: MoEConfig, mlp_kind: str,
            group_size: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.top_k
    g = group_size or min(GROUP_SIZE, T)
    while T % g:           # smoke-test shapes: shrink until it divides
        g //= 2
    G = T // g
    xt = x.reshape(G, g, d)
    logits = jnp.einsum("gsd,de->gse", xt, params["router"]["w"].astype(x.dtype))
    gates, aux = _top_k_gating(logits, k)                  # (G, g, E)

    capacity = max(int(cfg.capacity_factor * k * g / E), 1)

    sel = gates > 0                                        # (G, g, E)
    pos_in_expert = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1
    keep = sel & (pos_in_expert < capacity)
    disp = (keep[..., None]
            & (pos_in_expert[..., None] == jnp.arange(capacity)))  # (G,g,E,C)
    disp_f = disp.astype(x.dtype)
    combine = disp_f * gates.astype(x.dtype)[..., None]    # (G,g,E,C)

    expert_in = jnp.einsum("gsec,gsd->gecd", disp_f, xt)   # (G, E, C, d)

    wi, wo = params["wi"].astype(x.dtype), params["wo"].astype(x.dtype)
    if mlp_kind == "swiglu":
        wg = params["wg"].astype(x.dtype)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, wg)) * \
            jnp.einsum("gecd,edf->gecf", expert_in, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", expert_in, wi))
    expert_out = jnp.einsum("gecf,efd->gecd", h, wo)       # (G, E, C, d)

    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    return out.reshape(B, S, d), aux.astype(jnp.float32)
