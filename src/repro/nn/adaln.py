"""Noise-level conditioning (paper §3.1 Step 3): DiT-style AdaLN.

``sigma_embedding`` maps log σ through Fourier features + MLP to a conditioning
vector c; each layer owns an ``adaln`` head producing (shift, scale, gate) pairs
that modulate the pre-norm stream and gate the residual branch:

    h' = h + gate * f( norm(h) * (1 + scale) + shift )

With DB disabled the modulation params are absent and layers run vanilla.
The modulate+residual elementwise chain is the target of the fused Pallas
kernel in ``repro.kernels.fused_adaln``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.init import ParamSpec


def sigma_embed_spec(cond_dim: int, d_model: int):
    return {
        "mlp1": {"w": ParamSpec((cond_dim, d_model), (None, "mlp"))},
        "mlp2": {"w": ParamSpec((d_model, d_model), (None, "mlp"))},
    }


def fourier_features(log_sigma: jax.Array, dim: int) -> jax.Array:
    """log_sigma: (B,) -> (B, dim). EDM c_noise = log(σ)/4 convention applied
    by the caller; here we embed whatever scalar arrives."""
    half = dim // 2
    freqs = jnp.exp(jnp.linspace(0.0, 6.0, half))
    ang = log_sigma[..., None] * freqs
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def sigma_embedding(params, log_sigma: jax.Array, cond_dim: int,
                    dtype=jnp.float32) -> jax.Array:
    ff = fourier_features(log_sigma.astype(jnp.float32), cond_dim).astype(dtype)
    h = jax.nn.silu(ff @ params["mlp1"]["w"].astype(dtype))
    return jax.nn.silu(h @ params["mlp2"]["w"].astype(dtype))


def adaln_spec(d_model: int, n_mods: int = 6):
    """Per-layer modulation head: cond (d) -> n_mods * d (zero-init => identity).

    The output dim is sharded on the model axis ("mlp" rule): at qwen scale
    the per-layer head is d×6d ≈ 315 MB bf16 — replicating it across 64
    layers wasted ~20 GB/chip (found via the baseline roofline, §Perf P0)."""
    return {"w": ParamSpec((d_model, n_mods * d_model), (None, "mlp"),
                           "zeros"),
            "b": ParamSpec((n_mods * d_model,), ("mlp",), "zeros")}


def adaln_mods(params, cond: jax.Array, d_model: int,
               n_mods: int = 6) -> Tuple[jax.Array, ...]:
    """cond: (B, d) -> n_mods tensors of (B, 1, d) for broadcasting over S."""
    m = cond @ params["w"].astype(cond.dtype) + params["b"].astype(cond.dtype)
    return tuple(m[:, None, i * d_model:(i + 1) * d_model]
                 for i in range(n_mods))


def modulate(x: jax.Array, shift: Optional[jax.Array],
             scale: Optional[jax.Array],
             cond_mask: Optional[jax.Array] = None) -> jax.Array:
    """cond_mask: (S,) bool — positions where modulation applies (DB concat
    mode modulates only the noisy half; the clean context must stay
    σ-independent so its KV can be cached at inference)."""
    if shift is None:
        return x
    y = x * (1.0 + scale.astype(x.dtype)) + shift.astype(x.dtype)
    if cond_mask is None:
        return y
    return jnp.where(cond_mask[None, :, None], y, x)


def gate(residual: jax.Array, branch: jax.Array,
         g: Optional[jax.Array],
         cond_mask: Optional[jax.Array] = None,
         impl: str = "auto") -> jax.Array:
    """``impl="kernels"`` routes the unmasked σ-conditioned case through the
    fused Pallas gate+residual kernel (one VMEM pass, custom-VJP backward);
    the cond-masked concat path and the unconditioned case stay in jnp —
    the (B, d) gate vector cannot express a per-position mask."""
    if g is None:
        return residual + branch
    if impl == "kernels" and cond_mask is None and g.ndim == 3 \
            and g.shape[1] == 1:    # (B, 1, d) only — kernel gate is per-example
        from repro.kernels import ops as kops
        return kops.gate_residual(residual, branch, g[:, 0])
    gated = branch * (1.0 + g.astype(branch.dtype))
    if cond_mask is not None:
        gated = jnp.where(cond_mask[None, :, None], gated, branch)
    return residual + gated
