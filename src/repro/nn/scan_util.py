"""Unroll/remat-aware scan wrapper (see repro.runtime).

* REPRO_SCAN_UNROLL=1 — unroll scan bodies so XLA cost analysis counts true
  trip-count FLOPs (dry-run only).
* REPRO_LAYER_REMAT=1 — jax.checkpoint every scan body: per-layer activation
  checkpointing (saves only the layer inputs; recomputes the layer in the
  backward pass). Combined with DiffusionBlocks this realizes the paper's
  App. G analysis: remat cuts activations to O(1) per layer while DB cuts
  params/grads/optimizer to L/B — the two compose.
"""
from __future__ import annotations

import os

import jax

from repro import runtime


def layer_remat() -> bool:
    return os.environ.get("REPRO_LAYER_REMAT", "0") == "1"


def uscan(f, init, xs, length=None):
    if layer_remat():
        f = jax.checkpoint(f)
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=runtime.scan_unroll())
