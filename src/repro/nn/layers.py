"""Basic layers: linear, norms, rotary embeddings, positional encodings, MLP."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.nn.init import ParamSpec

# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def linear_spec(d_in: int, d_out: int, axes=( "embed", "mlp"), bias: bool = False,
                init: str = "normal", scale: float = 1.0):
    spec = {"w": ParamSpec((d_in, d_out), axes, init, scale)}
    if bias:
        spec["b"] = ParamSpec((d_out,), (axes[1],), "zeros")
    return spec


def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(d: int, kind: str):
    if kind == "rmsnorm":
        return {"g": ParamSpec((d,), (None,), "ones")}
    if kind == "layernorm":
        return {"g": ParamSpec((d,), (None,), "ones"),
                "b": ParamSpec((d,), (None,), "zeros")}
    if kind == "nonparam_ln":   # OLMo: no affine params
        return {}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["g"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["g"].astype(jnp.float32) + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:   # architecture without rope (whisper/vit/dit)
        return x
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper/ViT-style fixed sinusoidal table (S, d)."""
    return sinusoidal_at(jnp.arange(seq_len), d_model)


def sinusoidal_at(positions: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal embeddings at arbitrary (possibly traced, per-slot)
    positions: (...,) -> (..., d). The serving paths use this with each
    slot's own absolute offsets (ragged decode, chunked prefill)."""
    pos = positions.astype(jnp.float32)[..., None]
    half = d_model // 2
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (swiglu / gelu)
# ---------------------------------------------------------------------------

def mlp_spec(d: int, ff: int, kind: str):
    if kind == "swiglu":
        return {
            "wi": ParamSpec((d, ff), ("embed", "mlp")),
            "wg": ParamSpec((d, ff), ("embed", "mlp")),
            "wo": ParamSpec((ff, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, ff), ("embed", "mlp")),
        "wo": ParamSpec((ff, d), ("mlp", "embed")),
    }


def apply_mlp(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"].astype(x.dtype)) * (
            x @ params["wi"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ params["wi"].astype(x.dtype))
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / readout
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, scale: float = 0.02):
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), "embed", scale)}


def embed(params, tokens):
    return params["table"][tokens]


def l2_normalize_embeddings(table: jax.Array, eps: float = 1e-6) -> jax.Array:
    """App. C: L2-normalize embedding rows (anti embedding-collapse)."""
    n = jnp.linalg.norm(table.astype(jnp.float32), axis=-1, keepdims=True)
    return (table / jnp.maximum(n, eps)).astype(table.dtype)


def readout_spec(d: int, vocab: int):
    return {"w": ParamSpec((d, vocab), ("embed", "vocab"))}


def readout(params, x):
    return x @ params["w"].astype(x.dtype)
