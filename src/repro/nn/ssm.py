"""Mamba2 SSD layer, TPU-adapted.

GPU Mamba2 relies on a fused CUDA selective-scan. The TPU-native formulation
here is the *chunked SSD dual form*: within a chunk of length Q the recurrence
is evaluated as a masked quadratic (attention-like) contraction — MXU-friendly
matmuls — while chunk-boundary states are propagated with
``jax.lax.associative_scan`` over n_chunks elements only. Nothing of size
(S, heads, head_dim, d_state) is ever materialized.

State-space recurrence (per head, diagonal A):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t ⊗ x_t        h: (P, N)
    y_t = C_t · h_t + D * x_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.nn.init import ParamSpec
from repro.nn.scan_util import uscan

LOG_EPS = -30.0


def mamba2_spec(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    return {
        # in_proj produces [x (d_in), z gate (d_in), B (N), C (N), dt (H)]
        "in_proj": {"w": ParamSpec(
            (d_model, 2 * d_in + 2 * cfg.d_state + n_heads), ("embed", "heads"))},
        "conv_w": ParamSpec((cfg.d_conv, d_in + 2 * cfg.d_state),
                            (None, "heads"), "normal", 1.0),
        "conv_b": ParamSpec((d_in + 2 * cfg.d_state,), ("heads",), "zeros"),
        "a_log": ParamSpec((n_heads,), ("heads",), "uniform", 1.0),
        "dt_bias": ParamSpec((n_heads,), ("heads",), "zeros"),
        "d_skip": ParamSpec((n_heads,), ("heads",), "ones"),
        "norm_g": ParamSpec((d_in,), ("heads",), "ones"),
        "out_proj": {"w": ParamSpec((d_in, d_model), ("heads", "embed"))},
    }


def _split_proj(proj, d_in, d_state, n_heads):
    xz, rest = proj[..., :2 * d_in], proj[..., 2 * d_in:]
    x, z = xz[..., :d_in], xz[..., d_in:]
    B = rest[..., :d_state]
    C = rest[..., d_state:2 * d_state]
    dt = rest[..., 2 * d_state:]
    return x, z, B, C, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u: (B,S,C), w: (K,C). prev: (B,K-1,C) history.

    Returns (out (B,S,C), new_history (B,K-1,C))."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], K - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([prev, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(K):
        out = out + ext[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
    new_hist = ext[:, -(K - 1):] if K > 1 else prev
    return jax.nn.silu(out + b.astype(u.dtype)), new_hist


def _chunk_scan(x, dt, a_log, Bmat, Cmat, chunk: int,
                h0: Optional[jax.Array] = None, *,
                strict: bool = False):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); Bmat/Cmat: (B,S,N).

    ``strict=True`` computes y_i = C_i · h_{i-1} (history EXCLUDING token i,
    decayed only through i-1) — used by the DB two-pass AR adapter, where C may
    come from the noisy stream while x/dt/B build the clean state.

    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))                 # (H,) negative
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bmat.reshape(Bsz, nc, chunk, N)
    Cc = Cmat.reshape(Bsz, nc, chunk, N)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1 if strict else 0)
    init_state = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
                  else h0.astype(jnp.float32))

    # Sequential scan over chunks: only ONE (B, Q, Q, H) decay tile is live at
    # a time (the batched form materialized (B, nc, Q, Q, H) — 15 GB for
    # zamba2 at 4k). Intra-chunk work stays MXU-friendly matmuls.
    def one_chunk(h_prev, xs):
        xci, dti, Bci, Cci = xs                              # (B,Q,...) slices
        dAi = dti * A                                        # (B,Q,H)
        cum = jnp.cumsum(dAi, axis=1)
        total = cum[:, -1]                                   # (B,H)
        cum_q = cum - dAi if strict else cum
        diff = cum_q[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        L = jnp.where(mask[None, :, :, None],
                      jnp.exp(jnp.maximum(diff, LOG_EPS)), 0.0)
        CB = jnp.einsum("bin,bjn->bij", Cci.astype(jnp.float32),
                        Bci.astype(jnp.float32))             # (B,Q,Q)
        xdt = xci.astype(jnp.float32) * dti[..., None]       # (B,Q,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", CB, L, xdt)
        # inter-chunk: query the incoming state
        decay_in = jnp.exp(jnp.maximum(cum_q, LOG_EPS))      # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp",
                             Cci.astype(jnp.float32), h_prev, decay_in)
        # state update
        decay_to_end = jnp.exp(jnp.maximum(total[:, None] - cum, LOG_EPS))
        s_c = jnp.einsum("bjh,bjhp,bjn->bhpn", decay_to_end * dti,
                         xci.astype(jnp.float32), Bci.astype(jnp.float32))
        chunk_decay = jnp.exp(jnp.maximum(total, LOG_EPS))   # (B,H)
        h_new = chunk_decay[..., None, None] * h_prev + s_c
        return h_new, y_intra + y_inter

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3))
    h_final, ys = uscan(one_chunk, init_state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, H, P)[:, :S]
    return y, h_final


def mamba2_fwd(params, u: jax.Array, cfg: SSMConfig, d_model: int,
               state=None) -> Tuple[jax.Array, dict]:
    """Full-sequence forward. u: (B,S,d_model). Returns (out, new_state)."""
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    P, N = cfg.head_dim, cfg.d_state
    proj = u @ params["in_proj"]["w"].astype(u.dtype)
    x, z, Bm, Cm, dt = _split_proj(proj, d_in, N, H)
    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    prev = state["conv"] if state is not None else None
    conv_out, conv_hist = _causal_conv(conv_in, params["conv_w"],
                                       params["conv_b"], prev)
    x = conv_out[..., :d_in]
    Bm = conv_out[..., d_in:d_in + N]
    Cm = conv_out[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    xh = x.reshape(*x.shape[:2], H, P)
    h0 = state["h"] if state is not None else None
    y, h_final = _chunk_scan(xh, dt, params["a_log"], Bm, Cm, cfg.chunk_size, h0)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_in).astype(u.dtype)
    # gated RMSNorm (mamba2 style)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm_g"].astype(jnp.float32)).astype(u.dtype)
    out = y @ params["out_proj"]["w"].astype(u.dtype)
    return out, {"h": h_final, "conv": conv_hist}


def mamba2_two_pass(params, u_clean: jax.Array, u_noisy: jax.Array,
                    cfg: SSMConfig, d_model: int) -> Tuple[jax.Array, jax.Array]:
    """DB two-pass AR adaptation for an SSM layer (paper App. E.4 alternative).

    Clean stream runs the standard recurrence. Each noisy token i is denoised
    by a one-step update from the clean state h_{i-1}:

        h_i^noisy = exp(dt_i^n A) h_{i-1}^clean + dt_i^n B_i^n ⊗ x_i^n
        y_i^noisy = C_i^n · h_i^noisy + D x_i^n

    C_i^n · h_{i-1}^clean is evaluated for ALL i in parallel via the chunked
    scan in strict mode with the noisy C as the output contraction — no
    (S, H, P, N) state tensor is ever materialized.

    Returns (y_clean (B,S,d), y_noisy (B,S,d)).
    """
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    P, N = cfg.head_dim, cfg.d_state
    W = params["in_proj"]["w"]

    def proj_split(u):
        return _split_proj(u @ W.astype(u.dtype), d_in, N, H)

    xc, zc, Bc, Cc, dtc = proj_split(u_clean)
    xn, zn, Bn, Cn, dtn = proj_split(u_noisy)

    # causal conv: clean standard; noisy token i gets clean history i-K+1..i-1
    # plus its own current input -> conv(clean) - w_last*clean_i + w_last*noisy_i
    conv_c_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_n_in = jnp.concatenate([xn, Bn, Cn], axis=-1)
    # pre-activation conv so the noisy correction composes before the silu
    K = params["conv_w"].shape[0]
    prev = jnp.zeros((u_clean.shape[0], K - 1, conv_c_in.shape[-1]),
                     conv_c_in.dtype)
    ext = jnp.concatenate([prev, conv_c_in], axis=1)
    lin_c = jnp.zeros_like(conv_c_in)
    for i in range(K):
        lin_c = lin_c + ext[:, i:i + conv_c_in.shape[1]] * \
            params["conv_w"][i].astype(conv_c_in.dtype)
    lin_c = lin_c + params["conv_b"].astype(conv_c_in.dtype)
    w_last = params["conv_w"][K - 1].astype(conv_c_in.dtype)
    lin_n = lin_c - conv_c_in * w_last + conv_n_in * w_last
    conv_n = jax.nn.silu(lin_n)
    conv_c = jax.nn.silu(lin_c)

    def unpack(co):
        return co[..., :d_in], co[..., d_in:d_in + N], co[..., d_in + N:]

    xc_, Bc_, Cc_ = unpack(conv_c)
    xn_, Bn_, Cn_ = unpack(conv_n)
    dtc_ = jax.nn.softplus(dtc.astype(jnp.float32)
                           + params["dt_bias"].astype(jnp.float32))
    dtn_ = jax.nn.softplus(dtn.astype(jnp.float32)
                           + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xch = xc_.reshape(*xc_.shape[:2], H, P)
    xnh = xn_.reshape(*xn_.shape[:2], H, P)

    # clean pass (standard)
    y_clean, _ = _chunk_scan(xch, dtc_, params["a_log"], Bc_, Cc_,
                             cfg.chunk_size)
    y_clean = y_clean + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xch.astype(jnp.float32)

    # history query: u_i = C_i^noisy · h_{i-1}^clean
    u_hist, _ = _chunk_scan(xch, dtc_, params["a_log"], Bc_, Cn_,
                            cfg.chunk_size, strict=True)
    decay_n = jnp.exp(jnp.maximum(dtn_ * A, LOG_EPS))        # (B,S,H)
    cb_self = jnp.einsum("bsn,bsn->bs", Cn_.astype(jnp.float32),
                         Bn_.astype(jnp.float32))            # (B,S)
    y_noisy = (decay_n[..., None] * u_hist
               + (dtn_ * cb_self[..., None])[..., None]
               * xnh.astype(jnp.float32))
    y_noisy = y_noisy + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xnh.astype(jnp.float32)

    def finish(y, z):
        y = y.reshape(*z.shape[:2], d_in).astype(u_clean.dtype)
        y = y * jax.nn.silu(z)
        var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
             * params["norm_g"].astype(jnp.float32)).astype(u_clean.dtype)
        return y @ params["out_proj"]["w"].astype(u_clean.dtype)

    return finish(y_clean, zc), finish(y_noisy, zn)


def mamba2_init_state(batch: int, cfg: SSMConfig, d_model: int,
                      dtype=jnp.float32) -> dict:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return {
        "h": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in + 2 * cfg.d_state), dtype),
    }


def mamba2_decode_step(params, u: jax.Array, cfg: SSMConfig, d_model: int,
                       state: dict) -> Tuple[jax.Array, dict]:
    """Single-token decode: O(1) state update. u: (B,1,d_model)."""
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    P, N = cfg.head_dim, cfg.d_state
    proj = u @ params["in_proj"]["w"].astype(u.dtype)
    x, z, Bm, Cm, dt = _split_proj(proj, d_in, N, H)
    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_out, conv_hist = _causal_conv(conv_in, params["conv_w"],
                                       params["conv_b"], state["conv"])
    x = conv_out[..., :d_in]
    Bm = conv_out[..., d_in:d_in + N]
    Cm = conv_out[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,1,H)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = x.reshape(-1, H, P).astype(jnp.float32)              # (B,H,P)
    dt1 = dt[:, 0]                                            # (B,H)
    decay = jnp.exp(dt1 * A)                                  # (B,H)
    inc = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, Bm[:, 0].astype(jnp.float32))
    h = state["h"] * decay[..., None, None] + inc
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, 1, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm_g"].astype(jnp.float32)).astype(u.dtype)
    out = y @ params["out_proj"]["w"].astype(u.dtype)
    return out, {"h": h, "conv": conv_hist}
