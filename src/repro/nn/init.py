"""Parameter-spec machinery.

Every layer declares a *spec tree*: a nested dict whose leaves are
``ParamSpec(shape, axes, init, scale)``. From one spec tree we derive
  * the initialized parameter pytree (``init_params``),
  * the logical-axis pytree for sharding (``logical_axes``),
  * abstract shapes for dry-run lowering (``spec_shapes``).

Keeping shapes/axes/init in one place means the sharding rules can never drift
out of sync with the parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes                       # logical axis names; len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed | uniform
    scale: float = 1.0               # multiplier on the fan-in init std

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in_std(shape: Tuple[int, ...]) -> float:
    # fan-in = product of all but the last dim (weights stored (in..., out)).
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_params(rng: jax.Array, spec_tree: Any, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for key, spec in zip(rngs, leaves):
        if spec.init == "zeros":
            p = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            p = jnp.ones(spec.shape, dtype)
        elif spec.init == "embed":
            p = jax.random.normal(key, spec.shape, dtype) * spec.scale
        elif spec.init == "uniform":
            p = jax.random.uniform(key, spec.shape, dtype, -1.0, 1.0) * spec.scale
        else:  # normal: fan-in scaled
            std = _fan_in_std(spec.shape) * spec.scale
            p = jax.random.normal(key, spec.shape, dtype) * std
        out.append(p)
    return jax.tree_util.tree_unflatten(treedef, out)


def logical_axes(spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def spec_shapes(spec_tree: Any, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree,
        is_leaf=_is_spec)


def stack_specs(spec_tree: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Prepend a leading stacking dim (for lax.scan over homogeneous layers)."""
    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale)
    return jax.tree_util.tree_map(stack, spec_tree, is_leaf=_is_spec)


def slice_tree(params: Any, start: int, size: int) -> Any:
    """Slice a stacked-param tree along the leading (layers) dim."""
    return jax.tree_util.tree_map(
        lambda p: jax.lax.dynamic_slice_in_dim(p, start, size, axis=0), params)


def index_tree(params: Any, i) -> Any:
    return jax.tree_util.tree_map(lambda p: p[i], params)
