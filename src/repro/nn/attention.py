"""Attention: GQA projections, masks (causal / sliding-window / bidirectional /
custom), a chunked flash-style implementation in pure JAX (lowers on every
backend with O(S * chunk) memory — this is what the distributed dry-run uses),
naive reference, and KV-cache decode (with ring buffer for SWA).

The Pallas TPU kernel lives in ``repro.kernels.flash_attention``; it is the
hardware-target implementation, validated against these in interpret mode.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.nn.init import ParamSpec
from repro.nn.layers import apply_rope

NEG_INF = -1e30

MaskMod = Callable[[jax.Array, jax.Array], jax.Array]  # (qpos, kpos) -> bool


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def causal_mask(qpos, kpos):
    return kpos[None, :] <= qpos[:, None]


causal_mask.lower_tri = True   # every attended key satisfies kp <= qp
# (kind, window, mask_seq) routing tag for the Pallas kernel (kernels/ops.py)
causal_mask.kernel_mask = ("causal", None, None)


def sliding_window_mask(window: int):
    def mask(qpos, kpos):
        k, q = kpos[None, :], qpos[:, None]
        return (k <= q) & (k > q - window)
    mask.lower_tri = True
    mask.kernel_mask = ("window", window, None)
    return mask


def bidirectional_mask(qpos, kpos):
    return jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)


bidirectional_mask.kernel_mask = ("full", None, None)


def db_concat_mask(seq_len: int) -> MaskMod:
    """Paper App. E.4 causal-consistency mask for [clean || noisy] sequences.

    Positions 0..S-1 are clean tokens, S..2S-1 are noisy tokens (position i+S is
    the noisy copy of token i).
      * clean i attends causally to clean j <= i (standard AR half);
      * noisy i+S attends to clean j < i (strictly the clean PAST — never clean
        token i itself, which would leak the denoising target) and to itself.
    """
    S = seq_len

    def mask(qpos, kpos):
        q = qpos[:, None]
        k = kpos[None, :]
        q_clean = q < S
        k_clean = k < S
        clean_clean = q_clean & k_clean & (k <= q)
        noisy_clean = (~q_clean) & k_clean & (k < q - S)
        noisy_self = (~q_clean) & (k == q)
        return clean_clean | noisy_clean | noisy_self
    mask.lower_tri = True   # all attended keys satisfy kp <= qp
    mask.kernel_mask = ("db_concat", None, S)
    return mask


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attention_spec(d_model: int, dims: AttnDims, qkv_bias: bool = False):
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    spec = {
        "wq": ParamSpec((d_model, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d_model, kv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d_model, kv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d_model), ("heads", "embed")),
    }
    if qkv_bias:
        spec["bq"] = ParamSpec((h * hd,), ("heads",), "zeros")
        spec["bk"] = ParamSpec((kv * hd,), ("kv_heads",), "zeros")
        spec["bv"] = ParamSpec((kv * hd,), ("kv_heads",), "zeros")
    return spec


def project_qkv(params, x, dims: AttnDims, kv_x=None):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S_kv,KV,hd)."""
    B, S, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    S_kv = kv_x.shape[1]
    q = x @ params["wq"].astype(x.dtype)
    k = kv_x @ params["wk"].astype(x.dtype)
    v = kv_x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, dims.n_heads, dims.head_dim)
    k = k.reshape(B, S_kv, dims.n_kv_heads, dims.head_dim)
    v = v.reshape(B, S_kv, dims.n_kv_heads, dims.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math (GQA-aware)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> scores (B, KV, G, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * scale


def _gqa_combine(weights, v):
    """weights (B,KV,G,Sq,Sk), v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, KV, G, Sq, Sk = weights.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", weights, v)
    return out.reshape(B, Sq, KV * G, v.shape[-1])


def naive_attention(q, k, v, mask: Optional[jax.Array]) -> jax.Array:
    """Reference implementation. mask: (Sq, Sk) bool or None."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = _gqa_scores(q.astype(jnp.float32), k.astype(jnp.float32), scale)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    return _gqa_combine(weights, v.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(q, k, v, mask_mod: Optional[MaskMod], qpos, kpos,
                      q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Flash-style two-level chunked attention with online softmax.

    Memory: O(q_chunk * kv_chunk) score tiles; never materializes (Sq, Sk).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pad_q), constant_values=qpos[-1])
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad_k), constant_values=-10**9)  # masked out
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)
    qpos_c = qpos.reshape(nq, q_chunk)
    kpos_c = kpos.reshape(nk, kv_chunk)

    from repro import runtime
    unroll = runtime.scan_unroll()

    def one_q_chunk(args):
        qi, qp = args                     # (B,qc,KV,G,hd), (qc,)

        def kv_step(carry, kv_args):
            m, l, acc = carry
            ki, vi, kp = kv_args
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            kvalid = kp > -(10 ** 8)      # padded / invalid slots are sentinel
            if mask_mod is not None:
                msk = mask_mod(qp, kp) & kvalid[None, :]   # (qc, kvc)
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            else:
                s = jnp.where(kvalid[None, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kpos_c),
            unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # (B,KV,G,qc,hd)
        return out.transpose(0, 3, 1, 2, 4)             # (B,qc,KV,G,hd)

    # flash-attention-style rematerialization: recompute score tiles in the
    # backward pass instead of saving O(S·chunk) residuals per layer.
    one_q_chunk = jax.checkpoint(one_q_chunk)

    def q_step(_, args):
        return None, one_q_chunk(args)

    _, outs = jax.lax.scan(q_step, None,
                           (qc.transpose(1, 0, 2, 3, 4, 5), qpos_c),
                           unroll=unroll)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def chunked_attention_triangle(q, k, v, mask_mod, qpos, kpos,
                               q_chunk: int = 1024, kv_chunk: int = 1024):
    """Causal chunked attention with STRUCTURAL tile skipping (beyond-paper
    perf variant, §Perf iteration P1): the q-chunk loop is a Python loop with
    static kv slices [0 : (i+1)·C], so fully-masked future tiles are never
    computed — exact triangle FLOPs (the masked scan computes the full S²
    rectangle). Requires qpos/kpos to be the standard ascending ranges."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    assert Sq % q_chunk == 0 and Sq == Sk, "triangle path: aligned causal"
    nq = Sq // q_chunk
    outs = []
    for i in range(nq):
        qi = q[:, i * q_chunk:(i + 1) * q_chunk]
        hi = (i + 1) * q_chunk
        o = chunked_attention(qi, k[:, :hi], v[:, :hi], mask_mod,
                              qpos[i * q_chunk:(i + 1) * q_chunk],
                              kpos[:hi], q_chunk, kv_chunk)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def attend(q, k, v, *, mask_mod: Optional[MaskMod], qpos, kpos,
           impl: str = "auto", q_chunk: int = 1024, kv_chunk: int = 1024):
    """Dispatch between naive (small) and chunked (large / dry-run) attention."""
    Sq, Sk = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "naive" if (Sq * Sk <= 256 * 256) else "chunked"
    if impl == "naive":
        mask = mask_mod(qpos, kpos) if mask_mod is not None else None
        return naive_attention(q, k, v, mask)
    if impl == "triangle":
        return chunked_attention_triangle(q, k, v, mask_mod, qpos, kpos,
                                          q_chunk, kv_chunk)
    if impl == "chunked":
        import os
        if (os.environ.get("REPRO_CAUSAL_TRIANGLE", "0") == "1"
                and getattr(mask_mod, "lower_tri", False)
                and Sq == Sk and Sq % min(q_chunk, Sq) == 0):
            return chunked_attention_triangle(q, k, v, mask_mod, qpos, kpos,
                                              q_chunk, kv_chunk)
        return chunked_attention(q, k, v, mask_mod, qpos, kpos, q_chunk, kv_chunk)
    if impl in ("pallas", "kernels"):
        from repro.kernels import ops as kops
        # mask_mod=None means UNMASKED here (cross-attention) — route "full"
        return kops.flash_attention(q, k, v, mask_mod=mask_mod, qpos=qpos,
                                    kpos=kpos, causal=False)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + attend) and decode step
# ---------------------------------------------------------------------------

def attention_fwd(params, x, dims: AttnDims, *, positions, mask_mod,
                  kv_x=None, kv_positions=None, rope_positions=None,
                  impl="auto", q_chunk=1024, kv_chunk=1024):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    ``positions`` drive the mask; ``rope_positions`` (default: positions) drive
    rotary phases — they differ for the DB clean||noisy concat sequence, where
    the noisy copy of token i sits at mask-position S+i but rope-position i.

    Cross-attention (``kv_x`` given) applies NO rope to either side: the
    conditioning memory has no relative positions w.r.t. the text stream,
    and the serving decode path reads the precomputed (k, v) block with
    un-roped queries — roping q only at prefill would make a token's cross
    output depend on whether it was ingested or generated.
    """
    q, k, v = project_qkv(params, x, dims, kv_x)
    rpos = positions if rope_positions is None else rope_positions
    kpos = positions if kv_positions is None else kv_positions
    if kv_x is None:   # self-attention: rope on q and k
        q = apply_rope(q, rpos, dims.rope_theta)
        k = apply_rope(k, rpos, dims.rope_theta)
    out = attend(q, k, v, mask_mod=mask_mod, qpos=positions, kpos=kpos,
                 impl=impl, q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(*x.shape[:2], dims.n_heads * dims.head_dim)
    return out @ params["wo"].astype(x.dtype), (k, v)


def init_kv_cache(batch: int, cache_len: int, dims: AttnDims, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, cache_len, dims.n_kv_heads, dims.head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, dims.n_kv_heads, dims.head_dim), dtype),
    }


def decode_attention(params, x, dims: AttnDims, cache, pos, *,
                     window: Optional[int] = None, kv_chunk: int = 2048,
                     impl: str = "auto"):
    """One-token decode. x: (B, 1, d); cache k/v: (B, C, KV, hd); pos: scalar
    current absolute position. SWA uses a ring buffer of size C == window.

    ``impl="kernels"`` routes the attend through the split-KV Pallas
    flash-decode kernel (``repro.kernels.flash_decode``) by viewing the dense
    cache as pages with an identity table. The SWA ring buffer's slot→abs
    mapping has no static kernel mask, so that case is first UN-ROTATED into
    absolute order — a per-step O(window) gather, the same traffic the
    reference masked attend pays — and the window semantics collapse into the
    paged kernel's plain length mask.

    Returns (out, new_cache).
    """
    B = x.shape[0]
    C = cache["k"].shape[1]
    q, k, v = project_qkv(params, x, dims)
    posv = jnp.full((1,), pos)
    q = apply_rope(q, posv, dims.rope_theta)
    k = apply_rope(k, posv, dims.rope_theta)
    slot = pos % C if window is not None else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    if impl in ("pallas", "kernels"):
        from repro.nn import cache as KVC
        # attend committed tokens (< pos) from the OLD cache viewed as pages,
        # then fold in the fresh token's own (k, v) from the fp32 partials —
        # identical math to masked attention over the updated cache.
        if window is not None:
            # ring slot i holds abs pos p ≡ i (mod C). Gather the last
            # L = min(pos, window-1, C) committed in-window keys into
            # absolute order at logical [0, L): the kernel's length mask
            # (idx < L) then IS the sliding window.
            L = jnp.minimum(pos, min(window - 1, C))
            src = (pos - L + jnp.arange(C)) % C              # (C,) abs order
            k_lin = jnp.take(cache["k"], src, axis=1)
            v_lin = jnp.take(cache["v"], src, axis=1)
            lengths = jnp.full((B,), L, jnp.int32)
        else:
            k_lin, v_lin = cache["k"], cache["v"]
            lengths = jnp.full((B,), pos, jnp.int32)
        pages, table = KVC.dense_to_paged(k_lin, v_lin,
                                          KVC.DEFAULT_PAGE_SIZE * 8)
        qg = q[:, 0].reshape(B, dims.n_kv_heads, dims.q_per_kv, dims.head_dim)
        out = KVC.attend_paged(qg, pages, table, lengths, k[:, 0], v[:, 0],
                               impl=impl).astype(q.dtype)
    else:
        # validity: slot index corresponds to absolute position
        idx = jnp.arange(C)
        if window is not None:
            # ring: entry i holds abs pos p with p % C == i, p <= pos, pos-p < C
            abs_pos = pos - ((pos - idx) % C)
            valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)
        else:
            valid = idx <= pos
        kpos_arr = jnp.where(valid, idx if window is None else 0, -10**9)

        def mask(qp, kp):
            return (kp > -10**9)[None, :].repeat(qp.shape[0], 0)

        out = attend(q, new_k.astype(q.dtype), new_v.astype(q.dtype),
                     mask_mod=mask, qpos=posv, kpos=kpos_arr,
                     impl="chunked" if C > 4096 else "naive",
                     q_chunk=1, kv_chunk=kv_chunk)
    out = out.reshape(B, 1, dims.n_heads * dims.head_dim)
    return out @ params["wo"].astype(x.dtype), {"k": new_k, "v": new_v}
