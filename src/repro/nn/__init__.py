from repro.nn.init import (ParamSpec, init_params, logical_axes, spec_shapes,
                           stack_specs)
