"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable,
attention-like quadratic form for train/prefill + O(1) recurrent decode) and
sLSTM (scalar memory with recurrent gate connections, lax.scan over time).

Both use the stabilized exponential gating of the paper (log-domain max
stabilizer m_t).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from repro.nn.scan_util import uscan

from repro.configs.base import XLSTMConfig
from repro.nn.init import ParamSpec

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_spec(d_model: int, n_heads: int, cfg: XLSTMConfig):
    d_in = int(cfg.proj_factor * d_model)
    return {
        "up": {"w": ParamSpec((d_model, 2 * d_in), ("embed", "heads"))},
        "wq": ParamSpec((d_in, d_in), ("heads", "heads")),
        "wk": ParamSpec((d_in, d_in), ("heads", "heads")),
        "wv": ParamSpec((d_in, d_in), ("heads", "heads")),
        "wif": ParamSpec((d_in, 2 * n_heads), ("heads", None)),
        "bif": ParamSpec((2 * n_heads,), (None,), "zeros"),
        "norm_g": ParamSpec((d_in,), ("heads",), "ones"),
        "down": {"w": ParamSpec((d_in, d_model), ("heads", "embed"))},
    }


def _mlstm_parallel(q, k, v, log_i, log_f):
    """q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H). Quadratic stabilized form."""
    B, S, H, hd = q.shape
    lf_cum = jnp.cumsum(log_f, axis=1)                       # (B,S,H)
    # exponent E[t,s] = lf_cum_t - lf_cum_s + log_i_s   (s <= t)
    E = (lf_cum[:, :, None] - lf_cum[:, None, :] + log_i[:, None, :])
    mask = jnp.tril(jnp.ones((S, S), bool))
    E = jnp.where(mask[None, :, :, None], E, NEG)
    m = jnp.max(E, axis=2)                                   # (B,S,H)
    D = jnp.exp(E - m[:, :, None])                           # (B,S,S,H)
    scores = jnp.einsum("bthd,bshd->btsh", q, k) / (hd ** 0.5)
    Ct = scores * D
    n = jnp.maximum(jnp.abs(jnp.sum(Ct, axis=2)), jnp.exp(-m))  # (B,S,H)
    return jnp.einsum("btsh,bshd->bthd", Ct, v) / n[..., None]


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int = 256):
    """Chunked mLSTM: intra-chunk quadratic + sequential (C, n, m) state carry
    across chunks. Memory O(S * chunk) instead of O(S^2).

    All inputs f32. q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H)."""
    B, S, H, hd = q.shape
    pad = (-S) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    lic = log_i.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    lfc = log_f.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        C0, n0, m0 = carry                 # (B,H,hd,hd), (B,H,hd), (B,H)
        qi, ki, vi, li, lf = xs
        lf_cum = jnp.cumsum(lf, axis=1)    # (B,Q,H)
        # intra-chunk exponent
        E = lf_cum[:, :, None] - lf_cum[:, None, :] + li[:, None, :]
        E = jnp.where(tri[None, :, :, None], E, NEG)
        m_intra = jnp.max(E, axis=2)                        # (B,Q,H)
        m_inter = lf_cum + m0[:, None]                      # (B,Q,H)
        m_t = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(E - m_t[:, :, None])
        scores = jnp.einsum("bthd,bshd->btsh", qi, ki) / (hd ** 0.5)
        Ct = scores * D
        inter_w = jnp.exp(m_inter - m_t)                    # (B,Q,H)
        num = (jnp.einsum("btsh,bshd->bthd", Ct, vi)
               + inter_w[..., None] * jnp.einsum("bhvk,bthk->bthv", C0, qi))
        den_val = (jnp.sum(Ct, axis=2)
                   + inter_w * jnp.einsum("bhk,bthk->bth", n0, qi))
        den = jnp.maximum(jnp.abs(den_val), jnp.exp(-m_t))
        y = num / den[..., None]
        # end-of-chunk state
        lf_tot = lf_cum[:, -1]                              # (B,H)
        dk = lf_tot[:, None] - lf_cum + li                  # (B,Q,H) decay->end
        m_end = jnp.maximum(lf_tot + m0, jnp.max(dk, axis=1))
        w_end = jnp.exp(dk - m_end[:, None])                # (B,Q,H)
        k_s = ki / (hd ** 0.5)
        C_new = (jnp.exp(lf_tot + m0 - m_end)[..., None, None] * C0
                 + jnp.einsum("bqh,bqhv,bqhk->bhvk", w_end, vi, k_s))
        n_new = (jnp.exp(lf_tot + m0 - m_end)[..., None] * n0
                 + jnp.einsum("bqh,bqhk->bhk", w_end, k_s))
        return (C_new, n_new, m_end), y

    init = mlstm_init_state(B, H, H * hd)
    final, ys = uscan(step, init, (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, hd)
    return y[:, :S], final


def _mlstm_hist_raw(q, k, v, log_i, log_f, chunk: int = 256):
    """Strict-history query: for each position i return the stabilized triple
    (num_i, den_i, m_i) of querying q_i against the clean mLSTM state built
    from tokens j < i, decayed through f_{i-1} only (exclusive). Chunked, so
    memory is O(S * chunk). Shapes: num (B,S,H,hd), den/m (B,S,H)."""
    B, S, H, hd = q.shape
    pad = (-S) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    lic = log_i.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    lfc = log_f.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strict

    def step(carry, xs):
        C0, n0, m0 = carry
        qi, ki, vi, li, lf = xs
        lf_cum = jnp.cumsum(lf, axis=1)
        lf_excl = lf_cum - lf                               # decay through i-1
        E = lf_excl[:, :, None] - lf_cum[:, None, :] + li[:, None, :]
        E = jnp.where(tri[None, :, :, None], E, NEG)
        m_intra = jnp.max(E, axis=2)
        m_inter = lf_excl + m0[:, None]
        m_t = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(E - m_t[:, :, None])
        scores = jnp.einsum("bthd,bshd->btsh", qi, ki) / (hd ** 0.5)
        Ct = scores * D
        inter_w = jnp.exp(m_inter - m_t)
        num = (jnp.einsum("btsh,bshd->bthd", Ct, vi)
               + inter_w[..., None] * jnp.einsum("bhvk,bthk->bthv", C0, qi))
        den = (jnp.sum(Ct, axis=2)
               + inter_w * jnp.einsum("bhk,bthk->bth", n0, qi))
        # end-of-chunk state (inclusive, standard)
        lf_tot = lf_cum[:, -1]
        dk = lf_tot[:, None] - lf_cum + li
        m_end = jnp.maximum(lf_tot + m0, jnp.max(dk, axis=1))
        w_end = jnp.exp(dk - m_end[:, None])
        k_s = ki / (hd ** 0.5)
        C_new = (jnp.exp(lf_tot + m0 - m_end)[..., None, None] * C0
                 + jnp.einsum("bqh,bqhv,bqhk->bhvk", w_end, vi, k_s))
        n_new = (jnp.exp(lf_tot + m0 - m_end)[..., None] * n0
                 + jnp.einsum("bqh,bqhk->bhk", w_end, k_s))
        return (C_new, n_new, m_end), (num, den, m_t)

    init = mlstm_init_state(B, H, H * hd)
    _, (num, den, m) = uscan(step, init, (qc, kc, vc, lic, lfc))
    num = num.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, hd)[:, :S]
    den = den.transpose(1, 0, 2, 3).reshape(B, nc * chunk, H)[:, :S]
    m = m.transpose(1, 0, 2, 3).reshape(B, nc * chunk, H)[:, :S]
    return num, den, m


def _mlstm_recurrent_step(state, q, k, v, log_i, log_f):
    """One step. state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)). q/k/v: (B,H,hd)."""
    C, n, m = state
    hd = q.shape[-1]
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    k = k / (hd ** 0.5)
    C_new = f_p[..., None] * C + i_p[..., None] * v[..., :, None] * k[..., None, :]
    n_new = f_p * n + i_p * k
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)),
                      jnp.exp(-m_new))
    return (C_new, n_new, m_new), num / den[..., None]


def mlstm_fwd(params, x, n_heads: int, cfg: XLSTMConfig,
              state=None, return_state: bool = False):
    """x: (B,S,d). Parallel quadratic form (train) or chunked (prefill)."""
    B, S, _ = x.shape
    d_in = params["wq"].shape[0]
    q, k, v, log_i, log_f, z = _mlstm_project(params, x, n_heads)
    if S <= 512 and not return_state:
        y = _mlstm_parallel(q, k, v, log_i, log_f)
        final_state = None
    else:
        y, final_state = _mlstm_chunked(q, k, v, log_i, log_f)
    out = _mlstm_finish(params, y.reshape(B, S, d_in), z, x.dtype)
    return out, final_state


def mlstm_init_state(batch: int, n_heads: int, d_in: int):
    hd = d_in // n_heads
    return (jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            jnp.zeros((batch, n_heads, hd), jnp.float32),
            jnp.full((batch, n_heads), -1e30, jnp.float32))


def mlstm_decode_step(params, x, n_heads: int, cfg: XLSTMConfig,
                      state) -> Tuple[jax.Array, tuple]:
    """x: (B,1,d). O(1) recurrent update."""
    B = x.shape[0]
    d_in = params["wq"].shape[0]
    hd = d_in // n_heads
    up = x @ params["up"]["w"].astype(x.dtype)
    h, z = up[..., :d_in], up[..., d_in:]
    q = (h @ params["wq"].astype(x.dtype)).reshape(B, n_heads, hd)
    k = (h @ params["wk"].astype(x.dtype)).reshape(B, n_heads, hd)
    v = (h @ params["wv"].astype(x.dtype)).reshape(B, n_heads, hd)
    gif = (h @ params["wif"].astype(x.dtype)
           + params["bif"].astype(x.dtype)).astype(jnp.float32)
    log_i = gif[..., 0, :n_heads]
    log_f = jax.nn.log_sigmoid(gif[..., 0, n_heads:])
    new_state, y = _mlstm_recurrent_step(
        state, q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), log_i, log_f)
    y = y.reshape(B, 1, d_in)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm_g"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["down"]["w"].astype(x.dtype), new_state


def _mlstm_project(params, x, n_heads):
    B, S, _ = x.shape
    d_in = params["wq"].shape[0]
    hd = d_in // n_heads
    up = x @ params["up"]["w"].astype(x.dtype)
    h, z = up[..., :d_in], up[..., d_in:]
    q = (h @ params["wq"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    k = (h @ params["wk"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    v = (h @ params["wv"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    gif = (h @ params["wif"].astype(x.dtype)
           + params["bif"].astype(x.dtype)).astype(jnp.float32)
    log_i = gif[..., :n_heads]
    log_f = jax.nn.log_sigmoid(gif[..., n_heads:])
    return (q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), log_i, log_f, z)


def _mlstm_finish(params, y, z, x_dtype):
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm_g"].astype(jnp.float32)
    y = y.astype(x_dtype) * jax.nn.silu(z)
    return y @ params["down"]["w"].astype(x_dtype)


def mlstm_two_pass(params, x_clean, x_noisy, n_heads: int, cfg: XLSTMConfig):
    """DB two-pass: clean standard; noisy token i does one stabilized mLSTM
    step from the clean state at i-1 (queried via chunked strict-history scan).
    Returns (y_clean, y_noisy)."""
    B, S, _ = x_clean.shape
    d_in = params["wq"].shape[0]
    hd = d_in // n_heads
    qc, kc, vc, lic, lfc, zc = _mlstm_project(params, x_clean, n_heads)
    qn, kn, vn, lin, lfn, zn = _mlstm_project(params, x_noisy, n_heads)

    yc = (_mlstm_parallel(qc, kc, vc, lic, lfc) if S <= 512
          else _mlstm_chunked(qc, kc, vc, lic, lfc)[0])

    num_h, den_h, m_h = _mlstm_hist_raw(qn, kc, vc, lic, lfc)
    M = jnp.maximum(lfn + m_h, lin)                          # (B,S,H)
    w_hist = jnp.exp(lfn + m_h - M)
    w_self = jnp.exp(lin - M)
    self_score = jnp.einsum("bshd,bshd->bsh", qn, kn) / (hd ** 0.5)
    num = (w_hist[..., None] * num_h
           + (w_self * self_score)[..., None] * vn)
    den = jnp.maximum(jnp.abs(w_hist * den_h + w_self * self_score),
                      jnp.exp(-M))
    y_n = num / den[..., None]

    out_c = _mlstm_finish(params, yc.reshape(B, S, d_in), zc, x_clean.dtype)
    out_n = _mlstm_finish(params, y_n.reshape(B, S, d_in), zn, x_clean.dtype)
    return out_c, out_n


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_spec(d_model: int, n_heads: int, cfg: XLSTMConfig):
    hd = d_model // n_heads
    return {
        # input projections for gates i, f, z, o
        "wx": ParamSpec((d_model, 4 * d_model), ("embed", "heads")),
        # block-diagonal recurrent matrices per head, per gate
        "r": ParamSpec((4, n_heads, hd, hd), (None, "heads", None, None),
                       "normal", 1.0),
        "b": ParamSpec((4 * d_model,), ("heads",), "zeros"),
        "norm_g": ParamSpec((d_model,), (None,), "ones"),
        "up": {"w": ParamSpec((d_model, 2 * d_model), ("embed", "mlp"))},
        "down": {"w": ParamSpec((d_model, d_model), ("mlp", "embed"))},
    }


def slstm_init_state(batch: int, n_heads: int, d_model: int):
    hd = d_model // n_heads
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return (z, z, jnp.zeros((batch, n_heads), jnp.float32) + 1e-6,
            jnp.full((batch, n_heads), -1e30, jnp.float32))  # h, c, n, m


def _slstm_cell(params, xt, state, n_heads: int):
    """xt: (B, 4*d) pre-projected inputs. state: (h, c, n, m)."""
    h, c, n, m = state
    B = xt.shape[0]
    hd = h.shape[2]
    rec = jnp.einsum("ghij,bhj->bghi", params["r"].astype(jnp.float32), h)
    raw = xt.astype(jnp.float32).reshape(B, 4, n_heads, hd) \
        + rec + params["b"].astype(jnp.float32).reshape(4, n_heads, hd)
    i_t, f_t, z_t, o_t = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    # scalar gates per head (mean over head dim -> one gate per head)
    i_t = jnp.mean(i_t, axis=-1)
    f_t = jnp.mean(f_t, axis=-1)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n[..., None] + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / n_new
    return (h_new, c_new, n_new[..., 0], m_new), h_new


def _slstm_finish(params, hs, x_dtype):
    """hs: (B,S,H,hd) cell outputs -> block output (B,S,d)."""
    B, S = hs.shape[:2]
    d = hs.shape[2] * hs.shape[3]
    y = hs.reshape(B, S, d)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)
         * params["norm_g"].astype(jnp.float32)).astype(x_dtype)
    # small gated MLP after the cell (xLSTM post-up/down projection)
    up = y @ params["up"]["w"].astype(x_dtype)
    half = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :half]) * up[..., half:]
    return y @ params["down"]["w"].astype(x_dtype)


def slstm_fwd(params, x, n_heads: int, cfg: XLSTMConfig, state=None,
              return_states: bool = False):
    """x: (B,S,d): lax.scan over time."""
    B, S, d = x.shape
    xproj = x @ params["wx"].astype(x.dtype)                # (B,S,4d)
    if state is None:
        state = slstm_init_state(B, n_heads, d)

    def step(carry, xt):
        new, h = _slstm_cell(params, xt, carry, n_heads)
        return new, (new if return_states else h)

    final, out = jax.lax.scan(step, state, xproj.transpose(1, 0, 2))
    if return_states:
        states_seq, hs = out, out[0]
    else:
        states_seq, hs = None, out
    y = _slstm_finish(params, hs.transpose(1, 0, 2, 3), x.dtype)
    if return_states:
        return y, final, states_seq
    return y, final


def slstm_two_pass(params, x_clean, x_noisy, n_heads: int, cfg: XLSTMConfig):
    """DB two-pass: clean scan (collecting per-step states); each noisy token i
    runs one sLSTM cell step from the clean state at i-1, all in parallel."""
    B, S, d = x_clean.shape
    y_clean, _, states_seq = slstm_fwd(params, x_clean, n_heads, cfg,
                                       return_states=True)
    # states_seq leaves: (S, B, ...) post-step; state BEFORE step i is the
    # post-state of step i-1, with the init state at the front.
    init = slstm_init_state(B, n_heads, d)

    def shift(seq, ini):
        return jnp.concatenate([ini[None], seq[:-1]], axis=0)

    prev = tuple(shift(s, i) for s, i in zip(states_seq, init))
    xproj_n = (x_noisy @ params["wx"].astype(x_noisy.dtype))  # (B,S,4d)
    # vmap the cell over the time axis (NOT a reshape-fold of (S,B)->(S*B):
    # that would break SPMD batch-dim sharding propagation — §Perf P3c)
    x_t = xproj_n.transpose(1, 0, 2)                          # (S,B,4d)
    _, h_n = jax.vmap(lambda xt, st: _slstm_cell(params, xt, st, n_heads))(
        x_t, prev)
    y_noisy = _slstm_finish(params, h_n.transpose(1, 0, 2, 3), x_clean.dtype)
    return y_clean, y_noisy


def slstm_decode_step(params, x, n_heads: int, cfg: XLSTMConfig,
                      state) -> Tuple[jax.Array, tuple]:
    """x: (B,1,d)."""
    B, _, d = x.shape
    xproj = (x @ params["wx"].astype(x.dtype))[:, 0]
    new_state, h = _slstm_cell(params, xproj, state, n_heads)
    y = h.reshape(B, 1, d)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)
         * params["norm_g"].astype(jnp.float32)).astype(x.dtype)
    up = y @ params["up"]["w"].astype(x.dtype)
    half = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :half]) * up[..., half:]
    return y @ params["down"]["w"].astype(x.dtype), new_state
