"""Paged KV cache for serving (vLLM-style) + the paged decode attention op.

Instead of one dense worst-case ``(B, C_max, KV, hd)`` slab per layer, keys
and values live in a pool of fixed-size PAGES shared by every sequence slot:

  pages      (P, page_size, KV, hd)   physical storage (bf16 under the
                                      serving precision policy)
  page_table (B, n_logical_pages)     int32 — physical page id backing
                                      logical page p of slot b
  lengths    (B,) int32               committed tokens per slot

Memory is allocated in page granularity proportional to what sequences
*actually* use (the scheduler in ``launch/serve`` hands pages back when a
sequence retires), ragged prompt lengths share ONE compiled program (masking
is length-aware, never shape-aware), and the same pool layout feeds both the
gather-based reference attend and the Pallas flash-decode kernel
(``repro.kernels.flash_decode``).

Physical page 0 is RESERVED as the trash page whenever per-slot ``active``
masks are in play: writes for inactive slots are redirected there instead of
branching, so the append stays one dense scatter. ``init_paged_kv`` always
allocates it; allocators must hand out pages starting at 1 and point unused
page-table entries at 0 (they are DMA'd by the kernel, never read back
unmasked).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import attention as A
from repro.nn.layers import apply_rope

NEG_INF = -1e30
TRASH_PAGE = 0
DEFAULT_PAGE_SIZE = 16


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKV:
    """One layer's paged key/value pool. Registered as a pytree so it can be
    stacked over units, carried through ``lax.scan``, and sliced with
    ``tree_map`` exactly like the dense cache dicts it replaces.

    ``k_scale``/``v_scale`` are present ONLY when the pool stores quantized
    pages (integer storage dtype): one fp32 scalar per physical page per
    tensor. They are shaped ``(*units, P, 1, 1, 1)`` so their page axis sits
    at ``PAGE_AXIS`` exactly like the page data itself (the same gather /
    scatter index expressions move pages and their scales together) and
    dequantization is a plain broadcast multiply. Float pools leave them
    ``None`` — the unquantized pytree structure, and therefore every compiled
    program on the bf16 path, is byte-identical to the pre-quantization
    layout."""
    k: jax.Array    # (P, page_size, KV, hd) — leading unit axes when stacked
    v: jax.Array
    k_scale: Optional[jax.Array] = None   # (P, 1, 1, 1) fp32, quantized only
    v_scale: Optional[jax.Array] = None

    def tree_flatten(self):
        return (self.k, self.v, self.k_scale, self.v_scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


KV_SCALE_DTYPE = jnp.float32


def resolve_kv_dtype(dtype):
    """Resolve a KV storage dtype spec (``'bf16' | 'int8' | np/jnp dtype``)
    to a numpy dtype."""
    if isinstance(dtype, str):
        dtype = {"bf16": jnp.bfloat16, "fp32": jnp.float32,
                 "fp16": jnp.float16, "f32": jnp.float32}.get(dtype, dtype)
    return jnp.dtype(dtype)


def is_quantized_dtype(dtype) -> bool:
    """True for KV storage dtypes that need per-page scales (int8)."""
    return jnp.issubdtype(resolve_kv_dtype(dtype), jnp.integer)


def quantize_pages(x: jax.Array, dtype=jnp.int8):
    """Per-page symmetric absmax quantization. ``x`` is ``(..., psz, KV,
    hd)`` float pages (any number of leading page/unit axes); returns
    ``(q, scale)`` with ``q`` in ``dtype`` and ``scale`` fp32 shaped
    ``(..., 1, 1, 1)`` so ``dequantize_pages`` is a broadcast multiply.
    All-zero pages get scale 0 (q == 0 dequantizes to exactly 0)."""
    qmax = float(jnp.iinfo(dtype).max)
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=(-3, -2, -1), keepdims=True)
    scale = absmax / qmax
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(xf * inv), -qmax, qmax).astype(dtype)
    return q, scale.astype(KV_SCALE_DTYPE)


def dequantize_pages(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_pages``: fp32 pages from int pages + scales."""
    return q.astype(jnp.float32) * scale


def init_paged_kv(n_pages: int, page_size: int, dims: A.AttnDims,
                  dtype=jnp.bfloat16) -> PagedKV:
    dtype = resolve_kv_dtype(dtype)
    shape = (n_pages, page_size, dims.n_kv_heads, dims.head_dim)
    k, v = jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
    if is_quantized_dtype(dtype):
        scale = jnp.zeros((n_pages, 1, 1, 1), KV_SCALE_DTYPE)
        return PagedKV(k, v, scale, scale)
    return PagedKV(k, v)


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def identity_page_table(batch: int, pages_per_slot: int) -> jax.Array:
    """Static allocation: slot b owns pages [1 + b*pps, 1 + (b+1)*pps) —
    page 0 stays reserved as the trash page."""
    return (1 + jnp.arange(batch * pages_per_slot, dtype=jnp.int32)
            ).reshape(batch, pages_per_slot)


def cache_bytes(tree) -> int:
    """Total bytes of a cache pytree (paged or dense; also accepts the
    ``jax.eval_shape`` abstract tree, so sizes can be reported without
    allocating). Mixed-dtype trees — an int8 pool with its fp32 scale
    leaves, fp32 recurrent states beside bf16 pages — are summed per leaf:
    every leaf contributes size × itemsize of its OWN dtype, so quantized
    pools report page bytes AND scale bytes rather than assuming one
    homogeneous dtype."""
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree_util.tree_leaves(tree))


def cache_bytes_by_dtype(tree) -> Dict[str, int]:
    """Per-dtype byte breakdown of a cache pytree — the health/stats
    surface for mixed-dtype (quantized) pools, where a single total hides
    the fp32 scale arrays riding beside the int8 pages."""
    out: Dict[str, int] = {}
    for x in jax.tree_util.tree_leaves(tree):
        d = jnp.dtype(x.dtype)
        out[d.name] = out.get(d.name, 0) + int(np.prod(x.shape)) * d.itemsize
    return out


def reset_slots(tree, init_tree, slot_mask: jax.Array, batch_axis: int):
    """Restore masked slots' entries (along ``batch_axis``) to their INIT
    values from ``init_tree`` — NOT to zero: e.g. the xLSTM max-stabilizer
    states initialize to -1e30.

    Used when a continuous-batching slot is recycled for a NEW request:
    paged KV needs no reset (length masking hides stale pages), but per-slot
    RECURRENT state (mamba/xLSTM) and fixed cross-attention blocks would
    otherwise leak the previous occupant's state into the new sequence.
    """
    def one(cur, init):
        shape = [1] * cur.ndim
        shape[batch_axis] = slot_mask.shape[0]
        return jnp.where(slot_mask.reshape(shape), init.astype(cur.dtype),
                         cur)
    return jax.tree_util.tree_map(one, tree, init_tree)


def append_paged(pkv: PagedKV, k_new: jax.Array, v_new: jax.Array,
                 page_table: jax.Array, lengths: jax.Array,
                 active: Optional[jax.Array] = None) -> PagedKV:
    """Write one token's (k, v) per slot at logical position ``lengths[b]``.

    k_new/v_new: (B, KV, hd). Inactive slots write to the trash page —
    a dense scatter with redirected indices, no per-slot branching.
    """
    psz = pkv.page_size
    logical = lengths // psz
    slot = lengths % psz
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, TRASH_PAGE)
    if not pkv.quantized:
        return PagedKV(
            pkv.k.at[phys, slot].set(k_new.astype(pkv.k.dtype)),
            pkv.v.at[phys, slot].set(v_new.astype(pkv.v.dtype)),
        )
    # Quantized pool: the page is the quantization granule, so the write is
    # read-modify-REQUANTIZE on the B touched pages. Positions past the new
    # token are zeroed before the absmax — recycled pages carry stale
    # garbage that would otherwise inflate the scale and crush the real
    # tokens' precision (attention masks hide the zeros exactly as they hid
    # the garbage).
    B = k_new.shape[0]
    rows = jnp.arange(B)
    keep = (jnp.arange(psz)[None, :] <= slot[:, None])[..., None, None]

    def one(pool, scale, new):
        pg = dequantize_pages(pool[phys], scale[phys])    # (B, psz, KV, hd)
        pg = pg.at[rows, slot].set(new.astype(jnp.float32))
        q, s = quantize_pages(jnp.where(keep, pg, 0.0), pool.dtype)
        return pool.at[phys].set(q), scale.at[phys].set(s)

    k_p, k_s = one(pkv.k, pkv.k_scale, k_new)
    v_p, v_s = one(pkv.v, pkv.v_scale, v_new)
    return PagedKV(k_p, v_p, k_s, v_s)


def append_paged_chunk(pkv: PagedKV, k_new: jax.Array, v_new: jax.Array,
                       page_table: jax.Array, lengths: jax.Array,
                       n_valid: jax.Array) -> PagedKV:
    """Write a whole CHUNK of C tokens' (k, v) per slot in one dense scatter.

    k_new/v_new: (B, C, KV, hd); chunk token i of slot b lands at logical
    position ``lengths[b] + i``. ``n_valid`` (B,) int32 is the count of real
    tokens in the chunk per slot (ragged tails / inactive slots write to the
    trash page — same no-branch redirect as ``append_paged``). Valid tokens
    are always a chunk PREFIX (prompts are right-padded), so lengths advance
    by exactly ``n_valid``.
    """
    B, C = k_new.shape[:2]
    psz = pkv.page_size
    if not pkv.quantized:
        pos = lengths[:, None] + jnp.arange(C, dtype=lengths.dtype)[None, :]
        logical = jnp.clip(pos // psz, 0, page_table.shape[1] - 1)
        slot = pos % psz
        phys = jnp.take_along_axis(page_table, logical, axis=1)     # (B, C)
        valid = jnp.arange(C)[None, :] < n_valid[:, None]
        phys = jnp.where(valid, phys, TRASH_PAGE)
        fp, fs = phys.reshape(-1), slot.reshape(-1)
        k_flat = k_new.reshape(B * C, *k_new.shape[2:])
        v_flat = v_new.reshape(B * C, *v_new.shape[2:])
        return PagedKV(
            pkv.k.at[fp, fs].set(k_flat.astype(pkv.k.dtype)),
            pkv.v.at[fp, fs].set(v_flat.astype(pkv.v.dtype)),
        )
    # Quantized pool: requantize every page the chunk touches. A C-token
    # chunk starting mid-page spans at most C // psz + 1 pages per slot;
    # gather those, dequantize, splice the chunk in at its per-slot offset,
    # zero everything past lengths + n_valid (ragged tails AND stale
    # garbage — see ``append_paged``), requantize, scatter pages + scales
    # back. Touched pages with no valid token (inactive slots) are
    # redirected to the trash page, same no-branch trick as above.
    npg = page_table.shape[1]
    npt = C // psz + 1
    base = lengths // psz
    tlog = base[:, None] + jnp.arange(npt, dtype=lengths.dtype)   # (B, npt)
    tphys = jnp.take_along_axis(page_table, jnp.clip(tlog, 0, npg - 1),
                                axis=1)
    end = lengths + n_valid
    real = tlog * psz < end[:, None]
    tphys = jnp.where(real, tphys, TRASH_PAGE)
    span = npt * psz
    rows = jnp.arange(B)[:, None]
    rel = (lengths % psz)[:, None] + jnp.arange(C, dtype=lengths.dtype)
    keep = ((base[:, None] * psz + jnp.arange(span))
            < end[:, None])[..., None, None]                  # (B,span,1,1)
    fp = tphys.reshape(-1)

    def one(pool, scale, new):
        pg = dequantize_pages(pool[tphys], scale[tphys])  # (B,npt,psz,KV,hd)
        tail = pg.shape[3:]
        flat = pg.reshape(B, span, *tail)
        flat = flat.at[rows, rel].set(new.astype(jnp.float32))
        flat = jnp.where(keep, flat, 0.0)
        q, s = quantize_pages(flat.reshape(B, npt, psz, *tail), pool.dtype)
        return (pool.at[fp].set(q.reshape(B * npt, psz, *tail)),
                scale.at[fp].set(s.reshape(B * npt, 1, 1, 1)))

    k_p, k_s = one(pkv.k, pkv.k_scale, k_new)
    v_p, v_s = one(pkv.v, pkv.v_scale, v_new)
    return PagedKV(k_p, v_p, k_s, v_s)


# the page axis of a PagedKV leaf counted from the END: leaves are
# (*units, P, psz, KV, hd) with a VARIABLE number of leading unit axes
# (VLM stacks (n_units, k_self, P, ...)), so only trailing-axis indexing
# names the page axis reliably.
PAGE_AXIS = -4


def _page_index(ids):
    """Index tuple selecting physical pages ``ids`` at ``PAGE_AXIS`` for
    ``.at[...]`` updates, whatever the number of leading unit axes."""
    return (Ellipsis, ids, slice(None), slice(None), slice(None))


def copy_pool_pages(cache, src, dst):
    """Copy physical page ``src`` onto ``dst`` in every PagedKV leaf of a
    model cache (leaves are (*units, P, psz, KV, hd) — the page table is
    shared across units, so one physical id names the same page everywhere).
    Pages are addressed at ``PAGE_AXIS`` from the end: families stack a
    VARIABLE number of leading unit axes (VLM's self leaves carry an extra
    k_self axis), so positional ``[:, page]`` indexing would silently hit
    the wrong axis. Dense per-slot leaves (recurrent states, cross blocks)
    pass through untouched. This is the device half of copy-on-write prefix
    sharing. Quantized pools move each page's scale alongside its data —
    the scale arrays share ``PAGE_AXIS``, so the same index expressions
    apply."""
    def one(x):
        if isinstance(x, PagedKV):
            idx = _page_index(dst)

            def cp(a):
                if a is None:
                    return None
                return a.at[idx].set(jnp.take(a, src, axis=PAGE_AXIS))

            return PagedKV(cp(x.k), cp(x.v), cp(x.k_scale), cp(x.v_scale))
        return x
    return jax.tree_util.tree_map(one, cache,
                                  is_leaf=lambda x: isinstance(x, PagedKV))


def dense_to_paged(k: jax.Array, v: jax.Array, page_size: int
                   ) -> Tuple[PagedKV, jax.Array]:
    """View a dense (B, C, KV, hd) cache as pages + identity table, so the
    flash-decode kernel can also serve the legacy dense decode path. No
    trash page (this view is never appended to)."""
    B, C, KV, hd = k.shape
    psz = min(page_size, C)
    pad = (-C) % psz
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    npg = (C + pad) // psz
    pages = PagedKV(k.reshape(B * npg, psz, KV, hd),
                    v.reshape(B * npg, psz, KV, hd))
    table = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    return pages, table


# ---------------------------------------------------------------------------
# Slot spill / restore (host-side preemption store)
# ---------------------------------------------------------------------------
#
# Preemption needs a slot's ENTIRE sequence state to survive losing its slot
# and pages: the committed KV pages (paged leaves) plus the per-slot DENSE
# state the families keep outside the pool — recurrent mamba/xLSTM states and
# the fixed cross-attention conditioning blocks. DiffusionBlocks makes this
# snapshot unusually small and clean: every block is an independently trained
# denoiser over the same hidden stream, so there are no cross-block
# activations to capture — the cache pytree IS the whole state.
#
# ``spill_slot`` gathers to HOST numpy (the spill store lives off-device, so
# a preempted request costs no pool memory); ``restore_slot`` scatters the
# snapshot back into freshly allocated pages (possibly different physical
# ids — the page table is rewritten by the scheduler) and the same slot-axis
# rows. Both walk the cache with one flatten, so the leaf order is identical
# between spill and restore by construction.
#
# ``dense_axes`` maps top-level cache keys of dense (non-paged) subtrees to
# their slot axis (``model.paged_state_axes``): VLM/encdec cross blocks sit
# at axis 1, hybrid mamba states at axis 2 (an extra inner-layer axis).


@dataclasses.dataclass
class SpilledSlot:
    """Host-side snapshot of one slot's cache state: ``data[i]`` corresponds
    to flattened leaf i — an ``(k, v)`` numpy pair of gathered pages for a
    PagedKV leaf, a numpy slot-row for a dense leaf. ``n_pages`` is the
    number of (used) pages the snapshot covers.

    ``to_bytes``/``from_bytes`` give the snapshot a wire format (the
    RDMA-copy stub for migrating requests between workers whose pools do
    NOT share memory): a plain ``np.savez`` container, no pickle — the
    receiving process needs only numpy to reconstruct it, and a snapshot
    restores into ANY pool with matching per-page leaf shapes, regardless
    of that pool's total page count or slot count."""
    data: list
    n_pages: int

    def to_bytes(self) -> bytes:
        import io
        arrays = {"n_pages": np.asarray(self.n_pages, np.int64)}
        kinds, dtypes = [], []
        for i, entry in enumerate(self.data):
            if isinstance(entry, tuple) and len(entry) == 4:
                # quantized PagedKV leaf: (k, v, k_scale, v_scale)
                kinds.append(2)
                dtypes.append(entry[0].dtype.name)
                arrays[f"k{i}"], arrays[f"v{i}"] = entry[0], entry[1]
                arrays[f"ks{i}"], arrays[f"vs{i}"] = entry[2], entry[3]
            elif isinstance(entry, tuple):      # PagedKV leaf: (k, v) pages
                kinds.append(1)
                dtypes.append(entry[0].dtype.name)
                arrays[f"k{i}"], arrays[f"v{i}"] = entry
            else:                               # dense per-slot row
                kinds.append(0)
                dtypes.append(entry.dtype.name)
                arrays[f"d{i}"] = entry
        arrays["kinds"] = np.asarray(kinds, np.int8)
        # extension dtypes (bf16) serialize as raw void bytes — record the
        # name so the receiver can view them back
        arrays["dtypes"] = np.asarray(dtypes)
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "SpilledSlot":
        import io
        with np.load(io.BytesIO(raw), allow_pickle=False) as z:
            kinds, dtypes = z["kinds"], z["dtypes"]
            data = []
            for i, kind in enumerate(kinds):
                dt = np.dtype(str(dtypes[i]))
                if kind == 2:
                    data.append((z[f"k{i}"].view(dt), z[f"v{i}"].view(dt),
                                 z[f"ks{i}"].view(np.float32),
                                 z[f"vs{i}"].view(np.float32)))
                elif kind == 1:
                    data.append((z[f"k{i}"].view(dt), z[f"v{i}"].view(dt)))
                else:
                    data.append(z[f"d{i}"].view(dt))
            return cls(data=data, n_pages=int(z["n_pages"]))


def _is_pkv(x) -> bool:
    return isinstance(x, PagedKV)


def _dense_slot_axis(path, dense_axes) -> int:
    for p in path:
        if isinstance(p, jax.tree_util.DictKey) and p.key in dense_axes:
            return dense_axes[p.key]
    raise KeyError(
        f"dense cache leaf at {jax.tree_util.keystr(path)} has no slot axis "
        f"in paged_state_axes {dense_axes} — the family must declare where "
        "its per-slot state lives before it can be spilled")


def spill_slot(cache, slot: int, page_ids, dense_axes=None) -> SpilledSlot:
    """Snapshot slot ``slot``'s state to host memory: the content of its
    ``page_ids`` physical pages from every PagedKV leaf (gathered at
    ``PAGE_AXIS``) and its row of every dense per-slot leaf (at the axis
    ``dense_axes`` names). The cache itself is NOT modified — the scheduler
    frees the pages separately."""
    dense_axes = dense_axes or {}
    ids = jnp.asarray(np.asarray(page_ids, np.int32))
    leaves = jax.tree_util.tree_flatten_with_path(cache, is_leaf=_is_pkv)[0]
    data = []
    for path, leaf in leaves:
        if _is_pkv(leaf):
            entry = (np.asarray(jnp.take(leaf.k, ids, axis=PAGE_AXIS)),
                     np.asarray(jnp.take(leaf.v, ids, axis=PAGE_AXIS)))
            if leaf.quantized:
                entry += (np.asarray(jnp.take(leaf.k_scale, ids,
                                              axis=PAGE_AXIS)),
                          np.asarray(jnp.take(leaf.v_scale, ids,
                                              axis=PAGE_AXIS)))
            data.append(entry)
        else:
            ax = _dense_slot_axis(path, dense_axes)
            data.append(np.asarray(jnp.take(leaf, slot, axis=ax)))
    return SpilledSlot(data=data, n_pages=len(page_ids))


def restore_slot(cache, slot: int, page_ids, spilled: SpilledSlot,
                 dense_axes=None):
    """Write a ``spill_slot`` snapshot back: page content lands in the
    freshly allocated ``page_ids`` (``len(page_ids) == spilled.n_pages``;
    the ids may differ from the spill-time ones — logical order is what
    matters) and dense rows overwrite slot ``slot``. Returns the updated
    cache; the scheduler then rewrites the page table to ``page_ids``."""
    dense_axes = dense_axes or {}
    assert len(page_ids) == spilled.n_pages, \
        f"restore got {len(page_ids)} pages for a {spilled.n_pages}-page spill"
    ids = jnp.asarray(np.asarray(page_ids, np.int32))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache,
                                                           is_leaf=_is_pkv)
    assert len(leaves) == len(spilled.data), \
        "cache structure changed between spill and restore"
    new = []
    for (path, leaf), saved in zip(leaves, spilled.data):
        if _is_pkv(leaf):
            if not isinstance(saved, tuple):
                raise ValueError(
                    f"cache-state snapshot mismatch at "
                    f"{jax.tree_util.keystr(path)}: the snapshot holds a "
                    "dense row where the target pool has a paged leaf — "
                    "spill and restore caches come from different model "
                    "families")
            _check_restore_dtypes(path, leaf, saved)
            if spilled.n_pages == 0:
                # dense-rows-only snapshot (page-handle migration): the
                # handed pages already hold the KV — no paged writes
                new.append(leaf)
                continue
            idx = _page_index(ids)
            k_s, v_s = saved[0], saved[1]
            restored = PagedKV(leaf.k.at[idx].set(jnp.asarray(k_s)),
                               leaf.v.at[idx].set(jnp.asarray(v_s)))
            if leaf.quantized:
                restored = PagedKV(
                    restored.k, restored.v,
                    leaf.k_scale.at[idx].set(jnp.asarray(saved[2])),
                    leaf.v_scale.at[idx].set(jnp.asarray(saved[3])))
            new.append(restored)
        else:
            ax = _dense_slot_axis(path, dense_axes)
            idx = (slice(None),) * ax + (slot,)
            new.append(leaf.at[idx].set(
                jnp.asarray(saved).astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, new)


def _check_restore_dtypes(path, leaf: PagedKV, saved: tuple):
    """Refuse to scatter a snapshot's pages into a pool with a different
    storage dtype or quantization layout. Reinterpreting e.g. int8 page
    bytes as bf16 (mismatched ``--kv-dtype`` between disagg workers) would
    silently serve garbage KV — fail loudly with the remediation instead."""
    have_scales = len(saved) == 4
    snap_dt, pool_dt = np.dtype(saved[0].dtype), np.dtype(leaf.k.dtype)
    if snap_dt != pool_dt or have_scales != leaf.quantized:
        def _desc(dt, scaled):
            return (f"{np.dtype(dt).name} pages "
                    f"{'WITH' if scaled else 'without'} per-page scales")
        raise ValueError(
            f"cache-state dtype mismatch at {jax.tree_util.keystr(path)}: "
            f"snapshot carries {_desc(snap_dt, have_scales)} but the target "
            f"pool stores {_desc(pool_dt, leaf.quantized)}. The spilling and "
            "restoring pools must be built with the same --kv-dtype; "
            "re-prefill the request on the destination worker instead of "
            "migrating its cache state.")


# ---------------------------------------------------------------------------
# Conditioning memory (fixed per-slot cross-attention blocks)
# ---------------------------------------------------------------------------

def cross_attend(q, k, v, cond_lengths):
    """Cross-attention over a fixed per-slot conditioning block with a
    per-slot VALID length — the serving counterpart of the unmasked
    ``attention.attend(mask_mod=None)`` cross path.

    q: (B, S, H, hd) un-roped queries; k/v: (B, Sk, KV, hd) the slot's
    conditioning memory (image patches / encoded audio frames), zero-padded
    past ``cond_lengths[b]``. Padding must be MASKED, not attended: attending
    zero keys would dilute the softmax. ``cond_lengths[b] == 0`` means the
    slot is UNCONDITIONED — the sum of weights is zero and the output is
    exactly 0 (no NaN), which is what an absent cross term contributes.

    Returns (B, S, H, hd) in q.dtype (fp32 softmax inside).
    """
    B, S, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(Sk)[None, :] < cond_lengths[:, None]        # (B, Sk)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))   # all-masked rows -> p ~ 0
    p = jnp.where(valid[:, None, None, None, :], p, 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p / l, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


def conditioning_fingerprint(aux_inputs) -> int:
    """Content hash of a request's aux conditioning inputs (image/audio
    embeddings), folded into ``PrefixPageCache`` keys: identical prompt text
    under DIFFERENT conditioning must never share prefix pages (every
    token's hidden stream — and therefore its paged self-attention K/V —
    passes through cross-attention to this memory), while identical text
    AND identical conditioning shares exactly as unconditioned text does.

    Host-side (numpy), deterministic across processes. Returns 0 for
    unconditioned requests (``None`` / empty dict) — the unconditioned trie
    root, so text-only serving keeps today's hit rates."""
    import hashlib

    import numpy as np
    if not aux_inputs:
        return 0
    h = hashlib.sha256()
    for key in sorted(aux_inputs):
        arr = np.ascontiguousarray(np.asarray(aux_inputs[key], np.float32))
        h.update(key.encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return int.from_bytes(h.digest()[:8], "big") or 1


# ---------------------------------------------------------------------------
# Attend over the pool (committed tokens < lengths[b]) + the token's own k/v
# ---------------------------------------------------------------------------

def _attend_pages_ref(qg, pkv: PagedKV, page_table, lengths, k_self, v_self,
                      window: Optional[int]):
    """Gather-based reference: logical KV materialized per slot, fp32
    softmax over [cached (idx < lengths[b]) || self]. qg: (B, KV, G, hd);
    k_self/v_self: (B, KV, hd). Returns (B, KV, G, hd) fp32."""
    B, KV, G, hd = qg.shape
    npg, psz = page_table.shape[1], pkv.page_size
    L = npg * psz
    kk = pkv.k[page_table].astype(jnp.float32)        # (B, npg, psz, KV, hd)
    vv = pkv.v[page_table].astype(jnp.float32)
    if pkv.quantized:                 # per-page dequant: broadcast multiply
        kk = kk * pkv.k_scale[page_table]
        vv = vv * pkv.v_scale[page_table]
    kk = kk.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)   # (B, KV, L, hd)
    vv = vv.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)
    scale = 1.0 / (hd ** 0.5)
    qf = qg.astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, kk) * scale
    idx = jnp.arange(L)
    valid = idx[None, :] < lengths[:, None]
    if window is not None:
        valid &= idx[None, :] > lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s_self = jnp.einsum("bkgd,bkd->bkg", qf,
                        k_self.astype(jnp.float32)) * scale
    s_all = jnp.concatenate([s, s_self[..., None]], axis=-1)
    w = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w[..., :-1], vv)
    return out + w[..., -1:] * v_self.astype(jnp.float32)[:, :, None, :]


def attend_paged(qg, pkv: PagedKV, page_table, lengths, k_self, v_self, *,
                 window: Optional[int] = None, impl: str = "auto"):
    """Dispatch between the gather reference and the Pallas flash-decode
    kernel (split-KV over pages, logsumexp-combined, then the self term is
    folded in from the fp32 partials)."""
    if impl in ("pallas", "kernels"):
        from repro.kernels import ops as kops
        from repro.kernels import flash_decode as FD
        out_p, lse = kops.flash_decode(qg, pkv.k, pkv.v, page_table,
                                       lengths, window=window,
                                       k_scale=pkv.k_scale,
                                       v_scale=pkv.v_scale)
        scale = 1.0 / (qg.shape[-1] ** 0.5)
        s_self = jnp.einsum("bkgd,bkd->bkg", qg.astype(jnp.float32),
                            k_self.astype(jnp.float32)) * scale
        return FD.combine_self(out_p, lse, s_self,
                               v_self.astype(jnp.float32))
    return _attend_pages_ref(qg, pkv, page_table, lengths, k_self, v_self,
                             window)


def paged_decode_attention(params, x, dims: A.AttnDims, pkv: PagedKV, *,
                           lengths, page_table, active=None,
                           commit: bool = True,
                           window: Optional[int] = None, impl: str = "auto"):
    """One-token decode over the paged cache — the serving counterpart of
    ``attention.decode_attention``.

    x: (B, 1, d); each slot's token sits at its OWN absolute position
    ``lengths[b]`` (rope + mask are per-slot, so ragged batches trace once).
    ``commit=False`` is the DB denoising probe: attend but never append —
    the pool is returned untouched instead of copy-discarded.

    Returns (out (B, 1, d), new_pkv).
    """
    B = x.shape[0]
    q, k, v = A.project_qkv(params, x, dims)
    posv = lengths[:, None]                       # (B, 1) per-slot positions
    q = apply_rope(q, posv, dims.rope_theta)
    k = apply_rope(k, posv, dims.rope_theta)
    KV, G, hd = dims.n_kv_heads, dims.q_per_kv, dims.head_dim
    qg = q[:, 0].reshape(B, KV, G, hd)
    k_self, v_self = k[:, 0], v[:, 0]             # (B, KV, hd)
    out = attend_paged(qg, pkv, page_table, lengths, k_self, v_self,
                       window=window, impl=impl)
    out = out.reshape(B, 1, dims.n_heads * hd).astype(x.dtype)
    out = out @ params["wo"].astype(x.dtype)
    new_pkv = append_paged(pkv, k_self, v_self, page_table, lengths,
                           active) if commit else pkv
    return out, new_pkv


# ---------------------------------------------------------------------------
# Chunked prefill: C queries at a time over the pool (the chunk's own k/v are
# appended FIRST, so one attend covers history + intra-chunk causal)
# ---------------------------------------------------------------------------

def _attend_prefill_ref(qg, pkv: PagedKV, page_table, lengths,
                        window: Optional[int]):
    """Gather-based reference for chunk queries. qg: (B, C, KV, G, hd) at
    absolute positions lengths[b] + i; key at logical index j is valid for
    query i iff j <= lengths[b] + i (and within the sliding window). Returns
    (B, C, KV, G, hd) fp32."""
    B, C, KV, G, hd = qg.shape
    npg, psz = page_table.shape[1], pkv.page_size
    L = npg * psz
    kk = pkv.k[page_table].astype(jnp.float32)        # (B, npg, psz, KV, hd)
    vv = pkv.v[page_table].astype(jnp.float32)
    if pkv.quantized:                 # per-page dequant: broadcast multiply
        kk = kk * pkv.k_scale[page_table]
        vv = vv * pkv.v_scale[page_table]
    kk = kk.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)   # (B, KV, L, hd)
    vv = vv.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)
    scale = 1.0 / (hd ** 0.5)
    qf = qg.astype(jnp.float32)
    s = jnp.einsum("bckgd,bksd->bkgcs", qf, kk) * scale   # (B,KV,G,C,L)
    idx = jnp.arange(L)
    qabs = lengths[:, None] + jnp.arange(C)               # (B, C)
    valid = idx[None, None, :] <= qabs[:, :, None]        # (B, C, L)
    if window is not None:
        valid &= idx[None, None, :] > qabs[:, :, None] - window
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bksd->bkgcd", w, vv)
    return out.transpose(0, 3, 1, 2, 4)                   # (B, C, KV, G, hd)


def attend_prefill(qg, pkv: PagedKV, page_table, lengths, *,
                   window: Optional[int] = None, impl: str = "auto"):
    """Dispatch between the gather reference and the Pallas chunked-prefill
    kernel (``repro.kernels.flash_prefill``)."""
    if impl in ("pallas", "kernels"):
        from repro.kernels import ops as kops
        return kops.flash_prefill(qg, pkv.k, pkv.v, page_table, lengths,
                                  window=window, k_scale=pkv.k_scale,
                                  v_scale=pkv.v_scale)
    return _attend_prefill_ref(qg, pkv, page_table, lengths, window)


def paged_prefill_attention(params, x, dims: A.AttnDims, pkv: PagedKV, *,
                            lengths, page_table, n_valid,
                            window: Optional[int] = None, impl: str = "auto"):
    """Chunk-of-C prefill over the paged cache — the ingest counterpart of
    ``paged_decode_attention``. x: (B, C, d); slot b's chunk sits at its OWN
    absolute positions [lengths[b], lengths[b] + C) (per-slot rope + masks:
    ragged batches and prefix-cache offsets trace once). The chunk's K/V are
    written into pool pages in ONE scatter (ragged tails past ``n_valid[b]``
    to the trash page), then one attend covers [committed history ||
    intra-chunk causal]. Rows past ``n_valid[b]`` return garbage the caller
    discards — exactly like inactive decode slots.

    Returns (out (B, C, d), new_pkv).
    """
    B, C = x.shape[:2]
    q, k, v = A.project_qkv(params, x, dims)
    posv = lengths[:, None] + jnp.arange(C, dtype=lengths.dtype)[None, :]
    q = apply_rope(q, posv, dims.rope_theta)
    k = apply_rope(k, posv, dims.rope_theta)
    new_pkv = append_paged_chunk(pkv, k, v, page_table, lengths, n_valid)
    KV, G, hd = dims.n_kv_heads, dims.q_per_kv, dims.head_dim
    qg = q.reshape(B, C, KV, G, hd)
    out = attend_prefill(qg, new_pkv, page_table, lengths, window=window,
                         impl=impl)
    out = out.reshape(B, C, dims.n_heads * hd).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), new_pkv


# ---------------------------------------------------------------------------
# Shared-prefix page cache (host-side allocator metadata)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PrefixNode:
    """One cached page of prompt-prefix KV. Full-page nodes chain into a trie
    keyed by their page's token ids; each node may also carry TAIL candidates
    — partially-filled pages whose leading tokens continue this chain."""
    page: int
    children: Dict[tuple, "_PrefixNode"] = dataclasses.field(
        default_factory=dict)
    tails: List[Tuple[int, "np.ndarray"]] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class PrefixMatch:
    """Result of a prefix-cache lookup: ``pages`` are the shared physical
    pages (full pages, plus at most one partial TAIL page), ``n_tokens`` the
    prompt tokens they cover. ``tail_tokens`` > 0 means the LAST shared page
    is partially filled — the slot's first write lands inside it, so the
    scheduler must copy-on-write it before writing."""
    pages: List[int]
    n_tokens: int
    tail_tokens: int


class PrefixPageCache:
    """Host-side shared-prefix registry over the physical page pool.

    Prompt prefixes are hashed at PAGE granularity by token content: a trie
    node per full page (chained, so equal pages in different contexts never
    collide) plus partial-tail candidates for the page that follows a chain.
    The cache holds one refcount on every registered page so it survives its
    owner's retirement; the scheduler (``launch.serve.ContinuousBatcher``)
    adds one ref per slot that maps a shared page and frees a page only when
    its count drops to zero. Pages with refcount > 1 are READ-ONLY for any
    slot — a slot about to write into one gets a private copy first
    (``copy_pool_pages``), which is what makes the sharing copy-on-write.

    CONDITIONING-AWARE: every lookup/registration carries the request's
    conditioning fingerprint (``conditioning_fingerprint`` — a content hash
    of its aux image/audio embeddings; 0 = unconditioned). Each fingerprint
    owns its own trie root, so identical prompt text under different
    conditioning NEVER shares pages (the page content depends on the
    conditioning through cross-attention), while requests with identical
    text AND identical conditioning — and all unconditioned requests —
    share exactly as before.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.roots: Dict[int, _PrefixNode] = {}
        self.hits = 0            # lookups that shared at least one page
        self.tokens_shared = 0   # prompt tokens served from shared pages

    def _root(self, cond_fp: int) -> _PrefixNode:
        if cond_fp not in self.roots:
            self.roots[cond_fp] = _PrefixNode(page=-1)
        return self.roots[cond_fp]

    # ---- lookup ------------------------------------------------------
    def match(self, tokens, cond_fp: int = 0) -> PrefixMatch:
        """Longest shared prefix of ``tokens`` (np int array) under the
        request's conditioning fingerprint. Never matches the WHOLE prompt's
        last page as full+exact unless the prompt is page-aligned; a partial
        tail match covers at most page_size-1 tokens of the next page.

        Pure lookup — no refcounts are taken and no statistics move (the
        scheduler may defer the admission); ``hits`` / ``tokens_shared`` are
        updated by the caller when a match is actually admitted."""
        import numpy as np
        node = self.roots.get(cond_fp)   # pure: never create roots on lookup
        if node is None:
            return PrefixMatch(pages=[], n_tokens=0, tail_tokens=0)
        tokens = np.asarray(tokens)
        psz = self.page_size
        pages, n = [], 0
        while n + psz <= tokens.size:
            key = tuple(int(t) for t in tokens[n:n + psz])
            child = node.children.get(key)
            if child is None:
                break
            node, n = child, n + psz
            pages.append(child.page)
        tail_tokens, best = 0, None
        rest = tokens[n:]
        for page, ttoks in node.tails:
            m = 0
            lim = min(ttoks.size, rest.size)
            while m < lim and int(ttoks[m]) == int(rest[m]):
                m += 1
            if m > tail_tokens:
                tail_tokens, best = m, page
        if best is not None and tail_tokens > 0:
            pages.append(best)
            n += tail_tokens
        return PrefixMatch(pages=pages, n_tokens=n, tail_tokens=tail_tokens)

    # ---- registration ------------------------------------------------
    def insert(self, tokens, pages: List[int], refcount: Dict[int, int],
               cond_fp: int = 0):
        """Register a freshly-prefilled prompt's pages under its conditioning
        fingerprint. ``pages[i]`` backs tokens [i*psz, (i+1)*psz). Full pages
        extend the trie; a non-empty partial last page becomes a tail
        candidate. Every NEWLY registered page gains one cache-held ref in
        ``refcount``. Pages already in the trie (the request itself was a
        cache hit) are left alone."""
        import numpy as np
        tokens = np.asarray(tokens)
        psz = self.page_size
        node, n, i = self._root(cond_fp), 0, 0
        while n + psz <= tokens.size:
            key = tuple(int(t) for t in tokens[n:n + psz])
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(page=pages[i])
                node.children[key] = child
                refcount[pages[i]] = refcount.get(pages[i], 0) + 1
            node, n, i = child, n + psz, i + 1
        tail = tokens[n:]
        if tail.size and i < len(pages):
            known = any(np.array_equal(t, tail) for _, t in node.tails)
            if not known:
                node.tails.append((pages[i], tail.copy()))
                refcount[pages[i]] = refcount.get(pages[i], 0) + 1
        # If nothing was registered under a freshly created root (prompt
        # shorter than a page with no tail page to offer, say), drop the
        # root again: an empty root matches nothing, survives eviction
        # sweeps that stop as soon as enough pages are free, and would
        # accumulate forever across fingerprints.
        root = self.roots.get(cond_fp)
        if root is not None and not root.children and not root.tails:
            del self.roots[cond_fp]

    # ---- eviction ----------------------------------------------------
    def evict(self, refcount: Dict[int, int], free_pages: List[int],
              need: int) -> int:
        """Drop cache-held refs until ``need`` pages are free (deepest trie
        nodes and tails first — prefixes stay useful longest; conditioning
        tries are walked in insertion order). Pages whose count hits zero go
        back on the free list. Returns pages freed."""
        freed = 0

        def drop(page):
            nonlocal freed
            refcount[page] -= 1
            if refcount[page] == 0:
                del refcount[page]
                free_pages.append(page)
                freed += 1

        def walk(node):
            nonlocal freed
            for key in list(node.children):
                if len(free_pages) >= need:
                    return
                walk(node.children[key])
                child = node.children[key]
                if not child.children and not child.tails:
                    drop(child.page)
                    del node.children[key]
            while node.tails and len(free_pages) < need:
                page, _ = node.tails.pop()
                drop(page)

        for fp in list(self.roots):
            root = self.roots[fp]
            if len(free_pages) < need:
                walk(root)
            # Prune emptied roots even when eviction was satisfied mid-walk
            # (or before this root was reached): breaking out of the sweep
            # used to strand empty roots in ``self.roots``.
            if not root.children and not root.tails:
                del self.roots[fp]
        return freed
