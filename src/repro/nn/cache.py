"""Paged KV cache for serving (vLLM-style) + the paged decode attention op.

Instead of one dense worst-case ``(B, C_max, KV, hd)`` slab per layer, keys
and values live in a pool of fixed-size PAGES shared by every sequence slot:

  pages      (P, page_size, KV, hd)   physical storage (bf16 under the
                                      serving precision policy)
  page_table (B, n_logical_pages)     int32 — physical page id backing
                                      logical page p of slot b
  lengths    (B,) int32               committed tokens per slot

Memory is allocated in page granularity proportional to what sequences
*actually* use (the scheduler in ``launch/serve`` hands pages back when a
sequence retires), ragged prompt lengths share ONE compiled program (masking
is length-aware, never shape-aware), and the same pool layout feeds both the
gather-based reference attend and the Pallas flash-decode kernel
(``repro.kernels.flash_decode``).

Physical page 0 is RESERVED as the trash page whenever per-slot ``active``
masks are in play: writes for inactive slots are redirected there instead of
branching, so the append stays one dense scatter. ``init_paged_kv`` always
allocates it; allocators must hand out pages starting at 1 and point unused
page-table entries at 0 (they are DMA'd by the kernel, never read back
unmasked).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention as A
from repro.nn.layers import apply_rope

NEG_INF = -1e30
TRASH_PAGE = 0
DEFAULT_PAGE_SIZE = 16


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKV:
    """One layer's paged key/value pool. Registered as a pytree so it can be
    stacked over units, carried through ``lax.scan``, and sliced with
    ``tree_map`` exactly like the dense cache dicts it replaces."""
    k: jax.Array    # (P, page_size, KV, hd) — leading unit axes when stacked
    v: jax.Array

    def tree_flatten(self):
        return (self.k, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]


def init_paged_kv(n_pages: int, page_size: int, dims: A.AttnDims,
                  dtype=jnp.bfloat16) -> PagedKV:
    shape = (n_pages, page_size, dims.n_kv_heads, dims.head_dim)
    return PagedKV(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def identity_page_table(batch: int, pages_per_slot: int) -> jax.Array:
    """Static allocation: slot b owns pages [1 + b*pps, 1 + (b+1)*pps) —
    page 0 stays reserved as the trash page."""
    return (1 + jnp.arange(batch * pages_per_slot, dtype=jnp.int32)
            ).reshape(batch, pages_per_slot)


def cache_bytes(tree) -> int:
    """Total bytes of a cache pytree (paged or dense; also accepts the
    ``jax.eval_shape`` abstract tree, so sizes can be reported without
    allocating)."""
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def reset_slots(tree, init_tree, slot_mask: jax.Array, batch_axis: int):
    """Restore masked slots' entries (along ``batch_axis``) to their INIT
    values from ``init_tree`` — NOT to zero: e.g. the xLSTM max-stabilizer
    states initialize to -1e30.

    Used when a continuous-batching slot is recycled for a NEW request:
    paged KV needs no reset (length masking hides stale pages), but per-slot
    RECURRENT state (mamba/xLSTM) and fixed cross-attention blocks would
    otherwise leak the previous occupant's state into the new sequence.
    """
    def one(cur, init):
        shape = [1] * cur.ndim
        shape[batch_axis] = slot_mask.shape[0]
        return jnp.where(slot_mask.reshape(shape), init.astype(cur.dtype),
                         cur)
    return jax.tree_util.tree_map(one, tree, init_tree)


def append_paged(pkv: PagedKV, k_new: jax.Array, v_new: jax.Array,
                 page_table: jax.Array, lengths: jax.Array,
                 active: Optional[jax.Array] = None) -> PagedKV:
    """Write one token's (k, v) per slot at logical position ``lengths[b]``.

    k_new/v_new: (B, KV, hd). Inactive slots write to the trash page —
    a dense scatter with redirected indices, no per-slot branching.
    """
    psz = pkv.page_size
    logical = lengths // psz
    slot = lengths % psz
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, TRASH_PAGE)
    return PagedKV(
        pkv.k.at[phys, slot].set(k_new.astype(pkv.k.dtype)),
        pkv.v.at[phys, slot].set(v_new.astype(pkv.v.dtype)),
    )


def dense_to_paged(k: jax.Array, v: jax.Array, page_size: int
                   ) -> Tuple[PagedKV, jax.Array]:
    """View a dense (B, C, KV, hd) cache as pages + identity table, so the
    flash-decode kernel can also serve the legacy dense decode path. No
    trash page (this view is never appended to)."""
    B, C, KV, hd = k.shape
    psz = min(page_size, C)
    pad = (-C) % psz
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    npg = (C + pad) // psz
    pages = PagedKV(k.reshape(B * npg, psz, KV, hd),
                    v.reshape(B * npg, psz, KV, hd))
    table = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    return pages, table


# ---------------------------------------------------------------------------
# Attend over the pool (committed tokens < lengths[b]) + the token's own k/v
# ---------------------------------------------------------------------------

def _attend_pages_ref(qg, pkv: PagedKV, page_table, lengths, k_self, v_self,
                      window: Optional[int]):
    """Gather-based reference: logical KV materialized per slot, fp32
    softmax over [cached (idx < lengths[b]) || self]. qg: (B, KV, G, hd);
    k_self/v_self: (B, KV, hd). Returns (B, KV, G, hd) fp32."""
    B, KV, G, hd = qg.shape
    npg, psz = page_table.shape[1], pkv.page_size
    L = npg * psz
    kk = pkv.k[page_table].astype(jnp.float32)        # (B, npg, psz, KV, hd)
    vv = pkv.v[page_table].astype(jnp.float32)
    kk = kk.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)   # (B, KV, L, hd)
    vv = vv.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)
    scale = 1.0 / (hd ** 0.5)
    qf = qg.astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, kk) * scale
    idx = jnp.arange(L)
    valid = idx[None, :] < lengths[:, None]
    if window is not None:
        valid &= idx[None, :] > lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s_self = jnp.einsum("bkgd,bkd->bkg", qf,
                        k_self.astype(jnp.float32)) * scale
    s_all = jnp.concatenate([s, s_self[..., None]], axis=-1)
    w = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w[..., :-1], vv)
    return out + w[..., -1:] * v_self.astype(jnp.float32)[:, :, None, :]


def attend_paged(qg, pkv: PagedKV, page_table, lengths, k_self, v_self, *,
                 window: Optional[int] = None, impl: str = "auto"):
    """Dispatch between the gather reference and the Pallas flash-decode
    kernel (split-KV over pages, logsumexp-combined, then the self term is
    folded in from the fp32 partials)."""
    if impl in ("pallas", "kernels"):
        from repro.kernels import ops as kops
        from repro.kernels import flash_decode as FD
        out_p, lse = kops.flash_decode(qg, pkv.k, pkv.v, page_table,
                                       lengths, window=window)
        scale = 1.0 / (qg.shape[-1] ** 0.5)
        s_self = jnp.einsum("bkgd,bkd->bkg", qg.astype(jnp.float32),
                            k_self.astype(jnp.float32)) * scale
        return FD.combine_self(out_p, lse, s_self,
                               v_self.astype(jnp.float32))
    return _attend_pages_ref(qg, pkv, page_table, lengths, k_self, v_self,
                             window)


def paged_decode_attention(params, x, dims: A.AttnDims, pkv: PagedKV, *,
                           lengths, page_table, active=None,
                           commit: bool = True,
                           window: Optional[int] = None, impl: str = "auto"):
    """One-token decode over the paged cache — the serving counterpart of
    ``attention.decode_attention``.

    x: (B, 1, d); each slot's token sits at its OWN absolute position
    ``lengths[b]`` (rope + mask are per-slot, so ragged batches trace once).
    ``commit=False`` is the DB denoising probe: attend but never append —
    the pool is returned untouched instead of copy-discarded.

    Returns (out (B, 1, d), new_pkv).
    """
    B = x.shape[0]
    q, k, v = A.project_qkv(params, x, dims)
    posv = lengths[:, None]                       # (B, 1) per-slot positions
    q = apply_rope(q, posv, dims.rope_theta)
    k = apply_rope(k, posv, dims.rope_theta)
    KV, G, hd = dims.n_kv_heads, dims.q_per_kv, dims.head_dim
    qg = q[:, 0].reshape(B, KV, G, hd)
    k_self, v_self = k[:, 0], v[:, 0]             # (B, KV, hd)
    out = attend_paged(qg, pkv, page_table, lengths, k_self, v_self,
                       window=window, impl=impl)
    out = out.reshape(B, 1, dims.n_heads * hd).astype(x.dtype)
    out = out @ params["wo"].astype(x.dtype)
    new_pkv = append_paged(pkv, k_self, v_self, page_table, lengths,
                           active) if commit else pkv
    return out, new_pkv
