"""Train-step builders.

``make_db_train_step(dbm, b, …)`` returns a jitted step that computes the
paper's block-local loss (Eq. 6) and takes gradients ONLY for block b's unit
slice plus the shared periphery (embeddings / readout / σ-conditioning /
shared-attention weights in hybrid / encoder in audio). Activations and
optimizer state exist only for those parameters — the B× memory reduction is
structural, not simulated.

``make_e2e_train_step`` is the end-to-end backprop baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import precision as precision_mod
from repro.configs.base import TrainConfig
from repro.core.blocks import DiffusionBlocksModel
from repro.optim import adamw, apply_updates, warmup_cosine

STACK_KEYS = ("layers", "units")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Per-block anomaly guard (paper §3 independence as a FAULT boundary):
    a non-finite loss/grad-norm or a loss spike skips ONLY the offending
    block's update — its params, AdamW moments, and step counter stay put,
    and (in the block-parallel engine) its periphery gradient contribution
    is masked out of the psum. A spike is ``loss > spike_factor * ewma +
    margin`` once the block's loss EWMA is initialized (first clean step);
    ``rewind_after`` consecutive anomalies tell the supervisor
    (``repro.launch.trainrunner``) to rewind that block alone to its last
    checkpoint generation."""
    spike_factor: float = 8.0
    margin: float = 2.0
    ewma_decay: float = 0.9
    rewind_after: int = 3

    def classify(self, loss, gnorm, ewma, active=True):
        """(ok, new_ewma) — jit-safe scalars. ``ewma < 0`` means
        uninitialized (spike check disarmed); the EWMA only advances on
        clean steps so an anomaly can't drag the baseline toward itself.
        ``active=False`` (a dead pod / masked block) forces not-ok without
        touching the EWMA."""
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        spike = (ewma > 0) & (loss > self.spike_factor * ewma + self.margin)
        ok = finite & ~spike & active
        d = self.ewma_decay
        new_ewma = jnp.where(
            ok, jnp.where(ewma < 0, loss, d * ewma + (1 - d) * loss), ewma)
        return ok, new_ewma


def extract_block_view(params: Dict, start: int, size: int) -> Dict:
    """Sub-tree containing ONLY block b's unit slice + shared periphery.
    The view is itself a valid params dict whose stacks have length ``size``
    (apply with unit_range=(0, size))."""
    view = {}
    for k, v in params.items():
        if k in STACK_KEYS:
            view[k] = jax.tree_util.tree_map(
                lambda p: jax.lax.slice_in_dim(p, start, start + size, axis=0),
                v)
        else:
            view[k] = v
    return view


def write_back_block_view(params: Dict, view: Dict, start: int) -> Dict:
    out = {}
    for k, v in params.items():
        if k in STACK_KEYS:
            out[k] = jax.tree_util.tree_map(
                lambda whole, blk: jax.lax.dynamic_update_slice_in_dim(
                    whole, blk.astype(whole.dtype), start, axis=0),
                v, view[k])
        else:
            out[k] = view[k]
    return out


def make_optimizer(tcfg: TrainConfig):
    lr = warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.steps)
    return adamw(lr, tcfg.b1, tcfg.b2, tcfg.eps,
                 weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)


def make_db_train_step(dbm: DiffusionBlocksModel, b: int, tcfg: TrainConfig,
                       impl: str = "auto", jit: bool = True,
                       donate: bool = False, unit_range=None,
                       precision=None, guard: Optional[GuardConfig] = None):
    """Returns (init_opt_state_fn, step_fn).

    step_fn(params, opt_state_b, tokens, rng, aux_inputs=None)
        -> (params, opt_state_b, loss, metrics)

    ``unit_range`` overrides the block's unit slice (dry-run probes).

    ``impl="kernels"`` runs the block loss fwd+bwd entirely through the
    custom-VJP Pallas kernels; ``precision`` (repro.precision) keeps fp32
    master params and AdamW moments while the loss sees compute-dtype weight
    copies (the cast's transpose accumulates grads back to fp32). ``donate``
    donates the (params, opt_state) buffers to the jitted step so the update
    happens in place — no second copy of the model in HBM.

    ``guard`` (a ``GuardConfig``) switches to the ANOMALY-GUARDED signature:

    step_fn(params, opt_state_b, ewma, tokens, rng, aux_inputs=None,
            loss_mult=1.0) -> (params, opt_state_b, ewma, loss, metrics)

    where ``ewma`` is the block's scalar loss EWMA (pass -1.0 to start), a
    non-finite or spiking loss leaves params AND optimizer state (including
    the step counter) untouched, and ``metrics["ok"]`` reports the verdict.
    ``loss_mult`` scales the loss inside the grad (the ``grad_nan`` fault
    injection point — NaN in, guard catches it). With ``guard=None`` the
    behavior and signature are exactly the historical ones.
    """
    start, size = unit_range if unit_range is not None else dbm.ranges[b]
    pol = precision_mod.get_policy(precision)
    opt_init, opt_update = make_optimizer(tcfg)

    def init_opt(params):
        return opt_init(extract_block_view(params, start, size))

    def grads_of(params, tokens, rng, aux_inputs, loss_mult=None):
        view = extract_block_view(params, start, size)

        def loss_fn(v):
            vc = precision_mod.cast_params_for_compute(pol, v,
                                                       dbm.cfg.family)
            loss, metrics = dbm.block_loss(vc, b, tokens, rng,
                                           aux_inputs=aux_inputs,
                                           impl=impl, unit_range=(0, size),
                                           precision=pol)
            if loss_mult is not None:
                loss = loss * loss_mult
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(view)
        return view, loss, metrics, grads

    def step(params, opt_state, tokens, rng, aux_inputs=None):
        view, loss, metrics, grads = grads_of(params, tokens, rng, aux_inputs)
        updates, opt_state, om = opt_update(grads, opt_state, view)
        view = apply_updates(view, updates)
        params = write_back_block_view(params, view, start)
        metrics = {**metrics, **om}
        return params, opt_state, loss, metrics

    def guarded_step(params, opt_state, ewma, tokens, rng, aux_inputs=None,
                     loss_mult=1.0):
        view, loss, metrics, grads = grads_of(params, tokens, rng,
                                              aux_inputs, loss_mult)
        updates, opt2, om = opt_update(grads, opt_state, view)
        view2 = apply_updates(view, updates)
        ok, ewma = guard.classify(loss, om["grad_norm"], ewma)
        sel = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
        view = jax.tree_util.tree_map(sel, view2, view)
        opt_state = jax.tree_util.tree_map(sel, opt2, opt_state)
        params = write_back_block_view(params, view, start)
        metrics = {**metrics, **om, "ok": ok}
        return params, opt_state, ewma, loss, metrics

    fn = step if guard is None else guarded_step
    if jit:
        fn = jax.jit(fn, donate_argnums=(0, 1) if donate else ())
    return init_opt, fn


def make_e2e_train_step(dbm: DiffusionBlocksModel, tcfg: TrainConfig,
                        impl: str = "auto", jit: bool = True,
                        remat: bool = False, donate: bool = False,
                        precision=None):
    pol = precision_mod.get_policy(precision)
    opt_init, opt_update = make_optimizer(tcfg)

    def step(params, opt_state, tokens, rng, aux_inputs=None):
        def loss_fn(p):
            pc = precision_mod.cast_params_for_compute(pol, p,
                                                       dbm.cfg.family)
            return dbm.e2e_loss(pc, tokens, rng, aux_inputs=aux_inputs,
                                impl=impl, precision=pol)

        if remat:
            loss_fn = jax.checkpoint(loss_fn)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        updates, opt_state, om = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, {**metrics, **om}

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1) if donate else ())
    return opt_init, step


def train_db(dbm: DiffusionBlocksModel, tcfg: TrainConfig, data_iter,
             rng, params=None, log=print, aux_fn=None, parallel=None,
             periphery: str = "replicate+psum-mean", impl: str = "auto",
             precision=None, periphery_lr_scale=None):
    """Block-cycling single-host training driver (paper Fig. 3 right):
    each iteration samples a block uniformly and trains only it.

    ``parallel="blocks"`` routes to ``repro.parallel``: ALL blocks advance
    concurrently (one pod group per block when the host has the devices,
    round-robin otherwise), with the shared periphery reconciled by the
    ``periphery`` sync policy. ``tcfg.steps`` stays the total budget of
    per-block updates in both modes, so histories are comparable.
    ``periphery_lr_scale`` ("auto" = scale by B, or a float) compensates the
    parallel engine's periphery update-count gap: it applies ONE periphery
    update per batch where this sequential loop applies one per block
    update."""
    if parallel == "blocks":
        if aux_fn is not None:
            raise NotImplementedError(
                "aux_fn (modality conditioning) is not supported by the "
                "block-parallel engine yet; use the sequential path")
        from repro.parallel import train_db_parallel
        return train_db_parallel(dbm, tcfg, data_iter, rng, params=params,
                                 log=log, periphery=periphery, impl=impl,
                                 precision=precision,
                                 periphery_lr_scale=periphery_lr_scale)
    if parallel not in (None, "none"):
        raise ValueError(f"unknown parallel mode {parallel!r}")
    rng, r0 = jax.random.split(rng)
    if params is None:
        params = dbm.init(r0)
    steppers, opt_states = [], []
    for b in range(dbm.num_blocks):
        init_opt, step = make_db_train_step(dbm, b, tcfg, impl=impl,
                                            precision=precision)
        steppers.append(step)
        opt_states.append(init_opt(params))
    history = []
    for it in range(tcfg.steps):
        tokens = next(data_iter)
        aux = aux_fn(tokens) if aux_fn else None
        rng, rb, rs = jax.random.split(rng, 3)
        b = int(jax.random.randint(rb, (), 0, dbm.num_blocks))
        params, opt_states[b], loss, m = steppers[b](
            params, opt_states[b], tokens, rs, aux)
        history.append((it, b, float(loss)))
        if tcfg.log_every and it % tcfg.log_every == 0:
            log(f"[db] it={it} block={b} loss={float(loss):.4f} "
                f"gn={float(m['grad_norm']):.2f}")
    return params, history


def train_e2e(dbm: DiffusionBlocksModel, tcfg: TrainConfig, data_iter,
              rng, params=None, log=print, aux_fn=None, impl: str = "auto",
              precision=None):
    rng, r0 = jax.random.split(rng)
    if params is None:
        params = dbm.init(r0)
    init_opt, step = make_e2e_train_step(dbm, tcfg, impl=impl,
                                         precision=precision)
    opt_state = init_opt(params)
    history = []
    for it in range(tcfg.steps):
        tokens = next(data_iter)
        aux = aux_fn(tokens) if aux_fn else None
        rng, rs = jax.random.split(rng)
        params, opt_state, loss, m = step(params, opt_state, tokens, rs, aux)
        history.append((it, -1, float(loss)))
        if tcfg.log_every and it % tcfg.log_every == 0:
            log(f"[e2e] it={it} loss={float(loss):.4f}")
    return params, history
