"""Block partitioning (paper §3.3 + App. C/D).

Equi-probability partitioning: boundaries σ_b such that every block carries
exactly 1/B of p_noise's probability mass within [σ_min, σ_max]:

    σ_b = exp(P_mean + P_std Φ⁻¹(q_b)),  q_b = q_min + (b/B)(q_max − q_min),
    q_{min/max} = Φ((log σ_{min/max} − P_mean)/P_std).

Uniform partitioning (Table 7 ablation baseline) splits [σ_min, σ_max]
linearly. Overlap (App. C) expands block b's range to [σ_b/α_b, α_b σ_{b-1}]
with α_b = (σ_{b-1}/σ_b)^γ.

Everything here is host-side numpy (static at trace time).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np
from scipy.special import ndtr, ndtri

from repro.configs.base import DBConfig


def q_of_sigma(sigma, db: DBConfig):
    return ndtr((np.log(sigma) - db.p_mean) / db.p_std)


def sigma_of_q(q, db: DBConfig):
    return np.exp(db.p_mean + db.p_std * ndtri(q))


def sigma_edges(db: DBConfig) -> np.ndarray:
    """Descending edges: edges[0] = σ_max … edges[B] = σ_min. Block b
    (0-indexed, b=0 trains/serves the HIGHEST noise) covers
    [edges[b+1], edges[b]]."""
    B = db.num_blocks
    if db.partition == "uniform":
        asc = np.linspace(db.sigma_min, db.sigma_max, B + 1)
        return asc[::-1].copy()
    q_min = q_of_sigma(db.sigma_min, db)
    q_max = q_of_sigma(db.sigma_max, db)
    qs = q_min + (np.arange(B + 1) / B) * (q_max - q_min)
    asc = sigma_of_q(qs, db)
    asc[0], asc[-1] = db.sigma_min, db.sigma_max   # exact endpoints
    return asc[::-1].copy()


def block_sigma_range(db: DBConfig, b: int,
                      with_overlap: bool = True) -> Tuple[float, float]:
    """(σ_lo, σ_hi) for block b, optionally expanded by the overlap γ."""
    edges = sigma_edges(db)
    hi, lo = float(edges[b]), float(edges[b + 1])
    if with_overlap and db.overlap_gamma > 0:
        alpha = (hi / lo) ** db.overlap_gamma
        lo, hi = lo / alpha, hi * alpha
        lo = max(lo, db.sigma_min)
        hi = min(hi, db.sigma_max)
    return lo, hi


def block_qrange(db: DBConfig, b: int,
                 with_overlap: bool = True) -> Tuple[float, float]:
    lo, hi = block_sigma_range(db, b, with_overlap)
    return float(q_of_sigma(lo, db)), float(q_of_sigma(hi, db))


def block_qranges(db: DBConfig, with_overlap: bool = True) -> np.ndarray:
    """(B, 2) float32 rows of (q_lo, q_hi) per block — the array form of
    ``block_qrange`` consumed by the block-parallel engine, where the block
    index is data (a scanned/sharded axis) rather than a Python constant."""
    return np.asarray([block_qrange(db, b, with_overlap)
                       for b in range(db.num_blocks)], np.float32)


def block_mass(db: DBConfig, b: int) -> float:
    """Probability mass of p_noise in block b's (non-overlapped) range,
    normalized to the truncated support."""
    q_lo, q_hi = block_qrange(db, b, with_overlap=False)
    q_min = q_of_sigma(db.sigma_min, db)
    q_max = q_of_sigma(db.sigma_max, db)
    return (q_hi - q_lo) / (q_max - q_min)


def unit_ranges(n_units: int, num_blocks: int,
                distribution: Sequence[int] | None = None
                ) -> List[Tuple[int, int]]:
    """Contiguous (start, size) unit ranges per block. ``distribution`` gives
    explicit per-block unit counts (Table 7 ablation), default near-equal.
    Block 0 = FIRST units = highest noise (inference starts there)."""
    if distribution is None:
        base = n_units // num_blocks
        rem = n_units % num_blocks
        distribution = [base + (1 if i < rem else 0) for i in range(num_blocks)]
    assert sum(distribution) == n_units, (distribution, n_units)
    assert all(s > 0 for s in distribution)
    ranges, start = [], 0
    for s in distribution:
        ranges.append((start, s))
        start += s
    return ranges


def sampling_schedule(db: DBConfig, num_steps: int | None = None) -> np.ndarray:
    """σ sequence for inference (descending, num_steps+1 points incl. 0 end).

    Steps are placed at equal probability-mass quantiles of p_noise so each
    block serves ≈ num_steps/B steps (paper App. H). The final step targets
    σ = 0 (i.e. returns D exactly)."""
    N = num_steps or db.num_sampling_steps
    q_min = q_of_sigma(db.sigma_min, db)
    q_max = q_of_sigma(db.sigma_max, db)
    qs = q_max - (np.arange(N) / N) * (q_max - q_min)
    sig = sigma_of_q(qs, db)
    sig[0] = db.sigma_max
    return np.concatenate([sig, [0.0]])


def block_of_sigma(db: DBConfig, sigma: float) -> int:
    """Host-side: which block serves noise level σ (non-overlapped ranges)."""
    edges = sigma_edges(db)            # descending
    for b in range(db.num_blocks):
        if sigma >= edges[b + 1]:
            return b
    return db.num_blocks - 1
