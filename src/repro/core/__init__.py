# The paper's primary contribution: DiffusionBlocks — block-wise training via
# continuous-time diffusion interpretation (conversion recipe, equi-probability
# partitioning, block-local score-matching objectives, block-wise sampler).
from repro.core.blocks import DiffusionBlocksModel
from repro.core import edm, partition
from repro.core.training import (make_db_train_step, make_e2e_train_step,
                                 train_db, train_e2e)
