"""Masked diffusion language model adapter (paper §5.3 + Appendix D).

Continuous-time MDM (MD4-style) with linear schedule α(t) = 1 − t. App. D
shows the training mass is uniform in α, so DiffusionBlocks partitions the
masking schedule by equal decrements of α: block b owns
t ∈ [t_{b-1}, t_b] with t_b = b/B. Each block trains ONLY on its masking-rate
interval; the global NELBO decomposes as Σ_b L_b (Eq. 13).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig, ModelConfig
from repro.core import partition as P
from repro.models import build_model
from repro.models.common import LayerCtx
from repro.nn import adaln
from repro.nn import attention as A


class MaskedDiffusionBlocks:
    """vocab_size includes the [MASK] token at index vocab_size-1."""

    def __init__(self, cfg: ModelConfig, db: DBConfig,
                 distribution: Optional[Sequence[int]] = None):
        self.cfg, self.db = cfg, db
        self.mask_id = cfg.vocab_size - 1
        self.model = build_model(cfg, db)
        self.ranges = P.unit_ranges(self.model.n_units, db.num_blocks,
                                    distribution)

    def init(self, rng, dtype=jnp.float32):
        return self.model.init(rng, dtype)

    def block_of_t(self, t: float) -> int:
        """Block 0 serves the HIGHEST masking rates (t near 1), mirroring the
        σ ordering of the continuous case."""
        B = self.db.num_blocks
        return min(B - 1, int((1.0 - t) * B))

    def t_range(self, b: int) -> Tuple[float, float]:
        B = self.db.num_blocks
        hi = 1.0 - b / B
        lo = 1.0 - (b + 1) / B
        return lo, hi

    def _ctx(self, params, t, S):
        cond = adaln.sigma_embedding(params["cond"], t, self.db.cond_dim)
        return LayerCtx(cfg=self.cfg, mode="train", positions=jnp.arange(S),
                        mask_mod=A.bidirectional_mask, cond=cond)

    def _forward(self, params, tokens_masked, t, start, size):
        S = tokens_masked.shape[1]
        ctx = self._ctx(params, t, S)
        h = self.model.embed(params, tokens_masked)
        h, _, aux = self.model.apply_units(params, h, start, size, ctx)
        return self.model.logits(params, h), aux

    def block_loss(self, params, b, tokens, rng, unit_range=None):
        """Eq. (13): E_t∈[t_lo,t_hi] [ (−α'/(1−α)) Σ_masked CE ] with linear
        α: weight 1/t, normalized per masked token."""
        start, size = unit_range or self.ranges[b]
        Bsz, S = tokens.shape
        r_t, r_m = jax.random.split(rng)
        lo, hi = self.t_range(b)
        t = jax.random.uniform(r_t, (Bsz, 1), minval=lo, maxval=hi)
        t = jnp.maximum(t, 1e-3)
        mask = jax.random.uniform(r_m, (Bsz, S)) < t        # masked w.p. 1-α=t
        x_t = jnp.where(mask, self.mask_id, tokens)
        logits, aux = self._forward(params, x_t, t[:, 0], start, size)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, tokens[..., None], -1)[..., 0]
        w = (1.0 / t)                                        # −α'/(1−α) = 1/t
        per_tok = jnp.sum(mask * ce * w, axis=1) / S
        loss = jnp.mean(per_tok)
        return loss, {"ce": loss, "aux": aux,
                      "mask_rate": jnp.mean(mask.astype(jnp.float32))}

    def e2e_loss(self, params, tokens, rng):
        """Standard MDM (full stack, t ~ U(0,1)) — the MD4 baseline."""
        return self.block_loss(params, 0, tokens, rng,
                               unit_range=(0, self.model.n_units))

    def nelbo_bpc(self, params, tokens, rng, n_samples: int = 4,
                  blockwise: bool = True):
        """Monte-Carlo NELBO in bits/char. ``blockwise`` evaluates each t with
        the block that owns it (DB); otherwise the full stack (baseline)."""
        total = 0.0
        Bn = self.db.num_blocks if blockwise else 1
        for s in range(n_samples):
            for b in range(Bn):
                rng, r = jax.random.split(rng)
                bb = b if blockwise else 0
                if not blockwise:
                    loss, _ = self.e2e_loss(params, tokens, r)
                    total += loss
                else:
                    loss, _ = self.block_loss(params, bb, tokens, r,
                                              unit_range=None)
                    total += loss / Bn
        # each block's expectation covers 1/B of t uniformly, so averaging the
        # per-block losses IS the full-integral Monte-Carlo estimate.
        nelbo = total / n_samples          # nats per char
        return nelbo / jnp.log(2.0)

    # ------------------------------------------------------------------
    def generate(self, params, rng, batch, seq_len, num_steps=None):
        """Iterative demasking t: 1 → 0; step at time t uses block_of_t(t)."""
        N = num_steps or self.db.num_sampling_steps
        x = jnp.full((batch, seq_len), self.mask_id, jnp.int32)
        ts = jnp.linspace(1.0, 0.0, N + 1)
        for i in range(N):
            t_now, t_next = float(ts[i]), float(ts[i + 1])
            b = self.block_of_t(max(t_now, 1e-3))
            start, size = self.ranges[b]
            rng, r_c, r_u = jax.random.split(rng, 3)
            tvec = jnp.full((batch,), max(t_now, 1e-3))
            logits, _ = self._forward(params, x, tvec, start, size)
            pred = jax.random.categorical(r_c, logits.astype(jnp.float32))
            # unmask each currently-masked token w.p. (t_now - t_next)/t_now
            p_unmask = (t_now - t_next) / max(t_now, 1e-6)
            unmask = (jax.random.uniform(r_u, x.shape) < p_unmask) & \
                (x == self.mask_id)
            x = jnp.where(unmask, pred, x)
        # final: fill any leftovers greedily with block B-1
        b = self.db.num_blocks - 1
        start, size = self.ranges[b]
        logits, _ = self._forward(params, x,
                                  jnp.full((batch,), 1e-3), start, size)
        x = jnp.where(x == self.mask_id, jnp.argmax(logits, -1), x)
        return x
