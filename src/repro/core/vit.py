"""ViT classification adapter (paper §5.1 / App. B top-left, App. E.1).

Input sequence = [CLS, patch embeddings x, noisy label embedding z_σ].
Each block denoises the label token within its noise range; CE is taken
through the classification head on the denoised label embedding (Eq. 6 with
CE inner loss). Inference runs the Euler chain over blocks and classifies the
final z. The end-to-end baseline is a standard ViT ([CLS] readout).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig, ModelConfig
from repro.core import edm
from repro.core import partition as P
from repro.models import common as C
from repro.models.common import LayerCtx
from repro.nn import adaln
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn.init import ParamSpec, init_params, stack_specs


class ViTDiffusionBlocks:
    def __init__(self, cfg: ModelConfig, db: DBConfig, image_size: int = 32,
                 patch: int = 4, channels: int = 3,
                 distribution: Optional[Sequence[int]] = None):
        self.cfg, self.db = cfg, db
        self.patch, self.channels, self.image_size = patch, channels, image_size
        self.n_patches = (image_size // patch) ** 2
        self.num_classes = cfg.vocab_size
        self.ranges = P.unit_ranges(cfg.n_layers, db.num_blocks, distribution)
        self.edges = P.sigma_edges(db)
        d = cfg.d_model
        self.spec = {
            "patch": L.linear_spec(patch * patch * channels, d,
                                   (None, "embed")),
            "cls": ParamSpec((1, d), (None, "embed"), "embed", 0.02),
            "pos": ParamSpec((1 + self.n_patches + 1, d), (None, "embed"),
                             "embed", 0.02),
            "label_emb": ParamSpec((self.num_classes, d), ("vocab", "embed"),
                                   "embed", 1.0),
            "layers": stack_specs(C.tlayer_spec(cfg, db=True), cfg.n_layers),
            "final_norm": L.norm_spec(d, cfg.norm),
            "head": L.readout_spec(d, self.num_classes),
            "cond": adaln.sigma_embed_spec(db.cond_dim, d),
        }

    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.spec, dtype)

    # ------------------------------------------------------------------
    def patchify(self, images: jax.Array) -> jax.Array:
        """(B, H, W, C) -> (B, n_patches, p*p*C)."""
        B, H, W, Ch = images.shape
        p = self.patch
        x = images.reshape(B, H // p, p, W // p, p, Ch)
        return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, self.n_patches,
                                                     p * p * Ch)

    def tokens(self, params, images, z_label):
        B = images.shape[0]
        patches = L.linear(params["patch"], self.patchify(images))
        cls = jnp.broadcast_to(params["cls"], (B, 1, self.cfg.d_model))
        seq = jnp.concatenate(
            [cls, patches, z_label.astype(patches.dtype)], axis=1)
        return seq + params["pos"][None].astype(seq.dtype)

    def label_table(self, params):
        return L.l2_normalize_embeddings(params["label_emb"])

    def _run(self, params, seq, start, size, cond):
        ctx = LayerCtx(cfg=self.cfg, mode="train",
                       positions=jnp.arange(seq.shape[1]),
                       mask_mod=A.bidirectional_mask, cond=cond)
        if cond is not None:   # modulate only the label token
            cm = jnp.zeros((seq.shape[1],), bool).at[-1].set(True)
            ctx.cond_mask = cm
        lp = jax.tree_util.tree_map(lambda p: p[start:start + size],
                                    params["layers"])

        def step(h, p):
            h, _, _ = C.tlayer_apply(p, h, ctx)
            return h, None

        h, _ = jax.lax.scan(step, seq, lp)
        return h

    # ------------------------------------------------------------------
    def block_loss(self, params, b, images, labels, rng,
                   unit_range=None) -> Tuple[jax.Array, dict]:
        start, size = unit_range or self.ranges[b]
        Bsz = images.shape[0]
        r_s, r_e = jax.random.split(rng)
        q_lo, q_hi = P.block_qrange(self.db, b)
        sigma = edm.sample_sigma_in_qrange(r_s, (Bsz, 1, 1), self.db,
                                           q_lo, q_hi)
        y_emb = self.label_table(params)[labels][:, None]          # (B,1,d)
        z, _ = edm.add_noise(r_e, y_emb, sigma)
        c_skip, c_out, c_in, _ = edm.preconditioning(sigma, self.db.sigma_data)
        cond = adaln.sigma_embedding(params["cond"],
                                     jnp.log(sigma.reshape(-1)) / 4.0,
                                     self.db.cond_dim)
        seq = self.tokens(params, images, c_in * z)
        h = self._run(params, seq, start, size, cond)
        f_out = h[:, -1:]
        d_hat = edm.denoise_combine(z, f_out.astype(jnp.float32), sigma,
                                    self.db.sigma_data)
        d_hat = L.apply_norm(params["final_norm"], d_hat.astype(h.dtype),
                             self.cfg.norm)
        logits = L.readout(params["head"], d_hat[:, 0])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return jnp.mean(ce), {"ce": jnp.mean(ce)}

    def e2e_loss(self, params, images, labels, rng=None):
        """Standard ViT baseline: [CLS, patches] through all layers, head on
        CLS. (The label slot is fed zeros, conditioning off.)"""
        Bsz = images.shape[0]
        z0 = jnp.zeros((Bsz, 1, self.cfg.d_model))
        seq = self.tokens(params, images, z0)
        h = self._run(params, seq, 0, self.cfg.n_layers, cond=None)
        cls = L.apply_norm(params["final_norm"], h[:, 0], self.cfg.norm)
        logits = L.readout(params["head"], cls)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return jnp.mean(ce), {"ce": jnp.mean(ce)}

    # ------------------------------------------------------------------
    def predict(self, params, images, rng, num_steps: Optional[int] = None):
        """Euler chain σ_max→0 over the blocks; classify the final z."""
        steps = num_steps or max(self.db.num_blocks,
                                 self.cfg.n_layers // self.db.num_blocks)
        sched = P.sampling_schedule(self.db, steps)
        Bsz = images.shape[0]
        z = self.db.sigma_max * jax.random.normal(
            rng, (Bsz, 1, self.cfg.d_model))
        for i in range(len(sched) - 1):
            s_from, s_to = float(sched[i]), float(sched[i + 1])
            b = P.block_of_sigma(self.db, s_from)
            start, size = self.ranges[b]
            sig = jnp.full((Bsz, 1, 1), s_from)
            _, _, c_in, _ = edm.preconditioning(sig, self.db.sigma_data)
            cond = adaln.sigma_embedding(params["cond"],
                                         jnp.log(sig.reshape(-1)) / 4.0,
                                         self.db.cond_dim)
            seq = self.tokens(params, images, c_in * z)
            h = self._run(params, seq, start, size, cond)
            d_hat = edm.denoise_combine(z, h[:, -1:].astype(jnp.float32),
                                        sig, self.db.sigma_data)
            z = edm.euler_step(z, d_hat, s_from, s_to) if s_to > 0 else d_hat
        zf = L.apply_norm(params["final_norm"], z.astype(h.dtype),
                          self.cfg.norm)
        logits = L.readout(params["head"], zf[:, 0])
        return jnp.argmax(logits, -1), logits

    def predict_e2e(self, params, images):
        Bsz = images.shape[0]
        z0 = jnp.zeros((Bsz, 1, self.cfg.d_model))
        seq = self.tokens(params, images, z0)
        h = self._run(params, seq, 0, self.cfg.n_layers, cond=None)
        cls = L.apply_norm(params["final_norm"], h[:, 0], self.cfg.norm)
        logits = L.readout(params["head"], cls)
        return jnp.argmax(logits, -1), logits
