"""DiffusionBlocks conversion (paper §3.1–3.3) — the framework's core.

``DiffusionBlocksModel`` wraps any family model (``repro.models``) and exposes:

  * block partitioning: unit ranges per block + equi-probability noise ranges;
  * per-block training losses (paper Eq. 6) via the AR adapter (App. E.4),
    in ``concat`` (clean‖noisy single stream, modified causal mask) or
    ``two_pass`` (paired streams; required for SSM/hybrid) mode;
  * end-to-end baseline loss (vanilla next-token CE) for the comparisons;
  * block-wise inference: the Euler sampler (Eq. 5) that denoises the next
    token's embedding through the blocks, plus ``serve_step`` used by the
    dry-run decode shapes.

Block independence is structural: ``block_loss(params, b, …)`` only ever
*reads* units[start_b : start_b+size_b] (+ shared embed/head/cond), so
gradients for other blocks are never materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import precision as precision_mod
from repro.configs.base import HYBRID, SSM, DBConfig, ModelConfig
from repro.core import edm
from repro.core import partition as P
from repro.models import build_model
from repro.models.common import LayerCtx
from repro.nn import attention as A
from repro.nn.scan_util import uscan


def chunked_ce(model, params, h: jax.Array, targets: jax.Array,
               chunk: int = 512) -> jax.Array:
    """Memory-safe cross-entropy through the readout: the (S, vocab) logits
    are never materialized for the full sequence — per-chunk logits are
    computed, reduced, and REMATERIALIZED in the backward pass
    (jax.checkpoint). Standard production-LM trick; cuts the loss memory from
    O(S·V) to O(chunk·V)."""
    B, S = targets.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = h.shape[1] // chunk
    hc = h.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h_i, t_i):
        logits = model.logits(params, h_i)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.maximum(t_i, 0)
        ce = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(jnp.where(t_i >= 0, ce, 0.0))

    def step(tot, xs):
        h_i, t_i = xs
        return tot + one(h_i, t_i), None

    total, _ = uscan(step, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (B * S)


def _needs_two_pass(cfg: ModelConfig) -> bool:
    """SSM recurrences have no attention mask — the concat trick does not
    apply (DESIGN.md §Arch-applicability)."""
    return cfg.family in (HYBRID, SSM)


class DiffusionBlocksModel:
    def __init__(self, cfg: ModelConfig, db: DBConfig,
                 distribution: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.db = db
        self.model = build_model(cfg, db)
        self.edges = P.sigma_edges(db)                     # descending, B+1
        self.ranges = P.unit_ranges(self.model.n_units, db.num_blocks,
                                    distribution)
        self.causal_mode = ("two_pass" if _needs_two_pass(cfg)
                            else db.causal_mode)

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.db.num_blocks

    def init(self, rng, dtype=jnp.float32):
        return self.model.init(rng, dtype)

    def sample_block_sigma(self, rng, shape, b: int) -> jax.Array:
        q_lo, q_hi = P.block_qrange(self.db, b, with_overlap=True)
        return edm.sample_sigma_in_qrange(rng, shape, self.db, q_lo, q_hi)

    # ------------------------------------------------------------------
    # conditioning inputs (modality frontends live on the model —
    # ``model.encode_conditioning`` is the ONE code path shared by the
    # training losses, the dense dry-run shapes, and the serving engine's
    # admission-time encode)
    # ------------------------------------------------------------------
    def make_ctx(self, params, S: int, mode: str, sigma=None,
                 aux_inputs: Optional[Dict[str, jax.Array]] = None,
                 precision=None, cond_lengths=None, **kw) -> LayerCtx:
        ctx = LayerCtx(cfg=self.cfg, mode=mode, positions=jnp.arange(S),
                       precision=precision_mod.get_policy(precision),
                       cond_lengths=cond_lengths, **kw)
        if sigma is not None:
            ctx.cond = self.model.cond(params, jnp.log(sigma.reshape(-1)))
        # decode reads cross-attention K/V from the cache (filled at prefill
        # or at engine admission); re-encoding the modality frontend per
        # decode step would be wasted.
        if mode != "decode":
            kv_x = self.model.encode_conditioning(params, aux_inputs, ctx)
            if kv_x is not None:
                ctx.kv_x = kv_x
                ctx.kv_positions = jnp.arange(kv_x.shape[1])
        return ctx

    # ------------------------------------------------------------------
    # Training losses
    # ------------------------------------------------------------------
    def block_loss(self, params, b: int, tokens: jax.Array, rng,
                   aux_inputs=None, impl: str = "auto",
                   unit_range: Optional[Tuple[int, int]] = None,
                   sigma_qrange: Optional[Tuple] = None,
                   precision=None) -> Tuple[jax.Array, Dict]:
        """Paper Eq. (6) for the AR adapter: noisy slot i carries
        z_i = emb(x_i) + σ ε, conditioned on clean x_{<i}; the block denoises
        it and CE is taken through the readout. σ ~ p_noise restricted to
        block b's (overlap-expanded) range, one σ per example.

        ``sigma_qrange`` overrides the block-derived (q_lo, q_hi) noise range
        with (possibly traced) values — the block-parallel engine trains all
        blocks in one program, so the range must be data, not a constant.

        ``precision`` (repro.precision policy) sets the compute dtype of the
        hidden stream; the σ-preconditioning, denoiser combine, and loss
        reductions stay fp32 regardless (reduce_dtype)."""
        pol = precision_mod.get_policy(precision)
        cd = pol.compute_for(self.cfg.family)
        Bsz, S = tokens.shape
        start, size = unit_range if unit_range is not None else self.ranges[b]
        r_sig, r_eps = jax.random.split(rng)
        if sigma_qrange is not None:
            q_lo, q_hi = sigma_qrange
            sigma = edm.sample_sigma_in_qrange(r_sig, (Bsz, 1, 1), self.db,
                                               q_lo, q_hi)
        else:
            sigma = self.sample_block_sigma(r_sig, (Bsz, 1, 1), b)

        table = self.model.embedding_table(params)
        emb_clean = table[tokens]
        z, _ = edm.add_noise(r_eps, emb_clean.astype(jnp.float32), sigma)
        c_skip, c_out, c_in, _ = edm.preconditioning(sigma, self.db.sigma_data)
        z_in = (c_in * z).astype(cd)

        if self.causal_mode == "concat":
            stream = jnp.concatenate([emb_clean.astype(cd), z_in], axis=1)
            ctx = self.make_ctx(params, 2 * S, "train", sigma, aux_inputs,
                                impl=impl, precision=pol)
            ctx.mask_mod = A.db_concat_mask(S)
            ctx.rope_positions = jnp.concatenate(
                [jnp.arange(S), jnp.arange(S)])
            ctx.cond_mask = jnp.arange(2 * S) >= S
            h, _, aux = self.model.apply_units(params, stream, start, size, ctx)
            f_out = h[:, S:]
        else:
            ctx = self.make_ctx(params, S, "train", sigma, aux_inputs,
                                impl=impl, precision=pol)
            _, f_out, aux = self.model.apply_units_two_pass(
                params, emb_clean.astype(cd), z_in, start, size, ctx)

        if self.db.loss == "l2":
            # Eq. (6) score matching in F-space (continuous targets): the
            # fused kernel never materializes the (y − c_skip z)/c_out target
            # in HBM; fwd AND bwd run through the custom-VJP Pallas path.
            sig_b = sigma.reshape(Bsz)
            f32 = f_out.astype(jnp.float32)
            y32 = emb_clean.astype(jnp.float32)
            if impl == "kernels":
                from repro.kernels import ops as kops
                loss = kops.edm_loss(f32, z, y32, sig_b,
                                     sigma_data=self.db.sigma_data)
            else:
                loss = edm.edm_l2_loss(f32, z, y32, sigma, self.db.sigma_data)
            metrics = {"l2": loss}
        else:
            d_hat = edm.denoise_combine(z, f_out.astype(jnp.float32), sigma,
                                        self.db.sigma_data)
            loss = chunked_ce(self.model, params,
                              d_hat.astype(emb_clean.dtype), tokens)
            metrics = {"ce": loss}
        metrics.update({"loss": loss, "aux": aux,
                        "sigma_mean": jnp.mean(sigma)})
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.router_aux_weight * aux
        return loss, metrics

    def e2e_loss(self, params, tokens, rng=None, aux_inputs=None,
                 impl: str = "auto", precision=None):
        """Standard end-to-end next-token CE over the FULL stack — the
        backprop baseline the paper compares against (model built with the
        same AdaLN params; cond=None keeps them inert)."""
        pol = precision_mod.get_policy(precision)
        Bsz, S = tokens.shape
        ctx = self.make_ctx(params, S, "train", None, aux_inputs, impl=impl,
                            precision=pol)
        h = self.model.embed(params, tokens,
                             dtype=pol.compute_for(self.cfg.family))
        h, _, aux = self.model.apply_units(params, h, 0, self.model.n_units,
                                           ctx)
        loss = chunked_ce(self.model, params, h[:, :-1], tokens[:, 1:])
        metrics = {"ce": loss, "aux": aux}
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.router_aux_weight * aux
        return loss, metrics

    # ------------------------------------------------------------------
    # Inference: block-wise Euler sampling of the next token (App. B / H)
    # ------------------------------------------------------------------
    def denoise_schedule(self, steps_per_block: int = 1) -> list:
        """[(block, σ_from, σ_to)] — descending; the last step lands on 0."""
        out = []
        Bn = self.num_blocks
        for b in range(Bn):
            hi, lo = float(self.edges[b]), float(self.edges[b + 1])
            if b == Bn - 1:
                lo = 0.0
            qs = np.linspace(hi, lo, steps_per_block + 1)
            for i in range(steps_per_block):
                out.append((b, float(qs[i]), float(qs[i + 1])))
        return out

    def _probe_block(self, params, b: int, z: jax.Array, sigma: float,
                     cache, pos, ctx_base: LayerCtx) -> jax.Array:
        """Run block b's units over one noisy token (decode probe:
        ``commit=False`` — caches are read, never appended). Returns F
        (B,1,d)."""
        start, size = self.ranges[b]
        sig = jnp.full((z.shape[0], 1, 1), sigma, jnp.float32)
        _, _, c_in, _ = edm.preconditioning(sig, self.db.sigma_data)
        ctx = dataclasses.replace(ctx_base, mode="decode", pos=pos,
                                  commit=False)
        ctx.cond = self.model.cond(params, jnp.log(sig.reshape(-1)))
        sub_cache = jax.tree_util.tree_map(
            lambda c: c[start:start + size], cache)
        h = (c_in * z).astype(z.dtype)
        h, _, _ = self.model.apply_units(params, h, start, size, ctx,
                                         sub_cache)
        return h

    def denoise_next_token(self, params, cache, pos, rng, ctx_base,
                           steps_per_block: int = 1) -> jax.Array:
        """Full Euler chain (σ_max → 0) for the token at ``pos`` (dense
        caches) or at each slot's ``ctx_base.lengths`` (paged serving cache).
        Returns the denoised embedding D (B,1,d).

        ``steps_per_block`` is a PYTHON int: the schedule is unrolled at
        trace time, so under ``jax.jit`` it MUST be a static argument — each
        distinct value compiles its own program, and passing it as a traced
        value fails. ``launch.serve`` bakes it into the jitted engine
        closures once; ad-hoc callers should use
        ``static_argnames=("steps_per_block",)`` rather than thrashing the
        jit cache with wrapper lambdas."""
        batch = (ctx_base.lengths.shape[0] if ctx_base.lengths is not None
                 else self.model.cache_batch(cache))
        d = self.cfg.d_model
        z = self.db.sigma_max * jax.random.normal(rng, (batch, 1, d))
        for b, s_from, s_to in self.denoise_schedule(steps_per_block):
            f = self._probe_block(params, b, z, s_from, cache, pos, ctx_base)
            sig = jnp.asarray(s_from, jnp.float32)
            d_hat = edm.denoise_combine(z, f.astype(jnp.float32), sig,
                                        self.db.sigma_data)
            z = edm.euler_step(z, d_hat, s_from, max(s_to, 0.0)) \
                if s_to > 0 else d_hat
            z = z.astype(f.dtype)
        return z

    def commit_token(self, params, cache, pos, token, ctx_base):
        """Append the chosen clean token to every unit's cache in ONE scan.

        Training-consistent: each block's clean stream starts from RAW token
        embeddings (blocks are independent denoisers — block b never sees
        block b-1's output). The scan body resets the hidden stream to the
        embedding at every block boundary (``reset_mask``), so the commit
        traces a single ``lax.scan`` over ALL units — tracing cost no longer
        scales with ``num_blocks`` (the seed looped blocks in Python and
        re-concatenated the cache pytree per token). Total cost is still L
        layer evaluations."""
        ctx = dataclasses.replace(ctx_base, mode="decode", pos=pos, cond=None)
        pol = precision_mod.get_policy(ctx.precision)
        # absolute-position-embedding families (whisper) embed the token at
        # its true offset: per-slot lengths on the paged path, pos on dense
        if ctx.lengths is not None:
            epos = ctx.lengths[:, None]
        elif pos is not None:
            epos = jnp.asarray(pos).reshape(1, 1)
        else:
            epos = None
        emb = self.model.embed(params, token,
                               dtype=pol.compute_for(self.cfg.family),
                               positions=epos)
        starts = self._block_starts()
        _, new_cache, _ = self.model.apply_units(
            params, emb, 0, self.model.n_units, ctx, cache,
            reset_mask=starts)
        return new_cache

    def _block_starts(self) -> jax.Array:
        starts = np.zeros(self.model.n_units, dtype=bool)
        for b in range(self.num_blocks):
            starts[self.ranges[b][0]] = True
        return jnp.asarray(starts)

    def sample_token(self, logits, rng, temperature: float = 0.0,
                     top_k: int = 0):
        """Greedy (``temperature == 0``) or temperature / top-k sampling.
        Both are fully traced — temperature/top_k are static Python values
        selecting the trace, rng is data — so sampling lives INSIDE the
        scan-fused generation loop (no per-token host round-trip)."""
        logits = logits.astype(jnp.float32)
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        logits = logits / temperature
        if top_k and top_k < logits.shape[-1]:
            kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
        return jax.random.categorical(rng, logits)

    def serve_step(self, params, cache, pos, rng, aux_inputs=None,
                   steps_per_block: int = 1, temperature: float = 0.0,
                   top_k: int = 0, cond_lengths=None):
        """One generation step over DENSE caches: denoise token at ``pos``
        through the blocks, sample, commit. This is what decode dry-run
        shapes lower; the paged serving engine uses ``serve_step_paged``.
        ``cond_lengths`` masks the cross (conditioning) blocks per row when
        the dense cache was filled via ``model.set_conditioning`` (ragged
        conditioning); None keeps the unmasked read of prefill-sized blocks.
        ``steps_per_block``/``temperature``/``top_k`` are static under jit
        (see denoise_next_token). Returns (token (B,), new_cache)."""
        ctx_base = self.make_ctx(params, 1, "decode", None, aux_inputs,
                                 cond_lengths=cond_lengths)
        ctx_base.positions = None
        r_noise, r_samp = jax.random.split(rng)
        d_final = self.denoise_next_token(params, cache, pos, r_noise,
                                          ctx_base, steps_per_block)
        logits = self.model.logits(params, d_final)
        token = self.sample_token(logits[:, 0], r_samp, temperature, top_k)
        new_cache = self.commit_token(params, cache, pos, token[:, None],
                                      ctx_base)
        return token, new_cache

    # ------------------------------------------------------------------
    # Paged serving steps (repro.nn.cache pools; used by launch.serve)
    # ------------------------------------------------------------------
    def _paged_ctx(self, params, lengths, page_table, active, precision,
                   impl, cond_lengths=None) -> LayerCtx:
        ctx = self.make_ctx(params, 1, "decode", None, None,
                            precision=precision, impl=impl,
                            cond_lengths=cond_lengths)
        ctx.positions = None
        ctx.lengths = lengths
        ctx.page_table = page_table
        ctx.active = active
        return ctx

    def serve_step_paged(self, params, kv, page_table, lengths, rng, *,
                         active=None, steps_per_block: int = 1,
                         temperature: float = 0.0, top_k: int = 0,
                         precision=None, impl: str = "auto",
                         cond_lengths=None):
        """One generation step over the PAGED serving cache: each slot
        denoises + commits at its OWN position ``lengths[b]`` (ragged batches
        share this one trace). ``active`` masks slots that commit this step —
        inactive slots compute but write nothing (KV appends are redirected
        to the trash page, recurrent states held). Conditioned slots read
        their cross memory from the cache (written once at admission by
        ``model.set_conditioning``) under the per-slot valid length
        ``cond_lengths`` — aux inputs are never re-encoded per step. Keyword
        config is static under jit. Returns (token (B,), new_kv,
        new_lengths)."""
        ctx = self._paged_ctx(params, lengths, page_table, active, precision,
                              impl, cond_lengths)
        r_noise, r_samp = jax.random.split(rng)
        d_final = self.denoise_next_token(params, kv, None, r_noise, ctx,
                                          steps_per_block)
        logits = self.model.logits(params, d_final)
        token = self.sample_token(logits[:, 0], r_samp, temperature, top_k)
        new_kv = self.commit_token(params, kv, None, token[:, None], ctx)
        new_lengths = lengths + (active.astype(lengths.dtype)
                                 if active is not None else 1)
        return token, new_kv, new_lengths

    def commit_prompt_token(self, params, kv, page_table, lengths, token, *,
                            active=None, precision=None, impl: str = "auto",
                            cond_lengths=None):
        """Prefill building block: commit a known (prompt) token at each
        slot's ``lengths[b]`` without the denoising probe. Returns
        (new_kv, new_lengths)."""
        ctx = self._paged_ctx(params, lengths, page_table, active, precision,
                              impl, cond_lengths)
        new_kv = self.commit_token(params, kv, None, token, ctx)
        new_lengths = lengths + (active.astype(lengths.dtype)
                                 if active is not None else 1)
        return new_kv, new_lengths

    def commit_prompt_chunk(self, params, kv, page_table, lengths, tokens, *,
                            n_valid, precision=None, impl: str = "auto",
                            cond_lengths=None):
        """Chunked-prefill building block: commit up to C known (prompt)
        tokens per slot in ONE dispatch — a prompt of S tokens costs
        ceil(S / C) of these instead of S ``commit_prompt_token`` steps.

        tokens: (B, C) — slot b's next prompt tokens starting at its own
        offset ``lengths[b]`` (entries past ``n_valid[b]`` are padding:
        attention writes them to the trash page, recurrent states hold).
        Each block's clean stream restarts from raw embeddings at the block
        boundaries exactly as in ``commit_token``; attention layers append
        the chunk's K/V to pool pages and attend [history || intra-chunk
        causal] via ``cache.paged_prefill_attention`` (the flash-prefill
        kernel under ``impl="kernels"``); recurrent units advance their
        state over the chunk with one in-dispatch scan.

        Returns (new_kv, lengths + n_valid).
        """
        ctx = self._paged_ctx(params, lengths, page_table, None, precision,
                              impl, cond_lengths)
        ctx.mode = "prefill_chunk"
        ctx.n_valid = n_valid
        pol = precision_mod.get_policy(ctx.precision)
        C = tokens.shape[1]
        epos = lengths[:, None] + jnp.arange(C, dtype=lengths.dtype)[None, :]
        emb = self.model.embed(params, tokens,
                               dtype=pol.compute_for(self.cfg.family),
                               positions=epos)
        _, new_kv, _ = self.model.apply_units(
            params, emb, 0, self.model.n_units, ctx, kv,
            reset_mask=self._block_starts())
        return new_kv, lengths + n_valid

    def prefill_probe(self, params, tokens, k: int, aux_inputs=None,
                      impl: str = "auto"):
        """Dry-run probe: prefill over only the first k units (cost
        extrapolation — see launch/dryrun.py)."""
        S = tokens.shape[1]
        ctx = self.make_ctx(params, S, "prefill", None, aux_inputs, impl=impl)
        emb = self.model.embed(params, tokens)
        h, sub, _ = self.model.apply_units(params, emb, 0, k, ctx)
        return self.model.logits(params, h[:, -1:]), sub

    def prefill(self, params, tokens, aux_inputs=None, impl: str = "auto"):
        """Clean-stream prefill of all units' caches over a prompt. Each
        block's clean stream starts from raw embeddings (see commit_token)."""
        S = tokens.shape[1]
        ctx = self.make_ctx(params, S, "prefill", None, aux_inputs, impl=impl)
        emb = self.model.embed(params, tokens)
        parts, h_last = [], None
        for b in range(self.num_blocks):
            start, size = self.ranges[b]
            h_last, sub, _ = self.model.apply_units(params, emb, start, size,
                                                    ctx)
            parts.append(sub)
        cache = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        logits = self.model.logits(params, h_last[:, -1:])
        return logits, cache
