"""EDM machinery (Karras et al. 2022) as used by the paper (§2.1, App. C/E).

Variance-Exploding formulation: z_σ = y + σ ε. Denoiser parameterization

    D_θ(z; σ) = c_skip(σ) z + c_out(σ) F_θ(c_in(σ) z; c_noise(σ))

with  c_skip = σ_d²/(σ²+σ_d²),  c_out = σ σ_d/√(σ²+σ_d²),
      c_in  = 1/√(σ²+σ_d²),    c_noise = log(σ)/4,
and loss weighting w(σ) = (σ²+σ_d²)/(σ σ_d)².  Note w(σ)·c_out(σ)² ≡ 1, so the
L2 objective expressed in F-space has unit weight — we exploit this for
numerical stability (and use unweighted CE for discrete targets, where the
same identity motivates weight 1 after the readout).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig


def weighting(sigma: jax.Array, sigma_data: float) -> jax.Array:
    return (sigma ** 2 + sigma_data ** 2) / (sigma * sigma_data) ** 2


def preconditioning(sigma: jax.Array, sigma_data: float):
    """Returns (c_skip, c_out, c_in, c_noise); sigma broadcastable."""
    s2 = sigma ** 2
    d2 = sigma_data ** 2
    c_skip = d2 / (s2 + d2)
    c_out = sigma * sigma_data * jax.lax.rsqrt(s2 + d2)
    c_in = jax.lax.rsqrt(s2 + d2)
    c_noise = jnp.log(sigma) / 4.0
    return c_skip, c_out, c_in, c_noise


def sample_sigma_in_qrange(rng, shape, db: DBConfig, q_lo, q_hi) -> jax.Array:
    """Truncated log-normal sampling via inverse CDF on uniform q in
    [q_lo, q_hi] (q is the CDF of log σ under N(P_mean, P_std²))."""
    u = jax.random.uniform(rng, shape, minval=q_lo, maxval=q_hi)
    # ndtri = inverse standard normal CDF
    from jax.scipy.special import ndtri
    return jnp.exp(db.p_mean + db.p_std * ndtri(u))


def add_noise(rng, y: jax.Array, sigma: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """y: (..., d); sigma broadcastable to y[..., :1]. Returns (z_sigma, eps)."""
    eps = jax.random.normal(rng, y.shape, jnp.float32)
    return y + sigma * eps.astype(y.dtype), eps


def denoise_combine(z: jax.Array, f_out: jax.Array, sigma: jax.Array,
                    sigma_data: float) -> jax.Array:
    """D = c_skip z + c_out F. z is the UNSCALED noisy input (the block saw
    c_in·z)."""
    c_skip, c_out, _, _ = preconditioning(sigma, sigma_data)
    return c_skip * z + c_out * f_out


def edm_l2_loss(f_out: jax.Array, z: jax.Array, y: jax.Array,
                sigma: jax.Array, sigma_data: float) -> jax.Array:
    """w(σ)·||D − y||² rewritten in F-space with unit weight:
    ||F − (y − c_skip z)/c_out||² (elementwise mean)."""
    c_skip, c_out, _, _ = preconditioning(sigma, sigma_data)
    target = (y - c_skip * z) / c_out
    return jnp.mean(jnp.square(f_out.astype(jnp.float32)
                               - target.astype(jnp.float32)))


def euler_step(z: jax.Array, d_hat: jax.Array, sigma_from: jax.Array,
               sigma_to: jax.Array) -> jax.Array:
    """PF-ODE Euler step σ_from -> σ_to (< σ_from), paper Eq. (5).

    dz/dσ = (z − D)/σ  ⇒  z' = z + (σ_to − σ_from)(z − D)/σ_from
                           = (σ_to/σ_from) z + (1 − σ_to/σ_from) D.
    (At σ_to = 0 this returns D exactly — the update moves TOWARD the
    denoiser output; the transcribed Eq. (4) has the difference reversed,
    which moves away from D and cannot reach the data manifold; we implement
    the sign consistent with Eq. (1)+Tweedie.)"""
    r = sigma_to / sigma_from
    return r * z + (1.0 - r) * d_hat
