"""Recurrent-depth (Huginn) adapter — paper §5.5 / App. E.5 / Fig. 1 right.

Architecture: prelude (2 layers) → recurrent core (4 layers, applied K times)
→ coda (2 layers). The baseline trains with K recurrences and truncated BPTT
(last ``bptt_k`` iterations carry gradients). DiffusionBlocks reinterprets the
recurrence as a diffusion process: the core is trained as a single-pass
denoiser D(z_σ, x, σ) — eliminating the K-fold training compute — while
inference keeps K iterations, now as Euler steps of the PF-ODE.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig, ModelConfig
from repro.core import edm
from repro.core import partition as P
from repro.models import common as C
from repro.models.common import LayerCtx
from repro.nn import adaln
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn.init import init_params, stack_specs


class RecurrentDepthModel:
    def __init__(self, cfg: ModelConfig, db: DBConfig, prelude: int = 2,
                 coda: int = 2, recurrence: int = 32, bptt_k: int = 8):
        self.cfg, self.db = cfg, db
        self.K, self.bptt_k = recurrence, bptt_k
        d = cfg.d_model
        self.spec = {
            "embed": L.embed_spec(cfg.vocab_size, d),
            "prelude": stack_specs(C.tlayer_spec(cfg, db=False), prelude),
            # the core is σ-conditioned (AdaLN) — it IS the denoiser
            "core": stack_specs(C.tlayer_spec(cfg, db=True), cfg.n_layers),
            "adapter": L.linear_spec(2 * d, d, (None, "embed")),
            "coda": stack_specs(C.tlayer_spec(cfg, db=False), coda),
            "final_norm": L.norm_spec(d, cfg.norm),
            "head": L.readout_spec(d, cfg.vocab_size),
            "cond": adaln.sigma_embed_spec(db.cond_dim, d),
        }

    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.spec, dtype)

    def _stack(self, layers_params, h, ctx):
        def step(carry, p):
            h, _, _ = C.tlayer_apply(p, carry, ctx)
            return h, None
        h, _ = jax.lax.scan(step, h, layers_params)
        return h

    def _embed_ctx(self, tokens):
        S = tokens.shape[1]
        return LayerCtx(cfg=self.cfg, mode="train", positions=jnp.arange(S))

    def prelude_out(self, params, tokens):
        ctx = self._embed_ctx(tokens)
        table = L.l2_normalize_embeddings(params["embed"]["table"])
        h = table[tokens]
        return self._stack(params["prelude"], h, ctx), ctx

    def core_once(self, params, e, s, ctx):
        """One core application: s' from adapter([s, e]) through core layers."""
        x = jnp.concatenate([s, e], axis=-1)
        h = L.linear(params["adapter"], x)
        return self._stack(params["core"], h, ctx)

    def readout(self, params, s, ctx):
        h = self._stack(params["coda"], s, ctx)
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm)
        return L.readout(params["head"], h)

    # ------------------------------------------------------------------
    # Baseline: K-iteration recurrence with truncated BPTT
    # ------------------------------------------------------------------
    def baseline_loss(self, params, tokens, rng):
        e, ctx = self.prelude_out(params, tokens)
        s = self.db.sigma_data * jax.random.normal(rng, e.shape, e.dtype)
        for k in range(self.K):
            if k == self.K - self.bptt_k:
                s = jax.lax.stop_gradient(s)
            s = s + self.core_once(params, e, s, ctx)
        logits = self.readout(params, s, ctx)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)[..., 0]
        return jnp.mean(ce), {"ce": jnp.mean(ce)}

    # ------------------------------------------------------------------
    # DiffusionBlocks: single-pass denoiser training (B=1 over the core)
    # ------------------------------------------------------------------
    def db_loss(self, params, tokens, rng):
        """AR adapter with the core as one block: noisy slot i carries
        z = emb(x_i) + σε with σ ~ p_noise over the FULL range; one forward
        pass, no BPTT. Causal consistency via the concat mask."""
        Bsz, S = tokens.shape
        r_s, r_e = jax.random.split(rng)
        q_lo = float(P.q_of_sigma(self.db.sigma_min, self.db))
        q_hi = float(P.q_of_sigma(self.db.sigma_max, self.db))
        sigma = edm.sample_sigma_in_qrange(r_s, (Bsz, 1, 1), self.db,
                                           q_lo, q_hi)
        e, _ = self.prelude_out(params, tokens)
        table = L.l2_normalize_embeddings(params["embed"]["table"])
        y = table[tokens]
        z, _ = edm.add_noise(r_e, y, sigma)
        c_skip, c_out, c_in, _ = edm.preconditioning(sigma, self.db.sigma_data)

        ctx = LayerCtx(cfg=self.cfg, mode="train",
                       positions=jnp.arange(2 * S),
                       rope_positions=jnp.concatenate([jnp.arange(S),
                                                       jnp.arange(S)]),
                       mask_mod=A.db_concat_mask(S))
        ctx.cond = adaln.sigma_embedding(params["cond"],
                                         jnp.log(sigma.reshape(-1)) / 4.0,
                                         self.db.cond_dim)
        ctx.cond_mask = jnp.arange(2 * S) >= S
        e2 = jnp.concatenate([e, e], axis=1)
        s2 = jnp.concatenate([e.astype(z.dtype),
                              (c_in * z).astype(z.dtype)], axis=1)
        f = self.core_once(params, e2, s2, ctx)[:, S:]
        d_hat = edm.denoise_combine(z, f.astype(jnp.float32), sigma,
                                    self.db.sigma_data)
        ctx_r = self._embed_ctx(tokens)
        logits = self.readout(params, d_hat.astype(f.dtype), ctx_r)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, tokens[..., None], -1)[..., 0]
        return jnp.mean(ce), {"ce": jnp.mean(ce)}

    # ------------------------------------------------------------------
    def db_generate_logits(self, params, tokens, num_steps=None):
        """Teacher-forced parallel sampling of all positions (evaluation):
        K Euler steps of the core as denoiser, conditioned on the clean
        prefix via the concat mask (positions denoise in parallel)."""
        Bsz, S = tokens.shape
        N = num_steps or self.K
        sched = P.sampling_schedule(self.db, N)
        e, _ = self.prelude_out(params, tokens)
        rng = jax.random.PRNGKey(0)
        z = self.db.sigma_max * jax.random.normal(rng, e.shape, jnp.float32)
        ctx = LayerCtx(cfg=self.cfg, mode="train",
                       positions=jnp.arange(2 * S),
                       rope_positions=jnp.concatenate([jnp.arange(S),
                                                       jnp.arange(S)]),
                       mask_mod=A.db_concat_mask(S))
        ctx.cond_mask = jnp.arange(2 * S) >= S
        e2 = jnp.concatenate([e, e], axis=1)
        for i in range(N):
            s_from, s_to = float(sched[i]), float(sched[i + 1])
            sig = jnp.full((Bsz, 1, 1), s_from)
            _, _, c_in, _ = edm.preconditioning(sig, self.db.sigma_data)
            ctx.cond = adaln.sigma_embedding(
                params["cond"], jnp.log(sig.reshape(-1)) / 4.0,
                self.db.cond_dim)
            s2 = jnp.concatenate([e.astype(e.dtype),
                                  (c_in * z).astype(e.dtype)], axis=1)
            f = self.core_once(params, e2, s2, ctx)[:, S:]
            d_hat = edm.denoise_combine(z, f.astype(jnp.float32), sig,
                                        self.db.sigma_data)
            z = edm.euler_step(z, d_hat, s_from, s_to) if s_to > 0 else d_hat
        ctx_r = self._embed_ctx(tokens)
        return self.readout(params, z.astype(e.dtype), ctx_r)
