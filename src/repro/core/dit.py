"""Continuous-target diffusion model (DiT-style) under DiffusionBlocks —
paper §5.2. The model is already a denoiser, so the conversion is the native
fit: block b trains and serves only its σ-range. B=1 recovers the standard
DiT/EDM baseline. Inference applies ONE block per Euler step ⇒ B× fewer layer
evaluations per step (paper App. H).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig, ModelConfig
from repro.core import edm
from repro.core import partition as P
from repro.models import common as C
from repro.models.common import LayerCtx
from repro.nn import adaln
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn.init import ParamSpec, init_params, stack_specs


class DiTDiffusionBlocks:
    def __init__(self, cfg: ModelConfig, db: DBConfig, data_dim: int,
                 n_tokens: int,
                 distribution: Optional[Sequence[int]] = None):
        self.cfg, self.db = cfg, db
        self.data_dim, self.n_tokens = data_dim, n_tokens
        self.ranges = P.unit_ranges(cfg.n_layers, db.num_blocks, distribution)
        self.edges = P.sigma_edges(db)
        d = cfg.d_model
        self.spec = {
            "in_proj": L.linear_spec(data_dim, d, (None, "embed")),
            "pos": ParamSpec((n_tokens, d), (None, "embed"), "embed", 0.02),
            "layers": stack_specs(C.tlayer_spec(cfg, db=True), cfg.n_layers),
            "final_norm": L.norm_spec(d, cfg.norm),
            "out_proj": L.linear_spec(d, data_dim, ("embed", None),
                                      init="zeros"),
            "cond": adaln.sigma_embed_spec(db.cond_dim, d),
        }

    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.spec, dtype)

    def denoise(self, params, z, sigma, start, size):
        """F_θ for units [start, start+size): z (B, T, data_dim),
        sigma (B,1,1). Returns F (B, T, data_dim) (EDM F-space)."""
        _, _, c_in, _ = edm.preconditioning(sigma, self.db.sigma_data)
        h = L.linear(params["in_proj"], (c_in * z).astype(jnp.float32))
        h = h + params["pos"][None]
        cond = adaln.sigma_embedding(params["cond"],
                                     jnp.log(sigma.reshape(-1)) / 4.0,
                                     self.db.cond_dim)
        ctx = LayerCtx(cfg=self.cfg, mode="train",
                       positions=jnp.arange(self.n_tokens),
                       mask_mod=A.bidirectional_mask, cond=cond)
        lp = jax.tree_util.tree_map(lambda p: p[start:start + size],
                                    params["layers"])

        def step(hh, p):
            hh, _, _ = C.tlayer_apply(p, hh, ctx)
            return hh, None

        h, _ = jax.lax.scan(step, h, lp)
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm)
        return L.linear(params["out_proj"], h)

    def d_hat(self, params, z, sigma, block: int):
        start, size = self.ranges[block]
        f = self.denoise(params, z, sigma, start, size)
        return edm.denoise_combine(z, f, sigma, self.db.sigma_data)

    def block_loss(self, params, b, y, rng, unit_range=None):
        """Eq. (6) with L2 inner loss in F-space (unit weight — the EDM
        identity w(σ)c_out² = 1)."""
        start, size = unit_range or self.ranges[b]
        Bsz = y.shape[0]
        r_s, r_e = jax.random.split(rng)
        q_lo, q_hi = P.block_qrange(self.db, b)
        sigma = edm.sample_sigma_in_qrange(r_s, (Bsz, 1, 1), self.db,
                                           q_lo, q_hi)
        z, _ = edm.add_noise(r_e, y, sigma)
        f = self.denoise(params, z, sigma, start, size)
        loss = edm.edm_l2_loss(f, z, y, sigma, self.db.sigma_data)
        return loss, {"l2": loss}

    def e2e_loss(self, params, y, rng):
        """Standard EDM training of the FULL stack across the whole σ range
        (the paper's DiT baseline, B=1 semantics)."""
        return self.block_loss(params, 0, y, rng,
                               unit_range=(0, self.cfg.n_layers))

    def sample(self, params, rng, batch: int, num_steps: int = 18,
               blockwise: bool = True):
        """Euler sampler. blockwise=True: one block per step (DB);
        False: full stack per step (baseline). Returns samples + layer-eval
        count (the inference-cost metric of Table 2/App. H)."""
        sched = P.sampling_schedule(self.db, num_steps)
        z = self.db.sigma_max * jax.random.normal(
            rng, (batch, self.n_tokens, self.data_dim))
        layer_evals = 0
        for i in range(len(sched) - 1):
            s_from, s_to = float(sched[i]), float(sched[i + 1])
            sig = jnp.full((batch, 1, 1), s_from)
            if blockwise:
                b = P.block_of_sigma(self.db, s_from)
                start, size = self.ranges[b]
            else:
                start, size = 0, self.cfg.n_layers
            layer_evals += size
            f = self.denoise(params, z, sig, start, size)
            d_hat = edm.denoise_combine(z, f, sig, self.db.sigma_data)
            z = edm.euler_step(z, d_hat, s_from, s_to) if s_to > 0 else d_hat
        return z, layer_evals
