"""Process-wide runtime knobs (env-driven; set by launch/dryrun.py).

XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
count, so the roofline dry-run sets REPRO_SCAN_UNROLL=1 to unroll layer /
attention-tile / CE-chunk scans — the compiled module then carries the true
FLOP/byte counts. Normal execution keeps scans rolled (small HLO, fast
compile). REPRO_ATTN_CHUNK enlarges flash tiles in the dry-run to bound the
unrolled tile count.
"""
from __future__ import annotations

import os


def scan_unroll() -> bool:
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"


def attn_chunk() -> int:
    return int(os.environ.get("REPRO_ATTN_CHUNK", "1024"))
