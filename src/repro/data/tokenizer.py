"""Byte-level tokenizer (text8-style lowercase alphabet option)."""
from __future__ import annotations

import numpy as np

TEXT8_ALPHABET = "abcdefghijklmnopqrstuvwxyz "


class ByteTokenizer:
    vocab_size = 256

    def encode(self, s: str) -> np.ndarray:
        return np.frombuffer(s.encode("utf-8", errors="replace"),
                             dtype=np.uint8).astype(np.int64)

    def decode(self, ids) -> str:
        return bytes(int(i) % 256 for i in ids).decode("utf-8",
                                                       errors="replace")


class Text8Tokenizer:
    """27-symbol text8 alphabet + [MASK] (id 27). vocab_size=28."""
    def __init__(self):
        self.alphabet = TEXT8_ALPHABET
        self.stoi = {c: i for i, c in enumerate(self.alphabet)}
        self.mask_id = len(self.alphabet)
        self.vocab_size = len(self.alphabet) + 1

    def encode(self, s: str) -> np.ndarray:
        return np.array([self.stoi.get(c, self.stoi[" "]) for c in s.lower()],
                        np.int64)

    def decode(self, ids) -> str:
        out = []
        for i in ids:
            i = int(i)
            out.append(self.alphabet[i] if i < len(self.alphabet) else "_")
        return "".join(out)
