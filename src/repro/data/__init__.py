from repro.data.synthetic import (GaussianMixtureImages, MarkovLM,
                                  MarkovStream, MixtureImagesContinuous,
                                  arithmetic_stream)
from repro.data.pipeline import HostDataLoader, repeat_batches
from repro.data.tokenizer import ByteTokenizer, Text8Tokenizer
