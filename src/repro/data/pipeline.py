"""Host data pipeline: deterministic shard-aware batching + device placement.

On a real multi-host pod each process feeds its addressable shard of the
``data`` axis; ``HostDataLoader`` slices the global batch by (host_id,
num_hosts) and places arrays with the given sharding. Single-process here,
but the sharding path is the one the dry-run exercises.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class HostDataLoader:
    """Prefetching loader. When the wrapped ``gen`` exposes a ``cursor()``
    (e.g. ``repro.data.MarkovStream``), ``cursor()`` here returns that
    cursor advanced to the CONSUMER position — ``delivered`` counts batches
    handed to the trainer, not batches the prefetch thread has pulled ahead,
    so a resume from the cursor replays exactly the batches the trainer has
    not yet seen."""

    def __init__(self, gen: Iterator, host_id: int = 0, num_hosts: int = 1,
                 sharding=None, prefetch: int = 2):
        self.gen = gen
        self.host_id, self.num_hosts = host_id, num_hosts
        self.sharding = sharding
        self.delivered = 0
        self._cursor0 = gen.cursor() if hasattr(gen, "cursor") else None
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _slice(self, batch):
        def f(x):
            n = x.shape[0]
            per = n // self.num_hosts
            return x[self.host_id * per:(self.host_id + 1) * per]
        return jax.tree_util.tree_map(f, batch)

    def _place(self, batch):
        if self.sharding is None:
            return jax.tree_util.tree_map(jax.numpy.asarray, batch)
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self.sharding), batch)

    def _worker(self):
        try:
            for item in self.gen:
                if self._stop.is_set():
                    return
                self._q.put(self._place(self._slice(item)))
        except Exception as e:  # surface in consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        self.delivered += 1
        return item

    def cursor(self) -> dict:
        """Source cursor at the CONSUMER position (None when the wrapped
        generator has no ``cursor()``)."""
        if self._cursor0 is None:
            return None
        cur = dict(self._cursor0)
        cur["batches"] = cur.get("batches", 0) + self.delivered
        return cur

    def close(self):
        self._stop.set()


def repeat_batches(fn: Callable[[int], np.ndarray]) -> Iterator:
    i = 0
    while True:
        yield fn(i)
        i += 1
