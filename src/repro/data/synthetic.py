"""Synthetic data generators (offline container — no external datasets).

Language: a Zipf-weighted Markov-chain corpus with learnable n-gram structure
(so CE demonstrably decreases and generation quality is measurable against
the generating chain), plus a deterministic arithmetic stream for exactness
tests. Vision: Gaussian-mixture class images (class-dependent means) so
classification accuracy and denoising quality are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class MarkovLM:
    """Order-1 Markov chain with Zipf-ish sparse transitions."""
    vocab_size: int = 256
    branching: int = 4
    seed: int = 0

    def __post_init__(self):
        r = np.random.RandomState(self.seed)
        V, K = self.vocab_size, self.branching
        self.next_tokens = r.randint(0, V, (V, K))
        p = 1.0 / (np.arange(1, K + 1) ** 1.2)
        self.next_probs = p / p.sum()

    def sample(self, rng: np.random.RandomState, batch: int,
               seq_len: int) -> np.ndarray:
        V, K = self.vocab_size, self.branching
        x = np.empty((batch, seq_len), np.int64)
        x[:, 0] = rng.randint(0, V, batch)
        for t in range(1, seq_len):
            choice = rng.choice(K, size=batch, p=self.next_probs)
            x[:, t] = self.next_tokens[x[:, t - 1], choice]
        return x

    def iterator(self, batch: int, seq_len: int,
                 seed: int = 1) -> Iterator[np.ndarray]:
        rng = np.random.RandomState(seed)
        while True:
            yield self.sample(rng, batch, seq_len)

    def stream(self, batch: int, seq_len: int, seed: int = 1,
               start_batch: int = 0) -> "MarkovStream":
        """Cursor-able version of ``iterator`` (fault-tolerant training)."""
        return MarkovStream(self, batch, seq_len, seed=seed,
                            start_batch=start_batch)

    def log_likelihood(self, x: np.ndarray) -> float:
        """Average log2-likelihood per transition under the true chain
        (entropy floor for BPC-style metrics)."""
        V, K = self.vocab_size, self.branching
        probs = np.zeros((V, V))
        for k in range(K):
            np.add.at(probs, (np.arange(V), self.next_tokens[:, k]),
                      self.next_probs[k])
        p = probs[x[:, :-1], x[:, 1:]]
        return float(np.mean(np.log2(np.maximum(p, 1e-12))))

    def transition_accuracy(self, x: np.ndarray) -> float:
        """Fraction of transitions that are legal under the chain — the
        generation-quality proxy (MAUVE stand-in)."""
        legal = (self.next_tokens[x[:, :-1]] == x[:, 1:, None]).any(-1)
        return float(legal.mean())


class MarkovStream:
    """Deterministic, CURSOR-able batch stream over a ``MarkovLM``.

    Batch i depends only on (lm, seed, i), so a resumed run that fast-forwards
    to the delivered-batch count consumes the SAME batches the uninterrupted
    run would have — the property the training resume-parity gate needs. The
    cursor is a small JSON dict (no RandomState pickling), so it lives in the
    checkpoint manifest.
    """

    def __init__(self, lm: "MarkovLM", batch: int, seq_len: int,
                 seed: int = 1, start_batch: int = 0):
        self.lm, self.batch, self.seq_len, self.seed = lm, batch, seq_len, seed
        self.rng = np.random.RandomState(seed)
        self.batches = 0
        for _ in range(start_batch):
            next(self)                   # replay-to-cursor fast-forward

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        x = self.lm.sample(self.rng, self.batch, self.seq_len)
        self.batches += 1
        return x

    def cursor(self) -> dict:
        return {"kind": "markov", "vocab_size": self.lm.vocab_size,
                "branching": self.lm.branching, "lm_seed": self.lm.seed,
                "batch": self.batch, "seq_len": self.seq_len,
                "seed": self.seed, "batches": self.batches}

    @classmethod
    def from_cursor(cls, cur: dict) -> "MarkovStream":
        lm = MarkovLM(vocab_size=cur["vocab_size"],
                      branching=cur["branching"], seed=cur["lm_seed"])
        return cls(lm, cur["batch"], cur["seq_len"], seed=cur["seed"],
                   start_batch=cur["batches"])


def arithmetic_stream(batch: int, seq_len: int, vocab: int,
                      seed: int) -> np.ndarray:
    """Deterministic x_{t+1} = (3 x_t + 1) mod V — exactness checks."""
    r = np.random.RandomState(seed)
    x = np.empty((batch, seq_len), np.int64)
    x[:, 0] = r.randint(0, vocab, batch)
    for t in range(1, seq_len):
        x[:, t] = (3 * x[:, t - 1] + 1) % vocab
    return x


@dataclasses.dataclass
class GaussianMixtureImages:
    """Class-conditional images: class c has a fixed random mean image +
    noise. Linearly separable at high SNR; difficulty via noise_scale."""
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    noise_scale: float = 0.5
    seed: int = 0

    def __post_init__(self):
        r = np.random.RandomState(self.seed)
        self.means = r.randn(self.num_classes, self.image_size,
                             self.image_size, self.channels).astype(np.float32)

    def sample(self, rng: np.random.RandomState,
               batch: int) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.randint(0, self.num_classes, batch)
        x = self.means[y] + self.noise_scale * rng.randn(
            batch, self.image_size, self.image_size,
            self.channels).astype(np.float32)
        return x.astype(np.float32), y

    def iterator(self, batch: int, seed: int = 1):
        rng = np.random.RandomState(seed)
        while True:
            yield self.sample(rng, batch)


@dataclasses.dataclass
class MixtureImagesContinuous:
    """Continuous targets for the DiT image-generation benchmark: samples
    from a K-mode Gaussian mixture over flattened 'images' (tokens of d
    dims). The true score is analytic, so sample quality is measurable via
    moment matching."""
    n_tokens: int = 16
    dim: int = 32
    n_modes: int = 4
    mode_scale: float = 2.0
    noise: float = 0.25
    seed: int = 0

    def __post_init__(self):
        r = np.random.RandomState(self.seed)
        self.modes = (self.mode_scale *
                      r.randn(self.n_modes, self.n_tokens, self.dim)
                      ).astype(np.float32)

    def sample(self, rng: np.random.RandomState, batch: int):
        k = rng.randint(0, self.n_modes, batch)
        x = self.modes[k] + self.noise * rng.randn(
            batch, self.n_tokens, self.dim).astype(np.float32)
        return x.astype(np.float32), k

    def iterator(self, batch: int, seed: int = 1):
        rng = np.random.RandomState(seed)
        while True:
            yield self.sample(rng, batch)

    def mode_assignment(self, x: np.ndarray) -> np.ndarray:
        d = ((x[:, None] - self.modes[None]) ** 2).sum((-1, -2))
        return d.argmin(1)

    def fidelity(self, x: np.ndarray) -> Tuple[float, float]:
        """(mean distance to nearest mode, mode coverage entropy ratio) —
        the FID stand-in."""
        d = np.sqrt(((x[:, None] - self.modes[None]) ** 2).sum((-1, -2)))
        nearest = d.min(1)
        assign = d.argmin(1)
        counts = np.bincount(assign, minlength=self.n_modes) / len(assign)
        ent = -(counts * np.log(np.maximum(counts, 1e-12))).sum()
        return float(nearest.mean()), float(ent / np.log(self.n_modes))
