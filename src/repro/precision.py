"""Mixed-precision policy (``repro.precision``).

One frozen, hashable ``Policy`` object describes the three dtype roles of the
training hot path:

  param_dtype    master copies the optimizer updates (always fp32 by default —
                 AdamW moments and weight decay stay full precision)
  compute_dtype  the streamed activations and the weight copies the matmuls
                 see (bf16 under the ``bf16`` policy — half the HBM traffic,
                 2× the MXU throughput on TPU)
  reduce_dtype   softmax / layernorm statistics / loss accumulation (fp32 in
                 every shipped policy; the kernels and ``chunked_ce`` already
                 promote internally, this field documents + enforces it)

Per-family overrides: recurrent scans (xLSTM sLSTM state, Mamba SSD) compound
rounding error multiplicatively over the sequence, so the ``ssm`` / ``hybrid``
families keep fp32 compute even under the ``bf16`` policy unless the override
set is emptied explicitly.

The policy threads through ``LayerCtx.precision`` (``make_ctx``), the
train-step builders (params are cast once per step — masters stay fp32, the
loss sees compute-dtype copies, and the cast's transpose accumulates the
gradients back to fp32), and the block-parallel engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import HYBRID, SSM


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    reduce_dtype: Any = jnp.float32
    # families whose recurrences stay in fp32 even under low-precision compute
    fp32_families: Tuple[str, ...] = (SSM, HYBRID)
    # serving KV-cache storage; None = follow compute_dtype. Deliberately NOT
    # family-overridden: attention K/V tolerate bf16 storage even for the
    # hybrid family (scores/logsumexp are always fp32 — the flash-decode
    # kernel accumulates in fp32 scratch); only the recurrent STATES follow
    # compute_for (see state_for).
    kv_dtype: Any = None

    def compute_for(self, family: Optional[str] = None):
        """Effective compute dtype for an architecture family."""
        if family is not None and family in self.fp32_families:
            return jnp.float32
        return self.compute_dtype

    @property
    def kv(self):
        """KV-cache storage dtype (bf16 under the serving default)."""
        return self.compute_dtype if self.kv_dtype is None else self.kv_dtype

    @property
    def kv_quantized(self) -> bool:
        """True when the paged pool stores integer pages + per-page scales."""
        return jnp.issubdtype(jnp.dtype(self.kv), jnp.integer)

    @property
    def kv_dense(self):
        """Storage dtype for DENSE (non-paged) per-slot KV blocks — the
        fixed cross-attention conditioning memories. These have no per-page
        scale machinery, so they never quantize: under an int8 paged policy
        they fall back to the compute dtype."""
        return self.compute_dtype if self.kv_quantized else self.kv

    def state_for(self, family: Optional[str] = None):
        """Recurrent-state storage dtype (mamba/xLSTM): compounded rounding
        over the sequence keeps these fp32 under the bf16 policy."""
        return self.compute_for(family)

    @property
    def is_mixed(self) -> bool:
        return self.compute_dtype != self.param_dtype


FP32 = Policy("fp32")
BF16 = Policy("bf16", compute_dtype=jnp.bfloat16)
# int8 paged-KV variants: compute stays bf16/fp32, only the PAGE POOL stores
# int8 (+ one fp32 absmax scale per page per tensor — repro.nn.cache). Each
# gets a distinct name because engine memoization keys on get_policy(x).name.
BF16_KVINT8 = Policy("bf16_kvint8", compute_dtype=jnp.bfloat16,
                     kv_dtype=jnp.int8)
FP32_KVINT8 = Policy("fp32_kvint8", kv_dtype=jnp.int8)

_POLICIES = {"fp32": FP32, "float32": FP32, "bf16": BF16, "bfloat16": BF16,
             "mixed": BF16, None: FP32, "none": FP32,
             "bf16_kvint8": BF16_KVINT8, "fp32_kvint8": FP32_KVINT8,
             "int8": BF16_KVINT8, "kvint8": BF16_KVINT8}

PolicyLike = Union[None, str, Policy]


def get_policy(policy: PolicyLike) -> Policy:
    if isinstance(policy, Policy):
        return policy
    try:
        return _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown precision policy {policy!r}; one of "
            f"{sorted(k for k in _POLICIES if isinstance(k, str))}") from None


def with_kv_dtype(policy: PolicyLike, kv_dtype) -> Policy:
    """Resolve a (precision, --kv-dtype) flag pair to a registered policy:
    ``with_kv_dtype('bf16', 'int8') -> BF16_KVINT8``. ``kv_dtype`` of
    ``None``/``'auto'`` keeps the base policy; a float kv dtype matching the
    policy's existing storage dtype is likewise a no-op. Anything else must
    name a registered variant (the engine memoizes on ``Policy.name``, so
    ad-hoc unnamed combinations are refused rather than silently aliased)."""
    pol = get_policy(policy)
    if kv_dtype in (None, "", "auto"):
        return pol
    from repro.nn.cache import resolve_kv_dtype
    want = resolve_kv_dtype(kv_dtype)
    if jnp.dtype(pol.kv) == want:
        return pol
    for cand in _POLICIES.values():
        if (cand.compute_dtype == pol.compute_dtype
                and cand.param_dtype == pol.param_dtype
                and jnp.dtype(cand.kv) == want):
            return cand
    raise ValueError(
        f"no registered precision policy stores {want} KV pages over "
        f"{pol.name!r} compute; known policies: "
        f"{sorted(k for k in _POLICIES if isinstance(k, str))}")


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def cast_floating(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype`` (ints/bools pass)."""
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if _is_float(x) else x, tree)


def cast_params_for_compute(policy: PolicyLike, params,
                            family: Optional[str] = None):
    """Compute-dtype weight copies for one loss evaluation. A no-op tree map
    under fp32; under bf16 the cast's transpose is what accumulates gradients
    back into fp32 (grads come out in ``param_dtype`` automatically)."""
    pol = get_policy(policy)
    cd = pol.compute_for(family)
    if cd == pol.param_dtype:
        return params
    return cast_floating(params, cd)


def cast_stream(policy: PolicyLike, x, family: Optional[str] = None):
    """Cast an activation stream to the policy's compute dtype."""
    pol = get_policy(policy)
    return x.astype(pol.compute_for(family))
