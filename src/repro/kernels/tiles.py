"""Shared tile helpers for the Pallas kernels: sequence-axis zero-padding to
a block multiple and the recurring BlockSpec shapes ((B, rows, d) row tiles,
(B, d) per-example vectors, (B, 1) scalars, (B, ns, d) per-tile partials).
One definition so padding semantics cannot drift between kernels."""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl


def pad_rows(x, block_rows: int):
    """Zero-pad axis 1 of (B, S, ...) up to a multiple of ``block_rows``."""
    pad = (-x.shape[1]) % block_rows
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


def pad_seq(x, target: int):
    """Zero-pad axis 2 of (B, H, S, hd) up to exactly ``target``."""
    S = x.shape[2]
    return x if S == target else jnp.pad(
        x, ((0, 0), (0, 0), (0, target - S), (0, 0)))


def row_spec(block_rows: int, d: int):
    return pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0))


def vec_spec(d: int):
    return pl.BlockSpec((1, d), lambda b, i: (b, 0))


def scalar_spec():
    return pl.BlockSpec((1, 1), lambda b, i: (b, 0))


def tile_spec():
    return pl.BlockSpec((1, 1), lambda b, i: (b, i))


def partial_spec(d: int):
    return pl.BlockSpec((1, 1, d), lambda b, i: (b, i, 0))
