"""Fused AdaLN kernels — the elementwise hot path DiffusionBlocks adds to
every layer (noise conditioning, paper §3.1 Step 3).

Unfused, each layer costs 4 extra HBM round-trips of the (tokens, d) stream:
LN read/write, modulate read/write, gate read/write, residual read/write.
The two kernels here keep a (block_rows × d) tile resident in VMEM:

  fused_ln_modulate:  out = LN(x) * (1 + scale) + shift        (one pass)
  fused_gate_residual: out = res + branch * (1 + gate)          (one pass)

and a third fuses the EDM denoiser combine with the Euler step (Eq. 5):

  fused_euler: z' = (r + (1-r)·c_skip) · z + (1-r)·c_out · f

scale/shift/gate are per-example (B, d) vectors (σ-conditioning), broadcast
over the row tile.

All three are differentiable via ``jax.custom_vjp`` backed by Pallas backward
kernels: the backward pass reads each tile once, recomputes the cheap
row statistics in VMEM, and emits per-tile partial sums for the (B, d)
conditioning gradients (summed by the caller — O(B·n_tiles·d) bytes, no
atomics needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import (pad_rows as _pad_rows, partial_spec
                                 as _partial_spec, row_spec as _row_specs,
                                 scalar_spec, vec_spec as _vec_spec)

BLOCK_ROWS = 256


# ---------------------------------------------------------------------------
# fused_ln_modulate: out = LN(x) * (1 + scale) + shift
# ---------------------------------------------------------------------------

def _ln_mod_kernel(x_ref, scale_ref, shift_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)                       # (rows, d)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale_ref[0].astype(jnp.float32)) \
        + shift_ref[0].astype(jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)


def _ln_mod_bwd_kernel(x_ref, scale_ref, g_ref, dx_ref, dsc_ref, dsh_ref, *,
                       eps: float):
    """LN backward with the normalization stats recomputed in VMEM:
    dx = rstd · (dy − mean_d(dy) − x̂ · mean_d(dy·x̂)), dy = g·(1+scale);
    per-tile partials dscale = Σ_rows g·x̂, dshift = Σ_rows g."""
    x = x_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    scale = scale_ref[0].astype(jnp.float32)               # (1, d) broadcast
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * rstd
    dy = g * (1.0 + scale)
    dx = rstd * (dy - jnp.mean(dy, axis=-1, keepdims=True)
                 - xhat * jnp.mean(dy * xhat, axis=-1, keepdims=True))
    dx_ref[0] = dx.astype(dx_ref.dtype)
    dsc_ref[0, 0] = jnp.sum(g * xhat, axis=0)
    dsh_ref[0, 0] = jnp.sum(g, axis=0)


def _ln_mod_fwd_call(x, scale, shift, eps, block_rows, interpret):
    B, S, d = x.shape
    block_rows = min(block_rows, S)
    xp = _pad_rows(x, block_rows)
    ns = xp.shape[1] // block_rows
    out = pl.pallas_call(
        functools.partial(_ln_mod_kernel, eps=eps),
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), _vec_spec(d), _vec_spec(d)],
        out_specs=_row_specs(block_rows, d),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, scale, shift)
    return out[:, :S]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _ln_mod(x, scale, shift, eps, block_rows, interpret):
    return _ln_mod_fwd_call(x, scale, shift, eps, block_rows, interpret)


def _ln_mod_vjp_fwd(x, scale, shift, eps, block_rows, interpret):
    return (_ln_mod_fwd_call(x, scale, shift, eps, block_rows, interpret),
            (x, scale))


def _ln_mod_vjp_bwd(eps, block_rows, interpret, res, g):
    x, scale = res
    B, S, d = x.shape
    block_rows = min(block_rows, S)
    xp = _pad_rows(x, block_rows)
    gp = _pad_rows(g, block_rows)          # zero rows ⇒ zero partials
    ns = xp.shape[1] // block_rows
    dx, dsc, dsh = pl.pallas_call(
        functools.partial(_ln_mod_bwd_kernel, eps=eps),
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), _vec_spec(d),
                  _row_specs(block_rows, d)],
        out_specs=[_row_specs(block_rows, d), _partial_spec(d),
                   _partial_spec(d)],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, x.dtype),
                   jax.ShapeDtypeStruct((B, ns, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, ns, d), jnp.float32)],
        interpret=interpret,
    )(xp, scale, gp)
    dscale = dsc.sum(axis=1).astype(scale.dtype)
    dshift = dsh.sum(axis=1).astype(scale.dtype)
    return dx[:, :S], dscale, dshift


_ln_mod.defvjp(_ln_mod_vjp_fwd, _ln_mod_vjp_bwd)


def fused_ln_modulate(x: jax.Array, scale: jax.Array, shift: jax.Array,
                      eps: float = 1e-6, block_rows: int = BLOCK_ROWS,
                      interpret: bool = False) -> jax.Array:
    """x: (B, S, d); scale/shift: (B, d). Non-parametric LN + AdaLN affine."""
    return _ln_mod(x, scale, shift, eps, block_rows, interpret)


# ---------------------------------------------------------------------------
# fused_gate_residual: out = res + branch * (1 + gate)
# ---------------------------------------------------------------------------

def _gate_res_kernel(res_ref, br_ref, gate_ref, o_ref):
    o_ref[0] = (res_ref[0].astype(jnp.float32)
                + br_ref[0].astype(jnp.float32)
                * (1.0 + gate_ref[0].astype(jnp.float32))).astype(o_ref.dtype)


def _gate_res_bwd_kernel(br_ref, gate_ref, g_ref, dbr_ref, dg_ref):
    br = br_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    dbr_ref[0] = (g * (1.0 + gate_ref[0].astype(jnp.float32))
                  ).astype(dbr_ref.dtype)
    dg_ref[0, 0] = jnp.sum(g * br, axis=0)


def _gate_res_fwd_call(res, branch, gate, block_rows, interpret):
    B, S, d = res.shape
    block_rows = min(block_rows, S)
    rp = _pad_rows(res, block_rows)
    bp = _pad_rows(branch, block_rows)
    ns = rp.shape[1] // block_rows
    out = pl.pallas_call(
        _gate_res_kernel,
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), _row_specs(block_rows, d),
                  _vec_spec(d)],
        out_specs=_row_specs(block_rows, d),
        out_shape=jax.ShapeDtypeStruct(rp.shape, res.dtype),
        interpret=interpret,
    )(rp, bp, gate)
    return out[:, :S]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _gate_res(res, branch, gate, block_rows, interpret):
    return _gate_res_fwd_call(res, branch, gate, block_rows, interpret)


def _gate_res_vjp_fwd(res, branch, gate, block_rows, interpret):
    return (_gate_res_fwd_call(res, branch, gate, block_rows, interpret),
            (branch, gate))


def _gate_res_vjp_bwd(block_rows, interpret, res, g):
    branch, gate = res
    B, S, d = branch.shape
    block_rows = min(block_rows, S)
    bp = _pad_rows(branch, block_rows)
    gp = _pad_rows(g, block_rows)
    ns = bp.shape[1] // block_rows
    dbr, dg = pl.pallas_call(
        _gate_res_bwd_kernel,
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), _vec_spec(d),
                  _row_specs(block_rows, d)],
        out_specs=[_row_specs(block_rows, d), _partial_spec(d)],
        out_shape=[jax.ShapeDtypeStruct(bp.shape, branch.dtype),
                   jax.ShapeDtypeStruct((B, ns, d), jnp.float32)],
        interpret=interpret,
    )(bp, gate, gp)
    dgate = dg.sum(axis=1).astype(gate.dtype)
    return g, dbr[:, :S], dgate        # d res = identity pass-through


_gate_res.defvjp(_gate_res_vjp_fwd, _gate_res_vjp_bwd)


def fused_gate_residual(res: jax.Array, branch: jax.Array, gate: jax.Array,
                        block_rows: int = BLOCK_ROWS,
                        interpret: bool = False) -> jax.Array:
    """res/branch: (B, S, d); gate: (B, d)."""
    return _gate_res(res, branch, gate, block_rows, interpret)


# ---------------------------------------------------------------------------
# fused_euler: z' = (r + (1-r) c_skip) z + (1-r) c_out f
# ---------------------------------------------------------------------------

def _euler_kernel(z_ref, f_ref, a_ref, b_ref, o_ref):
    a = a_ref[0, 0]                                       # scalars per example
    b = b_ref[0, 0]
    o_ref[0] = (a * z_ref[0].astype(jnp.float32)
                + b * f_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def _euler_bwd_kernel(g_ref, a_ref, b_ref, dz_ref, df_ref):
    g = g_ref[0].astype(jnp.float32)
    dz_ref[0] = (a_ref[0, 0] * g).astype(dz_ref.dtype)
    df_ref[0] = (b_ref[0, 0] * g).astype(df_ref.dtype)


def _euler_coeffs(sigma, sigma_to, sigma_data: float):
    """EDM preconditioning folded into the Euler combine — pinned against
    core/edm.preconditioning by tests/test_kernel_grads.py."""
    B = sigma.shape[0]
    sf = sigma.astype(jnp.float32)
    s2 = sf ** 2
    d2 = sigma_data ** 2
    c_skip = d2 / (s2 + d2)
    c_out = sf * sigma_data * jax.lax.rsqrt(s2 + d2)
    r = sigma_to.astype(jnp.float32) / sf
    a = (r + (1 - r) * c_skip).reshape(B, 1)
    b = ((1 - r) * c_out).reshape(B, 1)
    return a, b


def _euler_fwd_call(z, f, a, b, block_rows, interpret):
    B, S, d = z.shape
    block_rows = min(block_rows, S)
    zp = _pad_rows(z, block_rows)
    fp = _pad_rows(f, block_rows)
    ns = zp.shape[1] // block_rows
    out = pl.pallas_call(
        _euler_kernel,
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), _row_specs(block_rows, d),
                  scalar_spec(), scalar_spec()],
        out_specs=_row_specs(block_rows, d),
        out_shape=jax.ShapeDtypeStruct(zp.shape, z.dtype),
        interpret=interpret,
    )(zp, fp, a, b)
    return out[:, :S]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _euler(z, f, sigma, sigma_to, sigma_data, block_rows, interpret):
    a, b = _euler_coeffs(sigma, sigma_to, sigma_data)
    return _euler_fwd_call(z, f, a, b, block_rows, interpret)


def _euler_vjp_fwd(z, f, sigma, sigma_to, sigma_data, block_rows, interpret):
    a, b = _euler_coeffs(sigma, sigma_to, sigma_data)
    out = _euler_fwd_call(z, f, a, b, block_rows, interpret)
    return out, (a, b, sigma, sigma_to)


def _euler_vjp_bwd(sigma_data, block_rows, interpret, res, g):
    a, b, sigma, sigma_to = res
    B, S, d = g.shape
    block_rows = min(block_rows, S)
    gp = _pad_rows(g, block_rows)
    ns = gp.shape[1] // block_rows
    dz, df = pl.pallas_call(
        _euler_bwd_kernel,
        grid=(B, ns),
        in_specs=[_row_specs(block_rows, d), scalar_spec(), scalar_spec()],
        out_specs=[_row_specs(block_rows, d), _row_specs(block_rows, d)],
        out_shape=[jax.ShapeDtypeStruct(gp.shape, g.dtype),
                   jax.ShapeDtypeStruct(gp.shape, g.dtype)],
        interpret=interpret,
    )(gp, a, b)
    # σ is sampled noise-schedule data, never a learnable input — zero cotangent
    return dz[:, :S], df[:, :S], jnp.zeros_like(sigma), jnp.zeros_like(sigma_to)


_euler.defvjp(_euler_vjp_fwd, _euler_vjp_bwd)


def fused_euler(z: jax.Array, f: jax.Array, sigma: jax.Array,
                sigma_to: jax.Array, sigma_data: float,
                block_rows: int = BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    """Fused denoise-combine + Euler step (paper Eq. 5 with EDM
    parameterization):  D = c_skip z + c_out F,  z' = r z + (1-r) D
    ⇒ z' = (r + (1-r) c_skip) z + (1-r) c_out F.

    z/f: (B, S, d); sigma/sigma_to: (B,) per-example noise levels."""
    return _euler(z, f, sigma, sigma_to, sigma_data, block_rows, interpret)
