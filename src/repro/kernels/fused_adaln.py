"""Fused AdaLN kernels — the elementwise hot path DiffusionBlocks adds to
every layer (noise conditioning, paper §3.1 Step 3).

Unfused, each layer costs 4 extra HBM round-trips of the (tokens, d) stream:
LN read/write, modulate read/write, gate read/write, residual read/write.
The two kernels here keep a (block_rows × d) tile resident in VMEM:

  fused_ln_modulate:  out = LN(x) * (1 + scale) + shift        (one pass)
  fused_gate_residual: out = res + branch * (1 + gate)          (one pass)

and a third fuses the EDM denoiser combine with the Euler step (Eq. 5):

  fused_euler: z' = (r + (1-r)·c_skip) · z + (1-r)·c_out · f

scale/shift/gate are per-example (B, d) vectors (σ-conditioning), broadcast
over the row tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_ROWS = 256


def _ln_mod_kernel(x_ref, scale_ref, shift_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)                       # (rows, d)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale_ref[0].astype(jnp.float32)) \
        + shift_ref[0].astype(jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)


def fused_ln_modulate(x: jax.Array, scale: jax.Array, shift: jax.Array,
                      eps: float = 1e-6, block_rows: int = BLOCK_ROWS,
                      interpret: bool = False) -> jax.Array:
    """x: (B, S, d); scale/shift: (B, d). Non-parametric LN + AdaLN affine."""
    B, S, d = x.shape
    block_rows = min(block_rows, S)
    pad = (-S) % block_rows
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    ns = x.shape[1] // block_rows
    out = pl.pallas_call(
        functools.partial(_ln_mod_kernel, eps=eps),
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, d), lambda b, i: (b, 0)),
            pl.BlockSpec((1, d), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale, shift)
    return out[:, :S]


def _gate_res_kernel(res_ref, br_ref, gate_ref, o_ref):
    o_ref[0] = (res_ref[0].astype(jnp.float32)
                + br_ref[0].astype(jnp.float32)
                * (1.0 + gate_ref[0].astype(jnp.float32))).astype(o_ref.dtype)


def fused_gate_residual(res: jax.Array, branch: jax.Array, gate: jax.Array,
                        block_rows: int = BLOCK_ROWS,
                        interpret: bool = False) -> jax.Array:
    """res/branch: (B, S, d); gate: (B, d)."""
    B, S, d = res.shape
    block_rows = min(block_rows, S)
    pad = (-S) % block_rows
    if pad:
        res = jnp.pad(res, ((0, 0), (0, pad), (0, 0)))
        branch = jnp.pad(branch, ((0, 0), (0, pad), (0, 0)))
    ns = res.shape[1] // block_rows
    out = pl.pallas_call(
        _gate_res_kernel,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, d), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(res.shape, res.dtype),
        interpret=interpret,
    )(res, branch, gate)
    return out[:, :S]


def _euler_kernel(z_ref, f_ref, a_ref, b_ref, o_ref):
    a = a_ref[0, 0]                                       # scalars per example
    b = b_ref[0, 0]
    o_ref[0] = (a * z_ref[0].astype(jnp.float32)
                + b * f_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def fused_euler(z: jax.Array, f: jax.Array, sigma: jax.Array,
                sigma_to: jax.Array, sigma_data: float,
                block_rows: int = BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    """Fused denoise-combine + Euler step (paper Eq. 5 with EDM
    parameterization):  D = c_skip z + c_out F,  z' = r z + (1-r) D
    ⇒ z' = (r + (1-r) c_skip) z + (1-r) c_out F.

    z/f: (B, S, d); sigma/sigma_to: (B,) per-example noise levels."""
    B, S, d = z.shape
    s2 = sigma.astype(jnp.float32) ** 2
    d2 = sigma_data ** 2
    c_skip = d2 / (s2 + d2)
    c_out = sigma * sigma_data * jax.lax.rsqrt(s2 + d2)
    r = sigma_to / sigma
    a = (r + (1 - r) * c_skip).reshape(B, 1)
    b = ((1 - r) * c_out).reshape(B, 1)
    block_rows = min(block_rows, S)
    pad = (-S) % block_rows
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        f = jnp.pad(f, ((0, 0), (0, pad), (0, 0)))
    ns = z.shape[1] // block_rows
    out = pl.pallas_call(
        _euler_kernel,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, block_rows, d), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, block_rows, d), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, 1), lambda bb, i: (bb, 0)),
            pl.BlockSpec((1, 1), lambda bb, i: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, d), lambda bb, i: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(z, f, a, b)
    return out[:, :S]
