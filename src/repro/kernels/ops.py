"""Jitted kernel wrappers. On the CPU dev container the Pallas kernels run in
interpret mode (the kernel body executes as JAX ops — correctness path); on a
TPU backend they compile to Mosaic.

Every wrapper is differentiable: gradients flow through the hand-written
Pallas backward kernels (``jax.custom_vjp`` in the kernel modules), never
through autodiff of ``pallas_call``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import edm_loss as _edm
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import flash_prefill as _fp
from repro.kernels import fused_adaln as _ad


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "mask_kind",
                                    "mask_seq"))
def flash_attention_hmajor(q, k, v, causal: bool = True,
                           window: Optional[int] = None,
                           mask_kind: Optional[str] = None,
                           mask_seq: Optional[int] = None):
    """(B, H, S, hd) layout."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               mask_kind=mask_kind, mask_seq=mask_seq,
                               interpret=_interpret())


def _route_mask(mask_mod, causal: bool, window: Optional[int]):
    """Map an ``attention.MaskMod`` onto a kernel mask kind.

    Mask constructors the kernel supports carry a ``kernel_mask`` tag
    ``(kind, window, mask_seq)``; anything untagged (custom masks, decode
    ring-buffer validity masks, …) is REJECTED so we never silently compute
    wrong attention.
    """
    if mask_mod is None:
        return (("window", window, None) if window is not None
                else ("causal", None, None) if causal
                else ("full", None, None))
    tag = getattr(mask_mod, "kernel_mask", None)
    if tag is None:
        raise NotImplementedError(
            f"mask_mod {getattr(mask_mod, '__name__', mask_mod)!r} has no "
            "Pallas kernel equivalent; use impl='chunked' (or tag the mask "
            "constructor with .kernel_mask = (kind, window, mask_seq)). "
            "One-token decode does not route here at all — it has a "
            "dedicated split-KV kernel, ops.flash_decode")
    return tag


def _check_positions(pos, n: int, name: str):
    """The kernel derives mask positions from block indices, so ``pos`` must
    be ``arange(n)``. Wrong lengths always raise; wrong CONTENTS (packed
    segments, offsets, ring buffers) raise when the array is concrete —
    inside a jit trace contents are unobservable, so there the arange
    assumption is on the caller (every in-repo path builds arange)."""
    if pos is None:
        return
    if pos.shape[0] != n:
        raise NotImplementedError(
            f"pallas flash attention requires {name} == arange({n}); got "
            f"length {pos.shape[0]}")
    if not isinstance(pos, jax.core.Tracer):
        import numpy as np
        if not np.array_equal(np.asarray(pos), np.arange(n)):
            raise NotImplementedError(
                f"pallas flash attention requires {name} == arange({n}); "
                "got non-standard positions (packed/offset/ring positions "
                "have no kernel mask equivalent — use impl='chunked')")


def flash_attention(q, k, v, *, mask_mod=None, qpos=None, kpos=None,
                    causal: bool = True, window: Optional[int] = None):
    """(B, S, H, hd) layout adapter used by repro.nn.attention.

    ``mask_mod`` is routed onto the kernel's block-index masks (causal /
    sliding-window / DB concat / DB two-pass); unsupported masks raise
    ``NotImplementedError``, as do non-arange ``qpos``/``kpos`` where
    detectable (see ``_check_positions``).
    """
    kind, win, mseq = _route_mask(mask_mod, causal, window)
    Sq, Sk = q.shape[1], k.shape[1]
    _check_positions(qpos, Sq, "qpos")
    _check_positions(kpos, Sk, "kpos")
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_hmajor(qh, kh, vh, causal=causal, window=win,
                                 mask_kind=kind, mask_seq=mseq)
    return out.transpose(0, 2, 1, 3)


@jax.jit
def ln_modulate(x, scale, shift):
    return _ad.fused_ln_modulate(x, scale, shift, interpret=_interpret())


@jax.jit
def gate_residual(res, branch, gate):
    return _ad.fused_gate_residual(res, branch, gate, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sigma_data",))
def euler_update(z, f, sigma, sigma_to, sigma_data: float = 0.5):
    return _ad.fused_euler(z, f, sigma, sigma_to, sigma_data,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sigma_data",))
def edm_loss(f, z, y, sigma, sigma_data: float = 0.5):
    return _edm.edm_loss(f, z, y, sigma, sigma_data, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def flash_decode(q, k_pages, v_pages, page_table, lengths,
                 window: Optional[int] = None,
                 k_scale=None, v_scale=None):
    """Split-KV paged decode attention (flash-decoding). q: (B, KV, G, hd);
    k/v pages: (P, page_size, KV, hd). For int8 pools pass the per-page fp32
    ``k_scale``/``v_scale`` arrays — dequant is fused into the kernel.
    Returns (out, lse) fp32 partials over the committed tokens; fold in the
    current token's own k/v with ``flash_decode.combine_self``. This is the
    decode route — the prefill / train masks above never see 1-token
    queries."""
    return _fd.flash_decode(q, k_pages, v_pages, page_table, lengths,
                            window=window, k_scale=k_scale, v_scale=v_scale,
                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def flash_prefill(q, k_pages, v_pages, page_table, lengths,
                  window: Optional[int] = None,
                  k_scale=None, v_scale=None):
    """Chunked-prefill paged attention. q: (B, C, KV, G, hd) — one prompt
    CHUNK of grouped queries at absolute positions [lengths[b], lengths[b]+C)
    whose own k/v are already appended to the pool
    (``repro.nn.cache.append_paged_chunk``). For int8 pools pass the
    per-page fp32 ``k_scale``/``v_scale`` arrays (fused dequant). Returns
    the fully-normalized fp32 output over [committed history || intra-chunk
    causal] — the serving ingest counterpart of ``flash_decode``."""
    return _fp.flash_prefill(q, k_pages, v_pages, page_table, lengths,
                             window=window, k_scale=k_scale, v_scale=v_scale,
                             interpret=_interpret())
