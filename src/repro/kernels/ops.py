"""Jitted kernel wrappers. On the CPU dev container the Pallas kernels run in
interpret mode (the kernel body executes as JAX ops — correctness path); on a
TPU backend they compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import edm_loss as _edm
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adaln as _ad


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_hmajor(q, k, v, causal: bool = True,
                           window: Optional[int] = None):
    """(B, H, S, hd) layout."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


def flash_attention(q, k, v, *, mask_mod=None, qpos=None, kpos=None,
                    causal: bool = True, window: Optional[int] = None):
    """(B, S, H, hd) layout adapter used by repro.nn.attention."""
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_hmajor(qh, kh, vh, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)


@jax.jit
def ln_modulate(x, scale, shift):
    return _ad.fused_ln_modulate(x, scale, shift, interpret=_interpret())


@jax.jit
def gate_residual(res, branch, gate):
    return _ad.fused_gate_residual(res, branch, gate, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sigma_data",))
def euler_update(z, f, sigma, sigma_to, sigma_data: float = 0.5):
    return _ad.fused_euler(z, f, sigma, sigma_to, sigma_data,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sigma_data",))
def edm_loss(f, z, y, sigma, sigma_data: float = 0.5):
    return _edm.edm_loss(f, z, y, sigma, sigma_data, interpret=_interpret())
