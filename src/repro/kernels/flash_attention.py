"""Flash attention Pallas TPU kernels: tiled online-softmax forward (emitting
the per-row logsumexp) plus recomputation-based backward kernels (dq and
dk/dv), wired together with ``jax.custom_vjp`` so training differentiates
through hand-written Pallas code instead of autodiff-ing the ``pallas_call``
(which XLA cannot transpose and Mosaic cannot compile).

Masking is computed from block indices (no (S, S) mask in HBM). Supported
mask kinds — all the masks the DiffusionBlocks training path uses:

  full       no masking (bidirectional)
  causal     kpos <= qpos
  window     causal sliding window of ``window`` keys
  db_concat  paper App. E.4 [clean || noisy] mask (mask_seq = S, streams 2S)
  two_pass   DB two-pass noisy-stream mask (keys = [clean || noisy_diag])

Layout: q (B, H, Sq, hd), k/v (B, KV, Sk, hd) — head-major so a (block_q, hd)
q tile and (block_k, hd) kv tiles stream through VMEM while the MXU runs
(block_q × hd) @ (hd × block_k). Tiles default to 128×128 (MXU-aligned);
accumulators live in VMEM scratch across the innermost grid dimension.

Validated (values and grads) against ``ref.mha_reference`` in interpret mode
(CPU container); compiled path targets TPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tiles import pad_seq as _pad_seq

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30

MASK_KINDS = ("full", "causal", "window", "db_concat", "two_pass")


@dataclasses.dataclass(frozen=True)
class FlashConfig:
    """Static kernel configuration (hashable — jit/custom_vjp nondiff arg)."""
    mask_kind: str = "causal"
    window: Optional[int] = None        # only for mask_kind == "window"
    mask_seq: Optional[int] = None      # S for db_concat / two_pass
    block_q: int = DEFAULT_BLOCK_Q
    block_k: int = DEFAULT_BLOCK_K
    interpret: bool = False

    def __post_init__(self):
        # hard raises (not asserts): an unchecked kind would fall through
        # _tile_mask to bounds-only masking — silent full attention
        if self.mask_kind not in MASK_KINDS:
            raise ValueError(f"unknown mask_kind {self.mask_kind!r}; "
                             f"one of {MASK_KINDS}")
        if self.mask_kind == "window" and self.window is None:
            raise ValueError("mask_kind='window' requires window")
        if self.mask_kind in ("db_concat", "two_pass") \
                and self.mask_seq is None:
            raise ValueError(f"mask_kind={self.mask_kind!r} requires "
                             "mask_seq")


def _tile_mask(qpos, kpos, cfg: FlashConfig, seq_q: int, seq_k: int):
    """Boolean keep-mask for a (block_q, block_k) tile of global positions."""
    mask = (qpos < seq_q) & (kpos < seq_k)
    if cfg.mask_kind == "causal":
        mask &= kpos <= qpos
    elif cfg.mask_kind == "window":
        mask &= (kpos <= qpos) & (kpos > qpos - cfg.window)
    elif cfg.mask_kind == "db_concat":
        S = cfg.mask_seq
        q_clean = qpos < S
        k_clean = kpos < S
        clean_clean = q_clean & k_clean & (kpos <= qpos)
        noisy_clean = (~q_clean) & k_clean & (kpos < qpos - S)
        noisy_self = (~q_clean) & (kpos == qpos)
        mask &= clean_clean | noisy_clean | noisy_self
    elif cfg.mask_kind == "two_pass":
        S = cfg.mask_seq
        mask &= ((kpos < S) & (kpos < qpos)) | (kpos == qpos + S)
    return mask


def _tile_positions(iq, ik, block_q: int, block_k: int):
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    return qpos, kpos


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale: float, cfg: FlashConfig, n_kv_blocks: int,
                seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos, kpos = _tile_positions(iq, ik, cfg.block_q, cfg.block_k)
    mask = _tile_mask(qpos, kpos, cfg, seq_q, seq_k)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        # logsumexp per q row; fully-masked (padded) rows stay at ~NEG_INF
        lse_ref[0, 0] = m_ref[...] + jnp.log(jnp.maximum(l, 1e-30))


def _fwd_impl(q, k, v, cfg: FlashConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,H,Sq,hd), lse (B,H,Sq_pad) float32)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    block_q = min(cfg.block_q, Sq)
    block_k = min(cfg.block_k, Sk)
    cfg = dataclasses.replace(cfg, block_q=block_q, block_k=block_k)
    q = _pad_seq(q, Sq + (-Sq) % block_q)
    k = _pad_seq(k, Sk + (-Sk) % block_k)
    v = _pad_seq(v, Sk + (-Sk) % block_k)
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(_fwd_kernel, scale=scale, cfg=cfg,
                               n_kv_blocks=nk, seq_q=Sq, seq_k=Sk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(q.shape[:3], jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),    # l (running sum)
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc (weighted values)
        ],
        interpret=cfg.interpret,
    )(q, k, v)
    return out[:, :, :Sq], lse


# ---------------------------------------------------------------------------
# Backward: dq kernel (grid inner dim = kv blocks), dk/dv kernel (inner = q)
# Both recompute the score tiles from (q, k) and the stored logsumexp — the
# (Sq, Sk) probability matrix never exists in HBM (FlashAttention-style).
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, scale: float, cfg: FlashConfig,
                   n_kv_blocks: int, seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]                            # (bq,)
    delta = delta_ref[0, 0]                        # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos, kpos = _tile_positions(iq, ik, cfg.block_q, cfg.block_k)
    mask = _tile_mask(qpos, kpos, cfg, seq_q, seq_k)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    acc_ref[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                    cfg: FlashConfig, n_q_blocks: int, seq_q: int,
                    seq_k: int):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos, kpos = _tile_positions(iq, ik, cfg.block_q, cfg.block_k)
    mask = _tile_mask(qpos, kpos, cfg, seq_q, seq_k)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)     # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale                  # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == n_q_blocks - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_impl(q, k, v, o, lse, do, cfg: FlashConfig):
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    block_q = min(cfg.block_q, Sq)
    block_k = min(cfg.block_k, Sk)
    cfg = dataclasses.replace(cfg, block_q=block_q, block_k=block_k)
    Sq_pad = Sq + (-Sq) % block_q
    Sk_pad = Sk + (-Sk) % block_k
    qp, dop, op = _pad_seq(q, Sq_pad), _pad_seq(do, Sq_pad), _pad_seq(o, Sq_pad)
    kp, vp = _pad_seq(k, Sk_pad), _pad_seq(v, Sk_pad)
    nq, nk = Sq_pad // block_q, Sk_pad // block_k
    # delta_i = sum_d dO_i · O_i — the softmax-normalization correction term
    # (one elementwise reduce; padded rows carry dO = 0 so contribute nothing)
    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32), axis=-1)

    q_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd),
                           lambda b, h, iq, ik: (b, h // G, ik, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, cfg=cfg,
                          n_kv_blocks=nk, seq_q=Sq, seq_k=Sk),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=cfg.interpret,
    )(qp, kp, vp, dop, lse, delta)

    # dk/dv computed per q-head into (B, H, Sk, hd); GQA group-sum follows.
    q_spec2 = pl.BlockSpec((1, 1, block_q, hd),
                           lambda b, h, ik, iq: (b, h, iq, 0))
    kv_spec2 = pl.BlockSpec((1, 1, block_k, hd),
                            lambda b, h, ik, iq: (b, h // G, ik, 0))
    kvh_spec2 = pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, ik, iq: (b, h, ik, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, cfg=cfg,
                          n_q_blocks=nq, seq_q=Sq, seq_k=Sk),
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=[kvh_spec2, kvh_spec2],
        out_shape=[jax.ShapeDtypeStruct((B, H, Sk_pad, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, H, Sk_pad, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=cfg.interpret,
    )(qp, kp, vp, dop, lse, delta)

    dq = dq[:, :, :Sq]
    dk, dv = dk[:, :, :Sk], dv[:, :, :Sk]
    if G > 1:   # GQA: sum the per-q-head contributions within each kv group
        dk = dk.reshape(B, KV, G, Sk, hd).sum(axis=2).astype(k.dtype)
        dv = dv.reshape(B, KV, G, Sk, hd).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg: FlashConfig):
    out, _ = _fwd_impl(q, k, v, cfg)
    return out


def _flash_fwd(q, k, v, cfg: FlashConfig):
    out, lse = _fwd_impl(q, k, v, cfg)
    return out, (q, k, v, out, lse)


def _flash_bwd(cfg: FlashConfig, res, do):
    q, k, v, o, lse = res
    return _bwd_impl(q, k, v, o, lse, do, cfg)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    mask_kind: Optional[str] = None,
                    mask_seq: Optional[int] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd); H = KV * G. Returns like q.

    Fully differentiable: gradients run through the Pallas backward kernels
    (``jax.custom_vjp``), never through autodiff of ``pallas_call``.
    """
    if mask_kind is None:
        mask_kind = ("window" if window is not None
                     else "causal" if causal else "full")
    cfg = FlashConfig(mask_kind=mask_kind, window=window, mask_seq=mask_seq,
                      block_q=block_q, block_k=block_k, interpret=interpret)
    return _flash(q, k, v, cfg)
