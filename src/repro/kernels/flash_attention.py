"""Flash attention Pallas TPU kernel: tiled online-softmax with causal /
sliding-window masking computed from block indices (no (S,S) mask in HBM),
GQA via kv-head index mapping.

Layout: q (B, H, Sq, hd), k/v (B, KV, Sk, hd) — head-major so a (block_q, hd)
q tile and (block_k, hd) kv tiles stream through VMEM while the MXU runs
(block_q × hd) @ (hd × block_k). Tiles default to 128×128 (MXU-aligned);
accumulators live in VMEM scratch across the innermost kv grid dimension.

Validated against ``ref.mha_reference`` in interpret mode (CPU container);
compiled path targets TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_kv_blocks: int,
                  seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = (qpos < seq_q) & (kpos < seq_k)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd); H = KV * G. Returns like q."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),    # l (running sum)
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc (weighted values)
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
