"""Chunked-prefill Pallas TPU kernel: a CHUNK of C queries over a PAGED KV
cache.

``flash_decode`` (PR 3) serves one query token per slot; prefilling a prompt
through it costs one serial attention step per token. This kernel is the
missing half: the whole prompt chunk's queries attend in ONE dispatch, after
the chunk's keys/values have been appended to the page pool
(``repro.nn.cache.append_paged_chunk``), so a prompt of S tokens costs
ceil(S / C) attention steps instead of S.

Layout and tricks shared with ``flash_decode``:

  * grid = (batch_slot, kv_head, logical_page); pages are the innermost grid
    dimension so the per-row (m, l, acc) logsumexp state carries across them
    in VMEM scratch;
  * the physical page streamed into VMEM comes from the scalar-prefetched
    page table (``PrefetchScalarGridSpec``) — no host-side indirection;
  * GQA-aware: queries arrive grouped (B, C, KV, G, hd) and are flattened to
    rows r = i*G + g, so the (rows, page_size) score tile is MXU-shaped and
    the per-row query index i = r // G drives the causal mask;
  * masking is length-aware AND causal: the chunk occupies absolute positions
    [lengths[b], lengths[b] + C), its K/V are ALREADY in the pages, and key
    slot at logical index ``idx`` is valid for query row i iff
    ``idx <= lengths[b] + i`` (sliding-window layers additionally require
    ``idx > lengths[b] + i - window``). Ragged chunk tails (tokens past a
    slot's prompt) produce garbage rows that the caller discards — their
    writes were redirected to the trash page, never to live pages.

Unlike decode there is no ``combine_self``: the chunk's own keys live in the
pool before the kernel runs, so one pass covers history + intra-chunk causal.

Prefill is inference-only (no custom VJP). Validated against the gather
reference in ``repro.nn.cache`` in interpret mode (CPU container); compiled
path targets TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
# TPU fp32 min sublane count; the flattened (C*G) query-row axis is padded up
# to a multiple of this so the (rows, page_size) score tile is alignable.
MIN_ROW_PAD = 8


def _prefill_kernel(*refs, scale: float, page_size: int,
                    n_pages: int, chunk: int, group: int,
                    window: Optional[int], quantized: bool):
    if quantized:
        (table_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (table_ref, len_ref, q_ref, k_ref, v_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    start = p * page_size

    # The furthest key any query in this chunk may attend is
    # lengths[b] + chunk - 1; pages entirely past that carry nothing valid —
    # skip their DMA'd tile outright (saves MXU work on the unreached tail).
    @pl.when(start < length + chunk)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)            # (rows, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page_size, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # in-register dequant with this physical page's prefetched scale
            phys = table_ref[b, p]
            k = k * ks_ref[phys]
            v = v * vs_ref[phys]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # query row r = i*G + g sits at absolute position lengths[b] + i
        qpos = length + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                 0) // group
        valid = idx <= qpos
        if window is not None:
            valid &= idx > qpos - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pexp = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                  page_table: jax.Array, lengths: jax.Array, *,
                  window: Optional[int] = None,
                  k_scale: Optional[jax.Array] = None,
                  v_scale: Optional[jax.Array] = None,
                  interpret: bool = False) -> jax.Array:
    """Chunked-prefill paged attention (history + intra-chunk causal).

    q:          (B, C, KV, G, hd) — the chunk's grouped queries; the chunk
                occupies absolute positions [lengths[b], lengths[b] + C) and
                its OWN k/v must already be appended to the pool
                (``repro.nn.cache.append_paged_chunk``)
    k_pages/v_pages: (P, page_size, KV, hd) physical page pool
    page_table: (B, n_logical_pages) int32; entries past a sequence's
                allocation MUST be in-bounds (reserved trash page — nn.cache)
    lengths:    (B,) int32 committed tokens per slot BEFORE this chunk
    k_scale/v_scale: per-PHYSICAL-page fp32 dequant scales for an int8 pool
                ((P,) or (P, 1, 1, 1); both given or both None),
                scalar-prefetched like the table and applied in-register

    Returns out (B, C, KV, G, hd) fp32 — fully softmax-normalized (no lse:
    the chunk's self keys are in the pool, nothing left to fold in).
    """
    B, C, KV, G, hd = q.shape
    psz = k_pages.shape[1]
    n_pages = page_table.shape[1]
    scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None
    rows = C * G
    Rp = -(-rows // MIN_ROW_PAD) * MIN_ROW_PAD
    # rows flatten (C, G) with G minor, so row r = i*G + g as the mask expects
    qr = q.transpose(0, 2, 1, 3, 4).reshape(B, KV, rows, hd)
    if Rp != rows:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, Rp - rows), (0, 0)))

    kernel = functools.partial(_prefill_kernel, scale=scale, page_size=psz,
                               n_pages=n_pages, chunk=C, group=G,
                               window=window, quantized=quantized)
    # with scales, the index_map lambdas receive two extra prefetch refs —
    # keep the unquantized specs verbatim so the bf16 program is unchanged
    if quantized:
        q_map = lambda b, kv, p, tbl, lens, ks, vs: (b, kv, 0, 0)
        kv_map = lambda b, kv, p, tbl, lens, ks, vs: (tbl[b, p], 0, kv, 0)
    else:
        q_map = lambda b, kv, p, tbl, lens: (b, kv, 0, 0)
        kv_map = lambda b, kv, p, tbl, lens: (tbl[b, p], 0, kv, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, Rp, hd), q_map),
            pl.BlockSpec((1, psz, 1, hd), kv_map),
            pl.BlockSpec((1, psz, 1, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Rp, hd), q_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((Rp,), jnp.float32),      # m (running max)
            pltpu.VMEM((Rp,), jnp.float32),      # l (running sum)
            pltpu.VMEM((Rp, hd), jnp.float32),   # acc (weighted values)
        ],
    )
    prefetch = (page_table.astype(jnp.int32), lengths.astype(jnp.int32))
    if quantized:
        prefetch += (k_scale.reshape(-1).astype(jnp.float32),
                     v_scale.reshape(-1).astype(jnp.float32))
    [out] = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, Rp, hd), jnp.float32)],
        interpret=interpret,
    )(*prefetch, qr, k_pages, v_pages)
    return out[:, :, :rows].reshape(B, KV, C, G, hd).transpose(0, 2, 1, 3, 4)
