"""Flash-decoding Pallas TPU kernel for 1-token queries over a PAGED KV cache.

The serving cache (``repro.nn.cache``) stores keys/values as a pool of
fixed-size pages; a per-slot page table maps logical page ``p`` of sequence
``b`` to a physical page id. This kernel is the split-KV trick from
flash-decoding (Dao et al.) married to paged-attention serving (Kwon et al.,
vLLM):

  * grid = (batch_slot, kv_head, logical_page) — the KV axis is split into
    pages and each page's partial softmax is combined online via the running
    (m, l, acc) logsumexp state in VMEM scratch (pages are the innermost grid
    dimension, so scratch carries across them);
  * the PHYSICAL page to stream into VMEM is computed from the page table via
    ``PrefetchScalarGridSpec`` — the table and the per-slot lengths are
    scalar-prefetched, so the BlockSpec index_map gathers pages straight from
    HBM with no host-side indirection;
  * masking is length-aware: page slots at logical position >= lengths[b]
    (and, for sliding-window layers, <= lengths[b] - window) are masked, so
    RAGGED sequences share one compiled program;
  * GQA-aware: queries arrive grouped (B, KV, G, hd); scores/accumulators are
    fp32 regardless of the (typically bf16) page dtype — the ``repro.precision``
    serving policy is "bf16 KV, fp32 logsumexp".

The kernel attends over *committed* tokens only (logical index < lengths[b]).
The current token's own k/v — which the DB sampler needs both for denoising
probes (not yet committed) and for the commit pass — is folded in afterwards
by ``combine_self`` from the returned (out, lse) partials; that keeps the
kernel free of any append/ordering concerns.

Decode is inference-only: no custom VJP (nothing differentiates through the
serving path). Validated against the gather-based reference in
``repro.nn.cache`` in interpret mode (CPU container); compiled path targets
TPU.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
# TPU fp32 min sublane count; the GQA group axis is padded up to this so the
# (G, page_size) score tile is alignable. Interpret mode accepts any G.
MIN_GROUP_PAD = 8


def _decode_kernel(*refs, scale: float, page_size: int,
                   n_pages: int, window: Optional[int], quantized: bool):
    if quantized:
        (table_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
         o_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (table_ref, len_ref, q_ref, k_ref, v_ref,
         o_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    start = p * page_size

    # pages entirely past the sequence's committed length carry no valid
    # slots — skip their DMA'd tile outright (the mask below would zero them
    # anyway; this saves the MXU work on the ragged tail).
    @pl.when(start < length)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page_size, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # int8 bytes stream from HBM; dequant happens here in-register
            # with this PHYSICAL page's fp32 scale, scalar-prefetched like
            # the page table itself.
            phys = table_ref[b, p]
            k = k * ks_ref[phys]
            v = v * vs_ref[phys]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = idx < length
        if window is not None:
            valid &= idx > length - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pexp = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        # lse of the page partials; a slot with lengths[b]==0 finalizes at
        # ~NEG_INF so combine_self gives it zero weight.
        lse_ref[0, 0] = m_ref[...] + jnp.log(jnp.maximum(l, 1e-30))


def flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                 page_table: jax.Array, lengths: jax.Array, *,
                 window: Optional[int] = None,
                 k_scale: Optional[jax.Array] = None,
                 v_scale: Optional[jax.Array] = None,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Split-KV paged decode attention over committed tokens.

    q:          (B, KV, G, hd) — the single new token's grouped queries
    k_pages/v_pages: (P, page_size, KV, hd) physical page pool
    page_table: (B, n_logical_pages) int32 — physical page id per logical
                page; entries past a sequence's allocation MUST still be
                in-bounds (point them at a reserved page — see nn.cache)
    lengths:    (B,) int32 committed-token counts (mask: idx < lengths[b])
    k_scale/v_scale: per-PHYSICAL-page fp32 dequant scales for an int8 pool
                ((P,) or (P, 1, 1, 1); both given or both None). They are
                scalar-prefetched exactly like the page table and applied
                in-register after the int8 page streams into VMEM.

    Returns ``(out, lse)``: out (B, KV, G, hd) fp32 — softmax-normalized over
    the committed tokens only — and lse (B, KV, G) fp32, the partials'
    logsumexp. Fold in the current token's own k/v with ``combine_self``.
    """
    B, KV, G, hd = q.shape
    psz = k_pages.shape[1]
    n_pages = page_table.shape[1]
    scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None
    Gp = max(G, MIN_GROUP_PAD)
    if Gp != G:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))

    kernel = functools.partial(_decode_kernel, scale=scale, page_size=psz,
                               n_pages=n_pages, window=window,
                               quantized=quantized)
    # with scales, the index_map lambdas receive two extra prefetch refs —
    # keep the unquantized specs verbatim so the bf16 program is unchanged
    if quantized:
        q_map = lambda b, kv, p, tbl, lens, ks, vs: (b, kv, 0, 0)
        kv_map = lambda b, kv, p, tbl, lens, ks, vs: (tbl[b, p], 0, kv, 0)
        lse_map = lambda b, kv, p, tbl, lens, ks, vs: (b, kv, 0)
    else:
        q_map = lambda b, kv, p, tbl, lens: (b, kv, 0, 0)
        kv_map = lambda b, kv, p, tbl, lens: (tbl[b, p], 0, kv, 0)
        lse_map = lambda b, kv, p, tbl, lens: (b, kv, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, Gp, hd), q_map),
            pl.BlockSpec((1, psz, 1, hd), kv_map),
            pl.BlockSpec((1, psz, 1, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Gp, hd), q_map),
            pl.BlockSpec((1, 1, Gp), lse_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((Gp,), jnp.float32),      # m (running max)
            pltpu.VMEM((Gp,), jnp.float32),      # l (running sum)
            pltpu.VMEM((Gp, hd), jnp.float32),   # acc (weighted values)
        ],
    )
    prefetch = (page_table.astype(jnp.int32), lengths.astype(jnp.int32))
    if quantized:
        prefetch += (k_scale.reshape(-1).astype(jnp.float32),
                     v_scale.reshape(-1).astype(jnp.float32))
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, Gp, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, Gp), jnp.float32),
        ],
        interpret=interpret,
    )(*prefetch, q, k_pages, v_pages)
    return out[:, :, :G], lse[:, :, :G]


def combine_self(out: jax.Array, lse: jax.Array, s_self: jax.Array,
                 v_self: jax.Array) -> jax.Array:
    """Merge the paged partial with the current token's own (k, v).

    Standard two-partial flash combine: the cache partial carries
    (out, lse); the self term is a one-key partial with score ``s_self``
    (B, KV, G) and value ``v_self`` (B, KV, hd). An empty cache
    (lse ≈ -inf) degrades to pure self-attention — exactly the first
    decode step of an empty slot.
    """
    m = jnp.maximum(lse, s_self)
    w_cache = jnp.exp(lse - m)
    w_self = jnp.exp(s_self - m)
    num = out * w_cache[..., None] + v_self[:, :, None, :] * w_self[..., None]
    return num / (w_cache + w_self)[..., None]
