# Fused Pallas kernel layer — the hardware-target train/infer hot path.
# Every kernel is differentiable via jax.custom_vjp with hand-written
# Pallas backward kernels (see each module); repro.nn and repro.core route
# through repro.kernels.ops when impl="kernels". Oracles live in ref.py.
