"""Fused EDM denoising loss kernel (paper Eq. 2/6, F-space form).

Computes per-tile partial sums of ||F − (y − c_skip z)/c_out||² without
materializing the target tensor in HBM: each (block_rows × d) tile of F, z, y
is read once, the target is formed in VMEM, squared error reduced on the VPU,
and one partial scalar per tile is written out. The caller sums the partials
(a (grid,) vector) — O(B·S/block_rows) bytes instead of O(B·S·d).

Differentiable via ``jax.custom_vjp``: the VJP is one cheap elementwise
kernel that re-forms the target in VMEM and scales by the incoming per-tile
cotangent — the target STILL never rematerializes in HBM on either pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import (pad_rows as _pad3, row_spec as _rows_spec,
                                 scalar_spec as _scalar_spec, tile_spec
                                 as _tile_spec)

BLOCK_ROWS = 256


def _coeffs(sigma, sigma_data: float):
    """c_skip/c_out per EDM preconditioning — pinned against
    core/edm.preconditioning by tests/test_kernel_grads.py (kernels stay
    import-light; the test makes silent drift impossible)."""
    B = sigma.shape[0]
    sf = sigma.astype(jnp.float32)
    s2 = sf ** 2
    d2 = sigma_data ** 2
    c_skip = (d2 / (s2 + d2)).reshape(B, 1)
    c_out = (sf * sigma_data * jax.lax.rsqrt(s2 + d2)).reshape(B, 1)
    return c_skip, c_out


def _loss_kernel(f_ref, z_ref, y_ref, cs_ref, co_ref, o_ref, *, rows: int,
                 block_rows: int):
    i = pl.program_id(1)
    f = f_ref[0].astype(jnp.float32)
    z = z_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    c_skip = cs_ref[0, 0]
    c_out = co_ref[0, 0]
    target = (y - c_skip * z) / c_out
    err = jnp.square(f - target)
    # zero padded rows
    ridx = i * block_rows + jax.lax.broadcasted_iota(
        jnp.int32, err.shape, 0)
    err = jnp.where(ridx < rows, err, 0.0)
    o_ref[0, 0] = jnp.sum(err)


def _loss_bwd_kernel(f_ref, z_ref, y_ref, cs_ref, co_ref, g_ref,
                     df_ref, dz_ref, dy_ref, *, rows: int, block_rows: int):
    """err = (f − t)², t = (y − c_skip z)/c_out ⇒ per-element
    df = 2(f−t)·g,  dz = (c_skip/c_out)·df,  dy = −df/c_out."""
    i = pl.program_id(1)
    f = f_ref[0].astype(jnp.float32)
    z = z_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    c_skip = cs_ref[0, 0]
    c_out = co_ref[0, 0]
    g = g_ref[0, 0]                                      # tile cotangent
    target = (y - c_skip * z) / c_out
    df = 2.0 * (f - target) * g
    ridx = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, df.shape, 0)
    df = jnp.where(ridx < rows, df, 0.0)
    df_ref[0] = df.astype(df_ref.dtype)
    dz_ref[0] = (df * (c_skip / c_out)).astype(dz_ref.dtype)
    dy_ref[0] = (-df / c_out).astype(dy_ref.dtype)


def _partials_fwd_call(f, z, y, c_skip, c_out, rows, block_rows, interpret):
    B, _, d = f.shape
    fp, zp, yp = (_pad3(t, block_rows) for t in (f, z, y))
    ns = fp.shape[1] // block_rows
    return pl.pallas_call(
        functools.partial(_loss_kernel, rows=rows, block_rows=block_rows),
        grid=(B, ns),
        in_specs=[_rows_spec(block_rows, d)] * 3 + [_scalar_spec()] * 2,
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((B, ns), jnp.float32),
        interpret=interpret,
    )(fp, zp, yp, c_skip, c_out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _partials(f, z, y, sigma, sigma_data, block_rows, interpret):
    c_skip, c_out = _coeffs(sigma, sigma_data)
    return _partials_fwd_call(f, z, y, c_skip, c_out, f.shape[1],
                              block_rows, interpret)


def _partials_vjp_fwd(f, z, y, sigma, sigma_data, block_rows, interpret):
    c_skip, c_out = _coeffs(sigma, sigma_data)
    out = _partials_fwd_call(f, z, y, c_skip, c_out, f.shape[1],
                             block_rows, interpret)
    return out, (f, z, y, c_skip, c_out, sigma)


def _partials_vjp_bwd(sigma_data, block_rows, interpret, res, g):
    f, z, y, c_skip, c_out, sigma = res
    B, S, d = f.shape
    fp, zp, yp = (_pad3(t, block_rows) for t in (f, z, y))
    ns = fp.shape[1] // block_rows
    df, dz, dy = pl.pallas_call(
        functools.partial(_loss_bwd_kernel, rows=S, block_rows=block_rows),
        grid=(B, ns),
        in_specs=[_rows_spec(block_rows, d)] * 3 + [_scalar_spec()] * 2
        + [_tile_spec()],
        out_specs=[_rows_spec(block_rows, d)] * 3,
        out_shape=[jax.ShapeDtypeStruct(fp.shape, f.dtype),
                   jax.ShapeDtypeStruct(fp.shape, z.dtype),
                   jax.ShapeDtypeStruct(fp.shape, y.dtype)],
        interpret=interpret,
    )(fp, zp, yp, c_skip, c_out, g.astype(jnp.float32))
    # σ parameterizes the sampled noise level — never differentiated
    return df[:, :S], dz[:, :S], dy[:, :S], jnp.zeros_like(sigma)


_partials.defvjp(_partials_vjp_fwd, _partials_vjp_bwd)


def edm_loss_partials(f: jax.Array, z: jax.Array, y: jax.Array,
                      sigma: jax.Array, sigma_data: float,
                      block_rows: int = BLOCK_ROWS,
                      interpret: bool = False) -> jax.Array:
    """f/z/y: (B, S, d); sigma: (B,). Returns partial sums (B, n_tiles);
    loss = sum(partials) / (B*S*d). Differentiable w.r.t. f, z, y."""
    block_rows = min(block_rows, f.shape[1])
    return _partials(f, z, y, sigma, sigma_data, block_rows, interpret)


def edm_loss(f, z, y, sigma, sigma_data: float, interpret: bool = False):
    B, S, d = f.shape
    partials = edm_loss_partials(f, z, y, sigma, sigma_data,
                                 interpret=interpret)
    return jnp.sum(partials) / (B * S * d)
