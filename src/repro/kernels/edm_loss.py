"""Fused EDM denoising loss kernel (paper Eq. 2/6, F-space form).

Computes per-tile partial sums of ||F − (y − c_skip z)/c_out||² without
materializing the target tensor in HBM: each (block_rows × d) tile of F, z, y
is read once, the target is formed in VMEM, squared error reduced on the VPU,
and one partial scalar per tile is written out. The caller sums the partials
(a (grid,) vector) — O(B·S/block_rows) bytes instead of O(B·S·d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _loss_kernel(f_ref, z_ref, y_ref, cs_ref, co_ref, o_ref, *, rows: int,
                 block_rows: int):
    i = pl.program_id(1)
    f = f_ref[0].astype(jnp.float32)
    z = z_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    c_skip = cs_ref[0, 0]
    c_out = co_ref[0, 0]
    target = (y - c_skip * z) / c_out
    err = jnp.square(f - target)
    # zero padded rows
    ridx = i * block_rows + jax.lax.broadcasted_iota(
        jnp.int32, err.shape, 0)
    err = jnp.where(ridx < rows, err, 0.0)
    o_ref[0, 0] = jnp.sum(err)


def edm_loss_partials(f: jax.Array, z: jax.Array, y: jax.Array,
                      sigma: jax.Array, sigma_data: float,
                      block_rows: int = BLOCK_ROWS,
                      interpret: bool = False) -> jax.Array:
    """f/z/y: (B, S, d); sigma: (B,). Returns partial sums (B, n_tiles);
    loss = sum(partials) / (B*S*d)."""
    B, S, d = f.shape
    s2 = sigma.astype(jnp.float32) ** 2
    d2 = sigma_data ** 2
    c_skip = (d2 / (s2 + d2)).reshape(B, 1)
    c_out = (sigma * sigma_data * jax.lax.rsqrt(s2 + d2)).reshape(B, 1)
    block_rows = min(block_rows, S)
    pad = (-S) % block_rows
    if pad:
        f = jnp.pad(f, ((0, 0), (0, pad), (0, 0)))
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        y = jnp.pad(y, ((0, 0), (0, pad), (0, 0)))
    ns = f.shape[1] // block_rows
    out = pl.pallas_call(
        functools.partial(_loss_kernel, rows=S, block_rows=block_rows),
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_rows, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, ns), jnp.float32),
        interpret=interpret,
    )(f, z, y, c_skip, c_out)
    return out


def edm_loss(f, z, y, sigma, sigma_data: float, interpret: bool = False):
    B, S, d = f.shape
    partials = edm_loss_partials(f, z, y, sigma, sigma_data,
                                 interpret=interpret)
    return jnp.sum(partials) / (B * S * d)
