"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) / (hd ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def mha_reference_masked(q, k, v, mask: jax.Array) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd); mask: (Sq, Sk) bool keep-mask.
    Oracle for the kernel's db_concat / two_pass mask kinds."""
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / (hd ** 0.5)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def ln_modulate_reference(x, scale, shift, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale[:, None].astype(jnp.float32)) \
        + shift[:, None].astype(jnp.float32)
    return y.astype(x.dtype)


def gate_residual_reference(res, branch, gate):
    return (res.astype(jnp.float32) + branch.astype(jnp.float32)
            * (1.0 + gate[:, None].astype(jnp.float32))).astype(res.dtype)


def euler_reference(z, f, sigma, sigma_to, sigma_data: float):
    s2 = sigma.astype(jnp.float32) ** 2
    d2 = sigma_data ** 2
    c_skip = d2 / (s2 + d2)
    c_out = sigma * sigma_data * jax.lax.rsqrt(s2 + d2)
    r = sigma_to / sigma
    a = (r + (1 - r) * c_skip)[:, None, None]
    b = ((1 - r) * c_out)[:, None, None]
    return (a * z.astype(jnp.float32) + b * f.astype(jnp.float32)
            ).astype(z.dtype)


def edm_loss_reference(f, z, y, sigma, sigma_data: float):
    s2 = sigma.astype(jnp.float32) ** 2
    d2 = sigma_data ** 2
    c_skip = (d2 / (s2 + d2))[:, None, None]
    c_out = (sigma * sigma_data * jax.lax.rsqrt(s2 + d2))[:, None, None]
    target = (y.astype(jnp.float32) - c_skip * z.astype(jnp.float32)) / c_out
    return jnp.mean(jnp.square(f.astype(jnp.float32) - target))
