"""Uniform model API across the six architecture families.

A model is partitioned into ``n_units`` *units* — the granularity at which
DiffusionBlocks slices the network into blocks (paper §3.1 Step 1 /
"treating entire architectural blocks as single denoising units"):

  dense/moe      unit = one transformer layer
  vlm            unit = superblock of (cross_attn_every-1 self + 1 cross) layers
  hybrid/zamba2  unit = superblock of attn_every mamba layers + shared attn
  ssm/xlstm      unit = (sLSTM, mLSTM) pair
  audio/whisper  unit = one decoder layer (encoder is conditioning, unpartitioned)

Every family implements:
  init / abstract_params / axes
  embed(params, batch)                      -> hidden stream h (B,S,d)
  cond(params, log_sigma)                   -> (B,d) AdaLN conditioning (DB only)
  apply_units(params, h, start, size, ctx, cache) -> (h, cache', aux)
  apply_units_two_pass(params, hc, hn, start, size, ctx) -> (hc, hn, aux)
  logits(params, h)                         -> (B,S,V)
  init_cache(batch, cache_len, dtype, start, size) -> cache pytree for units
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DBConfig, ModelConfig
from repro.nn import adaln
from repro.nn import layers as L
from repro.nn.init import init_params, logical_axes, spec_shapes


class BaseModel:
    def __init__(self, cfg: ModelConfig, db: Optional[DBConfig] = None):
        self.cfg = cfg
        self.db = db
        self.spec = self.build_spec()

    # ---- to be provided by subclasses ------------------------------------
    @property
    def n_units(self) -> int:
        raise NotImplementedError

    def build_spec(self):
        raise NotImplementedError

    def apply_units(self, params, h, start: int, size: int, ctx, cache=None,
                    reset_mask=None):
        """``reset_mask`` (n_units bool, requires ``cache``): before applying
        unit u with reset_mask[u] set, the hidden stream is reset to the
        input ``h`` — the serving commit pass restarts every DB block's clean
        stream from raw token embeddings in ONE scan (see blocks.commit_token)
        instead of a per-block Python loop."""
        raise NotImplementedError

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        raise NotImplementedError

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16,
                   start: int = 0, size: Optional[int] = None):
        raise NotImplementedError

    def init_paged_cache(self, num_slots: int, n_pages: int, page_size: int,
                         policy=None):
        """Paged serving cache (repro.nn.cache): attention KV lives in a
        pool of ``n_pages`` pages shared by ``num_slots`` request slots
        (physical page 0 reserved as the trash page); per-slot recurrent
        states stay dense. Storage dtype follows the precision policy
        (``Policy.kv`` — bf16 under the serving default — for KV;
        ``Policy.state_for`` for recurrent states)."""
        raise NotImplementedError

    def reset_paged_slots(self, cache, slot_mask):
        """Zero the PER-SLOT state of slots being recycled for a new request
        (``slot_mask``: (num_slots,) bool). Paged KV needs no reset — length
        masking hides stale pages — so the purely-paged families return the
        cache unchanged; families with recurrent state or fixed per-slot
        cross blocks override."""
        return cache

    @property
    def paged_state_axes(self) -> dict:
        """Slot axis of every DENSE (non-paged) per-slot subtree in the
        family's paged cache, keyed by top-level cache key — what
        ``repro.nn.cache.spill_slot``/``restore_slot`` need to snapshot a
        slot for preemption. Purely-paged families (bare PagedKV trees)
        return {}; families with recurrent state or fixed cross blocks
        override to name where the per-slot rows live."""
        return {}

    # ---- conditioning (aux image/audio inputs) ---------------------------
    # One code path for every consumer: the training losses and the dense
    # dry-run shapes (via blocks.make_ctx), AND the batched serving engine
    # (which encodes ONCE at admission and stores the projected result in
    # the per-slot cross blocks) all go through these methods. Unconditioned
    # families return None / raise, so callers can feature-test the model
    # instead of switching on cfg.family.

    @property
    def max_cond_tokens(self) -> int:
        """Capacity of the per-slot conditioning memory block (0 = the
        family takes no aux conditioning inputs)."""
        return 0

    def aux_input_specs(self, batch: int, dtype=jnp.bfloat16):
        """ShapeDtypeStruct stand-ins for the family's aux conditioning
        inputs (no allocation), or None. The dry-run lowering and the
        benchmarks build their placeholder inputs from this."""
        return None

    @property
    def cond_padding_safe(self) -> bool:
        """True when ``encode_conditioning`` is position-local, so a
        zero-padded aux batch with per-row valid lengths encodes the valid
        rows exactly as an unpadded one would (VLM passthrough). The audio
        encoder is bidirectional — padding frames change every row — so it
        overrides to False: ragged conditioning must be encoded per request
        at its true length (the continuous batcher's admission path)."""
        return True

    def encode_conditioning(self, params, aux_inputs, ctx=None):
        """Run the family's modality frontend over the aux inputs and return
        the cross-attention memory (B, Sk, d), or None when the family is
        unconditioned / no aux was supplied. VLM passes stubbed patch
        embeddings through; audio runs the (bidirectional) encoder stack —
        ONCE per request, never per decode step."""
        return None

    def set_conditioning(self, params, cache, cond, slot=None):
        """Project encoded conditioning ``cond`` (B, Sk, d) through every
        unit's cross-attention (k, v) and write it into the cache's
        per-slot cross blocks (``cond`` is zero-padded to the block
        capacity; the valid length travels separately as
        ``LayerCtx.cond_lengths``). ``slot=None`` writes all slots
        (B == num_slots, the static engine); an int32 ``slot`` writes one
        slot's block (continuous-batching admission, B == 1). Works on both
        the paged serving cache and the dense ``init_cache`` layout (the
        dry-run reference path)."""
        raise ValueError(
            f"family {self.cfg.family!r} has no conditioning inputs")

    def cache_batch(self, cache) -> int:
        """Batch size of a cache pytree (leaf layout is family-specific)."""
        return jax.tree_util.tree_leaves(cache)[0].shape[1]

    @property
    def kv_carries_all_state(self) -> bool:
        """True when a sequence's ENTIRE history lives in paged attention KV
        (no per-slot recurrent state), so two slots mapping the same physical
        prefix pages really do share the same computation — the soundness
        precondition for the shared-prefix page cache. Recurrent families
        (mamba / xLSTM) override to False: their O(1) state is not paged, so
        prefix sharing cannot skip their prefill."""
        return False

    # ---- shared ----------------------------------------------------------
    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.spec, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return spec_shapes(self.spec, dtype)

    def axes(self):
        return logical_axes(self.spec)

    def common_spec(self):
        """embedding / head / final norm / sigma-conditioning specs."""
        cfg = self.cfg
        spec = {
            "embed": L.embed_spec(cfg.vocab_size, cfg.d_model),
            "final_norm": L.norm_spec(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            spec["head"] = L.readout_spec(cfg.d_model, cfg.vocab_size)
        if self.db is not None:
            spec["cond"] = adaln.sigma_embed_spec(self.db.cond_dim, cfg.d_model)
        return spec

    def embedding_table(self, params):
        table = params["embed"]["table"]
        if self.db is not None and self.db.embed_l2_normalize:
            table = L.l2_normalize_embeddings(table)
        return table

    def embed(self, params, tokens, dtype=None, positions=None):
        """``positions`` (broadcastable to tokens' shape) matter only for
        families with absolute position embeddings (whisper/encdec); rope
        families apply positions inside attention and ignore them here."""
        del positions
        h = self.embedding_table(params)[tokens]
        return h if dtype is None else h.astype(dtype)

    def cond(self, params, log_sigma, dtype=jnp.float32):
        assert self.db is not None
        return adaln.sigma_embedding(params["cond"], log_sigma / 4.0,
                                     self.db.cond_dim, dtype)

    def logits(self, params, h):
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm)
        if self.cfg.tie_embeddings:
            return h @ self.embedding_table(params).T.astype(h.dtype)
        return L.readout(params["head"], h)

    # full forward (all units) — convenience for e2e baseline / smoke tests.
    # The stream runs in the ctx policy's compute dtype (repro.precision);
    # logits/readout reductions stay fp32 inside ``logits``.
    def forward(self, params, tokens, ctx, cache=None):
        pol = getattr(ctx, "precision", None)
        h = self.embed(params, tokens,
                       dtype=None if pol is None
                       else pol.compute_for(self.cfg.family))
        h, cache, aux = self.apply_units(params, h, 0, self.n_units, ctx, cache)
        return self.logits(params, h), cache, aux


_REGISTRY = {}


def register(family: str):
    def deco(cls):
        _REGISTRY[family] = cls
        return cls
    return deco


def build_model(cfg: ModelConfig, db: Optional[DBConfig] = None) -> BaseModel:
    # imports deferred to avoid cycles
    from repro.models import transformer, hybrid, ssm_model, encdec  # noqa: F401
    return _REGISTRY[cfg.family](cfg, db)
