"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv frontend is STUBBED per the assignment:
``input_specs`` provides precomputed frame embeddings (B, n_frames, d_model).
The encoder (bidirectional self-attn) runs clean as conditioning; DB
partitions the decoder stack only.

Decoder layer = self-attn + cross-attn(encoder) + MLP, AdaLN-conditioned on σ
in DB mode (self-attn and MLP branches; cross stays unmodulated — it carries
the conditioning signal).
"""
from __future__ import annotations

import jax
from repro.nn.scan_util import uscan
import jax.numpy as jnp

from repro import precision as precision_mod
from repro.configs.base import AUDIO
from repro.models import common as C
from repro.models.model_api import BaseModel, register
from repro.nn import adaln
from repro.nn import attention as A
from repro.nn import cache as KVC
from repro.nn import layers as L
from repro.nn.init import stack_specs


def _scan_slice(params, start, size):
    return jax.tree_util.tree_map(lambda p: p[start:start + size], params)


def dlayer_spec(cfg, db: bool):
    d = cfg.d_model
    dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.rope_theta)
    spec = {
        "ln1": L.norm_spec(d, cfg.norm),
        "attn": A.attention_spec(d, dims, cfg.qkv_bias),
        "lnx": L.norm_spec(d, cfg.norm),
        "xattn": A.attention_spec(d, dims, cfg.qkv_bias),
        "ln2": L.norm_spec(d, cfg.norm),
        "mlp": L.mlp_spec(d, cfg.d_ff, cfg.mlp),
    }
    if db:
        spec["adaln"] = adaln.adaln_spec(d, n_mods=6)
    return spec


def _self_attn(p, x, ctx, cache):
    dims = ctx.dims()
    if ctx.mode == "prefill_chunk":
        assert isinstance(cache, KVC.PagedKV), \
            "prefill_chunk requires the paged cache"
        return KVC.paged_prefill_attention(
            p, x, dims, cache, lengths=ctx.lengths,
            page_table=ctx.page_table, n_valid=ctx.n_valid, impl=ctx.impl)
    if ctx.mode == "decode":
        if isinstance(cache, KVC.PagedKV):
            return KVC.paged_decode_attention(
                p, x, dims, cache, lengths=ctx.lengths,
                page_table=ctx.page_table, active=ctx.active,
                commit=ctx.commit, impl=ctx.impl)
        return A.decode_attention(p, x, dims, cache, ctx.pos,
                                  kv_chunk=ctx.kv_chunk, impl=ctx.impl)
    mask_mod = ctx.mask_mod or A.causal_mask
    out, (k, v) = A.attention_fwd(
        p, x, dims, positions=ctx.positions, mask_mod=mask_mod,
        rope_positions=ctx.rope_positions, impl=ctx.impl,
        q_chunk=ctx.q_chunk, kv_chunk=ctx.kv_chunk)
    return out, ({"k": k, "v": v} if ctx.mode == "prefill" else None)


def _cross_attn(p, x, ctx, cache):
    dims = ctx.dims()
    if cache is not None and ctx.mode in ("decode", "prefill_chunk"):
        return C.cross_cached_attn(p, x, ctx, cache), cache
    if ctx.kv_x is None:
        raise ValueError(
            "cross-attention layer with no conditioning memory: pass "
            "aux_inputs (audio_embs) on the dense train/prefill path — the "
            "serving engine admits unconditioned requests via "
            "cond_lengths=0 instead")
    out, (k, v) = A.attention_fwd(
        p, x, dims, positions=ctx.positions, mask_mod=None, kv_x=ctx.kv_x,
        kv_positions=ctx.kv_positions, impl=ctx.impl)
    return out, ({"k": k, "v": v} if ctx.mode == "prefill" else None)


def dlayer_apply(p, h, ctx, cache=None):
    cfg = ctx.cfg
    if ctx.cond is not None and "adaln" in p:
        s1, c1, g1, s2, c2, g2 = adaln.adaln_mods(p["adaln"], ctx.cond,
                                                  cfg.d_model, 6)
    else:
        s1 = c1 = g1 = s2 = c2 = g2 = None
    sc, xc = (None, None) if cache is None else (cache["self"], cache["cross"])
    cm = ctx.cond_mask

    x = adaln.modulate(L.apply_norm(p["ln1"], h, cfg.norm), s1, c1, cm)
    out, new_self = _self_attn(p["attn"], x, ctx, sc)
    h = adaln.gate(h, out, g1, cm)

    x = L.apply_norm(p["lnx"], h, cfg.norm)
    out, new_cross = _cross_attn(p["xattn"], x, ctx, xc)
    h = h + out

    x = adaln.modulate(L.apply_norm(p["ln2"], h, cfg.norm), s2, c2, cm)
    h = adaln.gate(h, L.apply_mlp(p["mlp"], x, cfg.mlp), g2, cm)
    keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
    return h, ({"self": new_self, "cross": new_cross} if keep else None)


def dlayer_two_pass(p, hc, hn, ctx):
    """Two-pass DB for the decoder layer: reuse common.tlayer_two_pass for the
    self-attn + MLP pair, then insert the (unmodulated) cross-attn for both
    streams by composing manually."""
    cfg = ctx.cfg
    if ctx.cond is not None and "adaln" in p:
        s1, c1, g1, s2, c2, g2 = adaln.adaln_mods(p["adaln"], ctx.cond,
                                                  cfg.d_model, 6)
    else:
        s1 = c1 = g1 = s2 = c2 = g2 = None
    dims = ctx.dims()
    S = hc.shape[1]
    pos = ctx.positions if ctx.positions is not None else jnp.arange(S)

    # self-attention (two-pass)
    xc = L.apply_norm(p["ln1"], hc, cfg.norm)
    xn = adaln.modulate(L.apply_norm(p["ln1"], hn, cfg.norm), s1, c1)
    qc, kc, vc = A.project_qkv(p["attn"], xc, dims)
    qn, kn, vn = A.project_qkv(p["attn"], xn, dims)
    oc = A.attend(qc, kc, vc, mask_mod=A.causal_mask, qpos=pos, kpos=pos,
                  impl=ctx.impl)
    k_cat = jnp.concatenate([kc, kn], axis=1)
    v_cat = jnp.concatenate([vc, vn], axis=1)
    on = A.attend(qn, k_cat, v_cat, mask_mod=C.two_pass_mask(S), qpos=pos,
                  kpos=jnp.concatenate([pos, pos + S]), impl=ctx.impl)
    proj = lambda o: o.reshape(*o.shape[:2], dims.n_heads * dims.head_dim) \
        @ p["attn"]["wo"].astype(o.dtype)
    hc = hc + proj(oc)
    hn = adaln.gate(hn, proj(on), g1)

    # cross-attention: both streams attend encoder memory
    for is_clean in (True, False):
        h = hc if is_clean else hn
        x = L.apply_norm(p["lnx"], h, cfg.norm)
        out, _ = _cross_attn(p["xattn"], x, ctx, None)
        if is_clean:
            hc = hc + out
        else:
            hn = hn + out

    # MLP
    xc = L.apply_norm(p["ln2"], hc, cfg.norm)
    xn = adaln.modulate(L.apply_norm(p["ln2"], hn, cfg.norm), s2, c2)
    hc = hc + L.apply_mlp(p["mlp"], xc, cfg.mlp)
    hn = adaln.gate(hn, L.apply_mlp(p["mlp"], xn, cfg.mlp), g2)
    return hc, hn


@register(AUDIO)
class EncDecModel(BaseModel):
    @property
    def n_units(self) -> int:
        return self.cfg.n_layers           # decoder layers

    @property
    def kv_carries_all_state(self) -> bool:
        # decoder sequence history is all in paged self-attn KV; the cross
        # (encoder) block is per-request conditioning, as for VLM
        return True

    def build_spec(self):
        cfg = self.cfg
        db = self.db is not None
        spec = self.common_spec()
        # encoder: bidirectional standard transformer layers (never DB-cond)
        import dataclasses as _dc
        enc_cfg = _dc.replace(cfg, sliding_window=None)
        enc_layer = C.tlayer_spec(enc_cfg, db=False)
        spec["encoder"] = stack_specs(enc_layer, cfg.n_encoder_layers)
        spec["enc_norm"] = L.norm_spec(cfg.d_model, cfg.norm)
        spec["layers"] = stack_specs(dlayer_spec(cfg, db), cfg.n_layers)
        return spec

    def encode(self, params, audio_embs, ctx):
        """audio_embs: (B, n_frames, d) stubbed frame embeddings."""
        S = audio_embs.shape[1]
        h = audio_embs + L.sinusoidal_positions(
            S, self.cfg.d_model).astype(audio_embs.dtype)
        import dataclasses as _dc
        ectx = _dc.replace(ctx, mode="train", mask_mod=A.bidirectional_mask,
                           positions=jnp.arange(S), rope_positions=None,
                           cond=None, kv_x=None)

        def step(carry, p):
            h, _ = C.tlayer_apply(p, carry, ectx)[0], None
            return h, None

        h, _ = uscan(step, h, params["encoder"])
        return L.apply_norm(params["enc_norm"], h, self.cfg.norm)

    def embed(self, params, tokens, dtype=None, positions=None):
        h = super().embed(params, tokens, dtype)
        # whisper decoder: learned/sinusoidal absolute positions (no rope).
        # ``positions`` carries each slot's true offsets on the serving
        # paths (per-token decode commits, chunked prefill) so ragged
        # batches embed at their own absolute positions.
        if positions is None:
            pos = L.sinusoidal_positions(h.shape[1], self.cfg.d_model)
        else:
            pos = L.sinusoidal_at(positions, self.cfg.d_model)
        return h + pos.astype(h.dtype)

    def apply_units(self, params, h, start, size, ctx, cache=None,
                    reset_mask=None):
        lp = _scan_slice(params["layers"], start, size)
        zero = jnp.zeros((), jnp.float32)

        if cache is None:
            assert reset_mask is None
            def step_nc(carry, p):
                h, nc = dlayer_apply(p, carry, ctx, None)
                return h, nc
            h, caches = uscan(step_nc, h, lp)
            return h, caches if ctx.mode == "prefill" else None, zero

        h0 = h

        def step(carry, xs):
            if reset_mask is None:
                p, c = xs
                h = carry
            else:
                p, c, rflag = xs
                h = jnp.where(rflag, h0, carry)
            h, nc = dlayer_apply(p, h, ctx, c)
            return h, nc

        xs = (lp, cache) if reset_mask is None else (lp, cache, reset_mask)
        h, new_cache = uscan(step, h, xs)
        return h, new_cache, zero

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        lp = _scan_slice(params["layers"], start, size)

        def step(carry, p):
            hc, hn = carry
            hc, hn = dlayer_two_pass(p, hc, hn, ctx)
            return (hc, hn), None

        (h_clean, h_noisy), _ = uscan(step, (h_clean, h_noisy), lp)
        return h_clean, h_noisy, jnp.zeros((), jnp.float32)

    def init_cache(self, batch, cache_len, dtype=jnp.bfloat16, start=0,
                   size=None):
        size = self.n_units if size is None else size
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        self_one = A.init_kv_cache(batch, cache_len, dims, dtype)
        cross_one = A.init_kv_cache(batch, cfg.n_audio_frames, dims, dtype)
        bc = lambda x: jnp.broadcast_to(x[None], (size,) + x.shape)
        return {"self": jax.tree_util.tree_map(bc, self_one),
                "cross": jax.tree_util.tree_map(bc, cross_one)}

    def init_paged_cache(self, num_slots, n_pages, page_size, policy=None):
        """Decoder self-attention KV is paged; the cross (encoder) cache is a
        fixed per-slot block whose length never grows during decode."""
        pol = precision_mod.get_policy(policy)
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        self_one = KVC.init_paged_kv(n_pages, page_size, dims, pol.kv)
        # cross conditioning blocks are dense (no per-page scales): under an
        # int8 paged policy they stay in the compute dtype
        cross_one = A.init_kv_cache(num_slots, cfg.n_audio_frames, dims,
                                    pol.kv_dense)
        bc = lambda x: jnp.broadcast_to(x[None], (self.n_units,) + x.shape)
        return {"self": jax.tree_util.tree_map(bc, self_one),
                "cross": jax.tree_util.tree_map(bc, cross_one)}

    def reset_paged_slots(self, cache, slot_mask):
        # cross (encoder) blocks are (units, B, frames, ...): batch axis 1
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = A.init_kv_cache(int(slot_mask.shape[0]), cfg.n_audio_frames,
                              dims, jnp.float32)
        init = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.n_units,) + x.shape),
            one)
        return dict(cache, cross=KVC.reset_slots(cache["cross"], init,
                                                 slot_mask, 1))

    @property
    def paged_state_axes(self) -> dict:
        # cross (encoder) blocks are (units, B, frames, ...): batch axis 1
        return {"cross": 1}

    # ---- conditioning (stubbed mel/conv frontend + real encoder stack) ---
    @property
    def max_cond_tokens(self) -> int:
        return self.cfg.n_audio_frames

    def aux_input_specs(self, batch, dtype=jnp.bfloat16):
        return {"audio_embs": jax.ShapeDtypeStruct(
            (batch, self.cfg.n_audio_frames, self.cfg.d_model), dtype)}

    @property
    def cond_padding_safe(self) -> bool:
        return False      # bidirectional encoder: padded frames leak in

    def encode_conditioning(self, params, aux_inputs, ctx=None):
        if not aux_inputs or "audio_embs" not in aux_inputs:
            return None
        if ctx is None:
            ctx = C.LayerCtx(cfg=self.cfg, mode="train")
        return self.encode(params, aux_inputs["audio_embs"], ctx)

    def set_conditioning(self, params, cache, cond, slot=None):
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        cross = C.write_cross_block(cache["cross"], params["layers"]["xattn"],
                                    cond, dims, cfg.n_audio_frames, slot)
        return dict(cache, cross=cross)
