"""xLSTM model: units of (sLSTM, mLSTM) block pairs with pre-norm residuals.

Conditioning posture (serving): no aux inputs — inherits the base
conditioning API (``max_cond_tokens == 0``; conditioned ``submit`` raises),
and ``kv_carries_all_state`` stays False (per-slot recurrent state is not
paged), so the shared-prefix page cache remains disabled for this family
regardless of conditioning fingerprints.
"""
from __future__ import annotations

import jax
from repro.nn.scan_util import uscan
import jax.numpy as jnp

from repro.configs.base import SSM
from repro.models import common as C
from repro.models.model_api import BaseModel, register
from repro.nn import adaln
from repro.nn import layers as L
from repro.nn import xlstm as X
from repro.nn.init import stack_specs


def _scan_slice(params, start, size):
    return jax.tree_util.tree_map(lambda p: p[start:start + size], params)


def _block_spec(cfg, db: bool, kind: str):
    spec = {"ln": L.norm_spec(cfg.d_model, cfg.norm)}
    if kind == "slstm":
        spec["cell"] = X.slstm_spec(cfg.d_model, cfg.n_heads, cfg.xlstm)
    else:
        spec["cell"] = X.mlstm_spec(cfg.d_model, cfg.n_heads, cfg.xlstm)
    if db:
        spec["adaln"] = adaln.adaln_spec(cfg.d_model, n_mods=3)
    return spec


def _mods3(p, ctx):
    if ctx.cond is not None and "adaln" in p:
        return adaln.adaln_mods(p["adaln"], ctx.cond, ctx.cfg.d_model, 3)
    return (None, None, None)


def _block_apply(p, h, ctx, kind: str, state=None):
    cfg = ctx.cfg
    s, c, g = _mods3(p, ctx)
    x = adaln.modulate(L.apply_norm(p["ln"], h, cfg.norm), s, c)
    step = X.slstm_decode_step if kind == "slstm" else X.mlstm_decode_step
    if ctx.mode == "prefill_chunk":
        y, new_state = C.chunk_token_scan(
            lambda xt, st: step(p["cell"], xt, cfg.n_heads, cfg.xlstm, st),
            x, state, ctx.n_valid)
    elif kind == "slstm":
        if ctx.mode == "decode":
            y, new_state = X.slstm_decode_step(p["cell"], x, cfg.n_heads,
                                               cfg.xlstm, state)
        else:
            y, new_state = X.slstm_fwd(p["cell"], x, cfg.n_heads, cfg.xlstm)
    else:
        if ctx.mode == "decode":
            y, new_state = X.mlstm_decode_step(p["cell"], x, cfg.n_heads,
                                               cfg.xlstm, state)
        else:
            y, new_state = X.mlstm_fwd(p["cell"], x, cfg.n_heads, cfg.xlstm,
                                       return_state=ctx.mode == "prefill")
    if ctx.mode == "decode":
        if not ctx.commit:          # denoise probe: never advance the state
            new_state = state
        else:                       # ragged batches: inactive slots hold
            new_state = C.masked_state_update(new_state, state, ctx.active)
    keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
    return adaln.gate(h, y, g), (new_state if keep else None)


def _block_two_pass(p, hc, hn, ctx, kind: str):
    cfg = ctx.cfg
    s, c, g = _mods3(p, ctx)
    xc = L.apply_norm(p["ln"], hc, cfg.norm)
    xn = adaln.modulate(L.apply_norm(p["ln"], hn, cfg.norm), s, c)
    if kind == "slstm":
        yc, yn = X.slstm_two_pass(p["cell"], xc, xn, cfg.n_heads, cfg.xlstm)
    else:
        yc, yn = X.mlstm_two_pass(p["cell"], xc, xn, cfg.n_heads, cfg.xlstm)
    return hc + yc, adaln.gate(hn, yn, g)


@register(SSM)
class XLSTMModel(BaseModel):
    @property
    def n_units(self) -> int:
        return self.cfg.n_layers // 2      # (sLSTM, mLSTM) pairs

    def build_spec(self):
        db = self.db is not None
        spec = self.common_spec()
        spec["units"] = {
            "slstm": stack_specs(_block_spec(self.cfg, db, "slstm"),
                                 self.n_units),
            "mlstm": stack_specs(_block_spec(self.cfg, db, "mlstm"),
                                 self.n_units),
        }
        return spec

    def apply_units(self, params, h, start, size, ctx, cache=None,
                    reset_mask=None):
        up = _scan_slice(params["units"], start, size)
        zero = jnp.zeros((), jnp.float32)
        h0 = h

        def unit(carry, xs):
            h, aux = carry
            if reset_mask is not None:
                xs, rflag = xs
                h = jnp.where(rflag, h0, h)
            if cache is None:
                p, c = xs, {"slstm": None, "mlstm": None}
            else:
                p, c = xs
            h, s_new = _block_apply(p["slstm"], h, ctx, "slstm", c["slstm"])
            h, m_new = _block_apply(p["mlstm"], h, ctx, "mlstm", c["mlstm"])
            return (h, aux), {"slstm": s_new, "mlstm": m_new}

        xs = up if cache is None else (up, cache)
        if reset_mask is not None:
            xs = (xs, reset_mask)
        (h, aux), new_cache = uscan(unit, (h, zero), xs)
        keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
        return h, new_cache if keep else None, aux

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        up = _scan_slice(params["units"], start, size)
        zero = jnp.zeros((), jnp.float32)

        def unit(carry, p):
            hc, hn, aux = carry
            hc, hn = _block_two_pass(p["slstm"], hc, hn, ctx, "slstm")
            hc, hn = _block_two_pass(p["mlstm"], hc, hn, ctx, "mlstm")
            return (hc, hn, aux), None

        (h_clean, h_noisy, aux), _ = uscan(
            unit, (h_clean, h_noisy, zero), up)
        return h_clean, h_noisy, aux

    def init_cache(self, batch, cache_len, dtype=jnp.bfloat16, start=0,
                   size=None):
        size = self.n_units if size is None else size
        cfg = self.cfg
        d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
        s_one = X.slstm_init_state(batch, cfg.n_heads, cfg.d_model)
        m_one = X.mlstm_init_state(batch, cfg.n_heads, d_in)
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        return {
            "slstm": jax.tree_util.tree_map(lambda x: bc(x, size), s_one),
            "mlstm": jax.tree_util.tree_map(lambda x: bc(x, size), m_one),
        }

    def init_paged_cache(self, num_slots, n_pages, page_size, policy=None):
        """xLSTM decode state is O(1) per slot — there is nothing to page.
        The engine's per-slot lengths / active masks still apply (ragged
        batches and continuous batching work); pages are simply unused.
        The precision policy is deliberately NOT threaded here: the state
        constructors pin fp32 (max-stabilizer recurrences), matching the
        policy's fp32-family override for SSM."""
        return self.init_cache(num_slots, page_size)

    def reset_paged_slots(self, cache, slot_mask):
        # state leaves are (units, B, ...): batch axis 1
        from repro.nn import cache as KVC
        init = self.init_cache(int(slot_mask.shape[0]), 1)
        return KVC.reset_slots(cache, init, slot_mask, 1)

    @property
    def paged_state_axes(self) -> dict:
        # state leaves are (units, B, ...): batch axis 1
        return {"slstm": 1, "mlstm": 1}
