from repro.models.model_api import BaseModel, build_model
from repro.models.common import LayerCtx
