"""Shared layer machinery for all architecture families.

``LayerCtx`` threads everything a layer needs through scans: conditioning
(AdaLN mods from σ), positions, mask construction, KV caches, execution mode.

Modes:
  train      — full sequence, causal (+SWA) mask
  prefill    — like train, additionally returns KV/state caches
  decode     — one token + cache
  prefill_chunk — C prompt tokens + PAGED cache: attention layers append the
               whole chunk's K/V to pool pages and attend [history ||
               intra-chunk causal] in one shot (``cache.paged_prefill_
               attention`` / the flash-prefill kernel); recurrent layers
               advance their state over the chunk with one in-dispatch scan.
               Per-slot ``ctx.n_valid`` bounds real tokens (ragged tails
               write to the trash page / hold recurrent state)
  db_concat  — DB AR training, [clean || noisy] single stream, custom mask
               (paper App. E.4 concat variant; attention layers only)
  db_two_pass— DB AR training, paired (clean, noisy) streams; noisy stream is
               denoised against the clean prefix state (works for SSM too)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs.base import ModelConfig
from repro.nn import layers as L
from repro.nn import attention as A
from repro.nn import adaln
from repro.nn import cache as KVC
from repro.nn.init import ParamSpec
from repro.nn.moe import moe_fwd, moe_spec


@dataclasses.dataclass
class LayerCtx:
    cfg: ModelConfig
    mode: str = "train"
    positions: Optional[jax.Array] = None       # mask positions (S,)
    rope_positions: Optional[jax.Array] = None  # rope phases (S,)
    mask_mod: Optional[Callable] = None
    cond: Optional[jax.Array] = None            # (B, d) sigma embedding, or None
    cond_mask: Optional[jax.Array] = None       # (S,) bool: where AdaLN applies
    pos: Any = None                             # decode: scalar position
    kv_x: Optional[jax.Array] = None            # cross-attn memory (B, Sk, d)
    kv_positions: Optional[jax.Array] = None
    impl: str = "auto"                          # attention impl
    precision: Any = None                       # repro.precision.Policy | None
    # ---- paged serving decode (repro.nn.cache) ----
    lengths: Optional[jax.Array] = None         # (B,) committed tokens / slot
    page_table: Optional[jax.Array] = None      # (B, n_logical_pages) int32
    active: Optional[jax.Array] = None          # (B,) bool: slots that commit
    n_valid: Optional[jax.Array] = None         # (B,) prefill_chunk: real toks
    cond_lengths: Optional[jax.Array] = None    # (B,) valid conditioning toks
    #   per-slot length of the cross-attention (image/audio) memory block;
    #   0 = unconditioned slot (cross contributes exactly zero). None keeps
    #   the legacy unmasked read (dense caches sized to the true length).
    commit: bool = True                         # False = denoise probe (no append)
    q_chunk: int = dataclasses.field(default_factory=lambda: runtime.attn_chunk())
    kv_chunk: int = dataclasses.field(default_factory=lambda: runtime.attn_chunk())

    def dims(self) -> A.AttnDims:
        c = self.cfg
        return A.AttnDims(c.n_heads, c.n_kv_heads, c.head_dim, c.rope_theta)


def chunk_token_scan(step_fn, x, state, n_valid):
    """Advance a RECURRENT layer over a prefill chunk inside ONE dispatch.

    Attention layers ingest a chunk as one sequence-level call; recurrences
    (mamba / xLSTM) are inherently serial per token, so they advance with a
    ``lax.scan`` over the chunk's tokens instead — still killing the
    per-token dispatch, and numerically IDENTICAL to the per-token prefill
    (same decode-step math, same masked holds). ``step_fn(x_t (B,1,d),
    state) -> (y_t (B,1,d), new_state)``; slots whose valid tokens ran out
    (t >= n_valid[b]) hold their state. Returns (y (B,C,d), final_state)."""
    from repro.nn.scan_util import uscan
    S_c = x.shape[1]
    acts = jnp.arange(S_c)[:, None] < n_valid[None, :]      # (C, B)

    def tok(st, xs):
        xt, act = xs
        y_t, ns = step_fn(xt[:, None], st)
        return masked_state_update(ns, st, act), y_t[:, 0]

    new_state, ys = uscan(tok, state, (x.transpose(1, 0, 2), acts))
    return ys.transpose(1, 0, 2), new_state


def masked_state_update(new_state, old_state, active: Optional[jax.Array]):
    """Per-slot recurrent-state commit mask for ragged / continuous batching:
    inactive slots keep their old state. Leaves are (B, ...)-leading at the
    point of update (inside the unit scan). Attention KV needs no such mask —
    the paged append already redirects inactive writes to the trash page."""
    if active is None or old_state is None:
        return new_state
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)),
                               n, o), new_state, old_state)


def cross_cached_attn(p, x, ctx: LayerCtx, cache):
    """Cross-attention over a PRECOMPUTED per-slot (k, v) conditioning block
    (decode / prefill_chunk: the memory was projected once at prefill or at
    engine admission — re-encoding per step would be wasted). One code path
    for every conditioned family (VLM image blocks, encdec audio blocks).

    With ``ctx.cond_lengths`` the block is attended under a per-slot valid
    length (``cache.cross_attend``): the paged engine keeps one fixed-size
    block per slot and admits RAGGED conditioning, including length-0
    (unconditioned) slots in the same compiled program. Without it, the
    legacy unmasked read serves dense caches sized to the true length."""
    dims = ctx.dims()
    q, _, _ = A.project_qkv(p, x, dims)
    if ctx.cond_lengths is not None:
        out = KVC.cross_attend(q, cache["k"].astype(x.dtype),
                               cache["v"].astype(x.dtype), ctx.cond_lengths)
    else:
        out = A.attend(q, cache["k"].astype(x.dtype),
                       cache["v"].astype(x.dtype), mask_mod=None,
                       qpos=jnp.zeros((x.shape[1],), jnp.int32),
                       kpos=jnp.arange(cache["k"].shape[1]), impl="naive")
    out = out.reshape(*x.shape[:2], dims.n_heads * dims.head_dim)
    return out @ p["wo"].astype(x.dtype)


def project_cross_kv(p, cond, dims):
    """Project conditioning embeddings (B, Sk, d) into a cross block's
    (k, v) — the admission-time half of ``cross_cached_attn``, the same math
    ``attention.project_qkv`` applies to ``kv_x`` at dense prefill (the q
    projection is skipped: queries come from the text stream per step)."""
    B, Sk, _ = cond.shape
    k = cond @ p["wk"].astype(cond.dtype)
    v = cond @ p["wv"].astype(cond.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(cond.dtype)
        v = v + p["bv"].astype(cond.dtype)
    k = k.reshape(B, Sk, dims.n_kv_heads, dims.head_dim)
    v = v.reshape(B, Sk, dims.n_kv_heads, dims.head_dim)
    return k, v


def write_cross_block(cross_cache, cross_params, cond, dims, block: int,
                      slot=None):
    """Write projected conditioning into per-slot cross blocks.

    cross_cache: {"k", "v"} with leaves (n_units, num_slots, block, KV, hd);
    cross_params: the stacked per-unit cross-attention params (leading
    n_units axis); cond: (B, Sk, d), zero-padded here to the fixed ``block``
    capacity so ONE compiled program serves every conditioning length.
    ``slot=None`` requires B == num_slots and overwrites every slot's block;
    an int32 ``slot`` (traced is fine) overwrites one slot's block, B == 1.
    The full block is always written, so a recycled slot can never observe a
    previous occupant's tail."""
    Sk = cond.shape[1]
    assert Sk <= block, f"conditioning length {Sk} exceeds block {block}"
    if Sk < block:
        cond = jnp.pad(cond, ((0, 0), (0, block - Sk), (0, 0)))
    k, v = jax.vmap(lambda p: project_cross_kv(p, cond, dims))(cross_params)
    k = k.astype(cross_cache["k"].dtype)       # (units, B, block, KV, hd)
    v = v.astype(cross_cache["v"].dtype)
    if slot is None:
        assert k.shape == cross_cache["k"].shape, (
            f"set_conditioning(slot=None) writes ALL slots: cond batch "
            f"{cond.shape[0]} != num_slots {cross_cache['k'].shape[1]}")
        return {"k": k, "v": v}
    start = (jnp.zeros((), jnp.int32), jnp.asarray(slot, jnp.int32)) + \
        (jnp.zeros((), jnp.int32),) * 3
    return {"k": jax.lax.dynamic_update_slice(cross_cache["k"], k, start),
            "v": jax.lax.dynamic_update_slice(cross_cache["v"], v, start)}


def default_mask(cfg: ModelConfig, bidirectional: bool = False):
    if bidirectional:
        return A.bidirectional_mask
    if cfg.sliding_window:
        return A.sliding_window_mask(cfg.sliding_window)
    return A.causal_mask


# ---------------------------------------------------------------------------
# Standard transformer layer (attention + MLP/MoE), with optional AdaLN
# ---------------------------------------------------------------------------

def tlayer_spec(cfg: ModelConfig, db: bool, *, cross: bool = False,
                moe_layer: bool = False):
    d = cfg.d_model
    dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.rope_theta)
    spec = {
        "ln1": L.norm_spec(d, cfg.norm),
        "attn": A.attention_spec(d, dims, cfg.qkv_bias),
        "ln2": L.norm_spec(d, cfg.norm),
    }
    if moe_layer:
        assert cfg.moe is not None
        spec["moe"] = moe_spec(d, cfg.d_ff, cfg.moe, cfg.mlp)
    else:
        spec["mlp"] = L.mlp_spec(d, cfg.d_ff, cfg.mlp)
    if db:
        spec["adaln"] = adaln.adaln_spec(d, n_mods=6)
    if cross:
        # gate for cross-attn output (llama-3.2-vision style tanh gate)
        spec["xgate"] = ParamSpec((1,), (None,), "zeros")
    return spec


def _mods(params, ctx: LayerCtx):
    if ctx.cond is None or "adaln" not in params:
        return (None,) * 6
    return adaln.adaln_mods(params["adaln"], ctx.cond, ctx.cfg.d_model, 6)


def _norm_modulate(p_ln, h, ctx: LayerCtx, shift, scale, cond_mask):
    """norm → AdaLN modulate; under ``impl="kernels"`` the non-parametric-LN
    case fuses both into one Pallas pass (custom-VJP backward). Parametric
    norms (rmsnorm/layernorm carry a weight the kernel does not apply) and the
    cond-masked concat path keep the jnp composition."""
    if (ctx.impl == "kernels" and shift is not None and cond_mask is None
            and ctx.cfg.norm == "nonparam_ln" and shift.ndim == 3
            and shift.shape[1] == 1):   # (B, 1, d) per-example mods only
        from repro.kernels import ops as kops
        return kops.ln_modulate(h, scale[:, 0], shift[:, 0])
    return adaln.modulate(L.apply_norm(p_ln, h, ctx.cfg.norm), shift, scale,
                          cond_mask)


def tlayer_apply(params, h, ctx: LayerCtx, *, cross: bool = False,
                 moe_layer: bool = False, bidirectional: bool = False,
                 cache=None):
    """Returns (h, new_cache, aux_loss)."""
    cfg = ctx.cfg
    dims = ctx.dims()
    s1, c1, g1, s2, c2, g2 = _mods(params, ctx)
    aux = jnp.zeros((), jnp.float32)
    cm = ctx.cond_mask

    x = _norm_modulate(params["ln1"], h, ctx, s1, c1, cm)
    if ctx.mode in ("decode", "prefill_chunk") and not cross:
        if isinstance(cache, KVC.PagedKV):
            if ctx.mode == "prefill_chunk":
                attn_out, new_cache = KVC.paged_prefill_attention(
                    params["attn"], x, dims, cache, lengths=ctx.lengths,
                    page_table=ctx.page_table, n_valid=ctx.n_valid,
                    window=cfg.sliding_window, impl=ctx.impl)
            else:
                attn_out, new_cache = KVC.paged_decode_attention(
                    params["attn"], x, dims, cache, lengths=ctx.lengths,
                    page_table=ctx.page_table, active=ctx.active,
                    commit=ctx.commit, window=cfg.sliding_window,
                    impl=ctx.impl)
        else:
            if ctx.mode == "prefill_chunk":
                raise NotImplementedError(
                    "prefill_chunk requires the paged cache "
                    "(repro.nn.cache); dense caches prefill per-token")
            attn_out, new_cache = A.decode_attention(
                params["attn"], x, dims, cache, ctx.pos,
                window=cfg.sliding_window, kv_chunk=ctx.kv_chunk,
                impl=ctx.impl)
    elif cross:
        # cross-attention to ctx.kv_x (image/audio memory); cache holds
        # precomputed (k, v) in decode/prefill reuse.
        if cache is not None and ctx.mode in ("decode", "prefill_chunk"):
            attn_out = cross_cached_attn(params["attn"], x, ctx, cache)
            new_cache = cache
        else:
            if ctx.kv_x is None:
                raise ValueError(
                    "cross-attention layer with no conditioning memory: "
                    "pass aux_inputs (image_embs/audio_embs) on the dense "
                    "train/prefill path — the serving engine admits "
                    "unconditioned requests via cond_lengths=0 instead")
            attn_out, (k, v) = A.attention_fwd(
                params["attn"], x, dims, positions=ctx.positions,
                mask_mod=None, kv_x=ctx.kv_x,
                kv_positions=ctx.kv_positions, impl=ctx.impl,
                q_chunk=ctx.q_chunk, kv_chunk=ctx.kv_chunk)
            new_cache = {"k": k, "v": v} if ctx.mode == "prefill" else None
        attn_out = attn_out * jnp.tanh(params["xgate"].astype(attn_out.dtype))
    else:
        mask_mod = ctx.mask_mod or default_mask(cfg, bidirectional)
        attn_out, (k, v) = A.attention_fwd(
            params["attn"], x, dims, positions=ctx.positions,
            mask_mod=mask_mod, rope_positions=ctx.rope_positions,
            impl=ctx.impl, q_chunk=ctx.q_chunk, kv_chunk=ctx.kv_chunk)
        new_cache = {"k": k, "v": v} if ctx.mode == "prefill" else None
    h = adaln.gate(h, attn_out, g1, cm, impl=ctx.impl)

    x = _norm_modulate(params["ln2"], h, ctx, s2, c2, cm)
    if moe_layer:
        mlp_out, aux = moe_fwd(params["moe"], x, cfg.moe, cfg.mlp)
    else:
        mlp_out = L.apply_mlp(params["mlp"], x, cfg.mlp)
    h = adaln.gate(h, mlp_out, g2, cm, impl=ctx.impl)
    return h, new_cache, aux


def two_pass_mask(seq_len: int):
    """Mask for two-pass DB attention: q are the S noisy tokens; keys are
    [clean(0..S-1) || noisy_diag(0..S-1)]. Noisy query i sees clean j < i and
    its own noisy key (position S+i)."""
    S = seq_len

    def mask(qpos, kpos):
        q = qpos[:, None]          # noisy query index i (0..S-1)
        k = kpos[None, :]
        clean = (k < S) & (k < q)
        self_k = k == q + S
        return clean | self_k
    mask.kernel_mask = ("two_pass", None, S)
    return mask


def tlayer_two_pass(params, h_clean, h_noisy, ctx: LayerCtx, *,
                    moe_layer: bool = False):
    """DB two-pass for an attention layer: clean stream runs standard causal;
    noisy stream attends clean past + own noisy kv. Returns (clean, noisy, aux)."""
    cfg = ctx.cfg
    dims = ctx.dims()
    S = h_clean.shape[1]
    s1, c1, g1, s2, c2, g2 = _mods(params, ctx)
    aux = jnp.zeros((), jnp.float32)

    # --- attention ---
    xc = L.apply_norm(params["ln1"], h_clean, cfg.norm)          # clean: no mods
    xn = _norm_modulate(params["ln1"], h_noisy, ctx, s1, c1, None)
    qc, kc, vc = A.project_qkv(params["attn"], xc, dims)
    qn, kn, vn = A.project_qkv(params["attn"], xn, dims)
    pos = ctx.positions if ctx.positions is not None else jnp.arange(S)
    qc = L.apply_rope(qc, pos, dims.rope_theta)
    kc = L.apply_rope(kc, pos, dims.rope_theta)
    qn = L.apply_rope(qn, pos, dims.rope_theta)
    kn = L.apply_rope(kn, pos, dims.rope_theta)
    base_mask = ctx.mask_mod or default_mask(cfg, False)
    oc = A.attend(qc, kc, vc, mask_mod=base_mask, qpos=pos, kpos=pos,
                  impl=ctx.impl, q_chunk=ctx.q_chunk, kv_chunk=ctx.kv_chunk)
    k_cat = jnp.concatenate([kc, kn], axis=1)
    v_cat = jnp.concatenate([vc, vn], axis=1)
    kpos_cat = jnp.concatenate([pos, pos + S])
    on = A.attend(qn, k_cat, v_cat, mask_mod=two_pass_mask(S), qpos=pos,
                  kpos=kpos_cat, impl=ctx.impl, q_chunk=ctx.q_chunk,
                  kv_chunk=ctx.kv_chunk)
    proj = lambda o: o.reshape(*o.shape[:2], dims.n_heads * dims.head_dim) \
        @ params["attn"]["wo"].astype(o.dtype)
    h_clean = h_clean + proj(oc)
    h_noisy = adaln.gate(h_noisy, proj(on), g1, impl=ctx.impl)

    # --- mlp ---
    xc = L.apply_norm(params["ln2"], h_clean, cfg.norm)
    xn = _norm_modulate(params["ln2"], h_noisy, ctx, s2, c2, None)
    if moe_layer:
        mc, aux1 = moe_fwd(params["moe"], xc, cfg.moe, cfg.mlp)
        mn, aux2 = moe_fwd(params["moe"], xn, cfg.moe, cfg.mlp)
        aux = aux1 + aux2
    else:
        mc = L.apply_mlp(params["mlp"], xc, cfg.mlp)
        mn = L.apply_mlp(params["mlp"], xn, cfg.mlp)
    h_clean = h_clean + mc
    h_noisy = adaln.gate(h_noisy, mn, g2, impl=ctx.impl)
    return h_clean, h_noisy, aux
