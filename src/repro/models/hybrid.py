"""Zamba2-style hybrid model: Mamba2 backbone with a SHARED-weight attention
(+MLP) block applied after every ``attn_every`` mamba layers.

Unit = superblock of ``attn_every`` mamba layers + one application of the
shared attention block. The shared block's weights are the same for every
unit (closure constants under the unit scan) but each application keeps its
own KV cache.

Conditioning posture (serving): no aux inputs — the family inherits the
base conditioning API (``max_cond_tokens == 0``), so
``ContinuousBatcher.submit(..., aux_inputs=...)`` rejects conditioned
requests loudly, and ``kv_carries_all_state`` stays False (the mamba
recurrence is per-slot O(1) state, not paged), which keeps the shared-
prefix page cache disabled for this family regardless of fingerprinting.
"""
from __future__ import annotations


import jax
from repro.nn.scan_util import uscan
import jax.numpy as jnp

from repro import precision as precision_mod
from repro.configs.base import HYBRID
from repro.models import common as C
from repro.models.model_api import BaseModel, register
from repro.nn import adaln
from repro.nn import attention as A
from repro.nn import cache as KVC
from repro.nn import layers as L
from repro.nn import ssm as SSM
from repro.nn.init import stack_specs


def _scan_slice(params, start, size):
    return jax.tree_util.tree_map(lambda p: p[start:start + size], params)


def mamba_layer_spec(cfg, db: bool):
    spec = {
        "ln": L.norm_spec(cfg.d_model, cfg.norm),
        "mixer": SSM.mamba2_spec(cfg.d_model, cfg.ssm),
    }
    if db:
        spec["adaln"] = adaln.adaln_spec(cfg.d_model, n_mods=3)
    return spec


def mamba_layer_apply(p, h, ctx, state=None):
    cfg = ctx.cfg
    if ctx.cond is not None and "adaln" in p:
        s, c, g = adaln.adaln_mods(p["adaln"], ctx.cond, cfg.d_model, 3)
    else:
        s = c = g = None
    x = adaln.modulate(L.apply_norm(p["ln"], h, cfg.norm), s, c)
    if ctx.mode == "decode":
        y, new_state = SSM.mamba2_decode_step(p["mixer"], x, cfg.ssm,
                                              cfg.d_model, state)
        if not ctx.commit:          # denoise probe: never advance the state
            new_state = state
        else:                       # ragged batches: inactive slots hold
            new_state = C.masked_state_update(new_state, state, ctx.active)
    elif ctx.mode == "prefill_chunk":
        y, new_state = C.chunk_token_scan(
            lambda xt, st: SSM.mamba2_decode_step(p["mixer"], xt, cfg.ssm,
                                                  cfg.d_model, st),
            x, state, ctx.n_valid)
    else:
        y, new_state = SSM.mamba2_fwd(p["mixer"], x, cfg.ssm, cfg.d_model,
                                      state if ctx.mode == "decode" else None)
    keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
    return adaln.gate(h, y, g), (new_state if keep else None)


def mamba_layer_two_pass(p, hc, hn, ctx):
    cfg = ctx.cfg
    if ctx.cond is not None and "adaln" in p:
        s, c, g = adaln.adaln_mods(p["adaln"], ctx.cond, cfg.d_model, 3)
    else:
        s = c = g = None
    xc = L.apply_norm(p["ln"], hc, cfg.norm)
    xn = adaln.modulate(L.apply_norm(p["ln"], hn, cfg.norm), s, c)
    yc, yn = SSM.mamba2_two_pass(p["mixer"], xc, xn, cfg.ssm, cfg.d_model)
    return hc + yc, adaln.gate(hn, yn, g)


@register(HYBRID)
class HybridModel(BaseModel):
    @property
    def inner(self) -> int:
        return self.cfg.attn_every

    @property
    def n_units(self) -> int:
        return self.cfg.n_layers // self.inner

    def build_spec(self):
        db = self.db is not None
        spec = self.common_spec()
        m = mamba_layer_spec(self.cfg, db)
        spec["units"] = {"mamba": stack_specs(
            stack_specs(m, self.inner, "inner"), self.n_units)}
        spec["shared"] = C.tlayer_spec(self.cfg, db)   # shared attention block
        return spec

    def apply_units(self, params, h, start, size, ctx, cache=None,
                    reset_mask=None):
        up = _scan_slice(params["units"], start, size)
        shared = params["shared"]
        zero = jnp.zeros((), jnp.float32)
        h0 = h

        def unit(carry, xs):
            h, aux = carry
            if reset_mask is not None:
                xs, rflag = xs
                h = jnp.where(rflag, h0, h)
            if cache is None:
                p, c = xs, None
            else:
                p, c = xs

            def inner(carry2, xs2):
                h2 = carry2
                if c is None:
                    p2, st2 = xs2, None
                else:
                    p2, st2 = xs2
                h2, new_st = mamba_layer_apply(p2, h2, ctx, st2)
                return h2, new_st

            inner_xs = p["mamba"] if c is None else (p["mamba"], c["mamba"])
            h, new_states = uscan(inner, h, inner_xs)
            h, new_kv, a = C.tlayer_apply(
                shared, h, ctx, cache=None if c is None else c["shared_kv"])
            new_c = {"mamba": new_states, "shared_kv": new_kv}
            return (h, aux + a), new_c

        xs = up if cache is None else (up, cache)
        if reset_mask is not None:
            xs = (xs, reset_mask)
        (h, aux), new_cache = uscan(unit, (h, zero), xs)
        keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
        return h, new_cache if keep else None, aux

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        up = _scan_slice(params["units"], start, size)
        shared = params["shared"]
        zero = jnp.zeros((), jnp.float32)

        def unit(carry, p):
            hc, hn, aux = carry

            def inner(carry2, p2):
                hc2, hn2 = carry2
                hc2, hn2 = mamba_layer_two_pass(p2, hc2, hn2, ctx)
                return (hc2, hn2), None

            (hc, hn), _ = uscan(inner, (hc, hn), p["mamba"])
            hc, hn, a = C.tlayer_two_pass(shared, hc, hn, ctx)
            return (hc, hn, aux + a), None

        (h_clean, h_noisy, aux), _ = uscan(
            unit, (h_clean, h_noisy, zero), up)
        return h_clean, h_noisy, aux

    def cache_batch(self, cache) -> int:
        return cache["shared_kv"]["k"].shape[1]

    def init_cache(self, batch, cache_len, dtype=jnp.bfloat16, start=0,
                   size=None):
        size = self.n_units if size is None else size
        cfg = self.cfg
        clen = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        kv_one = A.init_kv_cache(batch, clen, dims, dtype)
        m_one = SSM.mamba2_init_state(batch, cfg.ssm, cfg.d_model, dtype)
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda x: bc(bc(x, self.inner), size), m_one),
            "shared_kv": jax.tree_util.tree_map(lambda x: bc(x, size), kv_one),
        }

    def reset_paged_slots(self, cache, slot_mask):
        # mamba state leaves are (units, inner, B, ...): batch axis 2
        cfg = self.cfg
        m_one = SSM.mamba2_init_state(int(slot_mask.shape[0]), cfg.ssm,
                                      cfg.d_model, jnp.float32)
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        init = jax.tree_util.tree_map(
            lambda x: bc(bc(x, self.inner), self.n_units), m_one)
        return dict(cache, mamba=KVC.reset_slots(cache["mamba"], init,
                                                 slot_mask, 2))

    @property
    def paged_state_axes(self) -> dict:
        # mamba state leaves are (units, inner, B, ...): batch axis 2
        return {"mamba": 2}

    def init_paged_cache(self, num_slots, n_pages, page_size, policy=None):
        """Shared-attention KV is paged (bf16 under the serving policy); the
        mamba states are O(1) per slot and follow the family's fp32-state
        precision override (compounded rounding over the recurrence)."""
        pol = precision_mod.get_policy(policy)
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        kv_one = KVC.init_paged_kv(n_pages, page_size, dims, pol.kv)
        m_one = SSM.mamba2_init_state(num_slots, cfg.ssm, cfg.d_model,
                                      pol.state_for(HYBRID))
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda x: bc(bc(x, self.inner), self.n_units), m_one),
            "shared_kv": jax.tree_util.tree_map(
                lambda x: bc(x, self.n_units), kv_one),
        }
