"""Dense / MoE decoder and VLM (cross-attn superblock) models."""
from __future__ import annotations


import jax
from repro.nn.scan_util import uscan
import jax.numpy as jnp

from repro import precision as precision_mod
from repro.configs.base import DENSE, MOE, VLM
from repro.models import common as C
from repro.models.model_api import BaseModel, register
from repro.nn import attention as A
from repro.nn import cache as KVC
from repro.nn.init import stack_specs


def _scan_slice(params, start, size):
    return jax.tree_util.tree_map(lambda p: p[start:start + size], params)


@register(DENSE)
@register(MOE)
class DecoderModel(BaseModel):
    """Standard decoder stack; every layer is MoE for the moe family."""

    @property
    def n_units(self) -> int:
        return self.cfg.n_layers

    @property
    def is_moe(self) -> bool:
        return self.cfg.family == MOE

    @property
    def kv_carries_all_state(self) -> bool:
        return True

    def build_spec(self):
        layer = C.tlayer_spec(self.cfg, self.db is not None,
                              moe_layer=self.is_moe)
        spec = self.common_spec()
        spec["layers"] = stack_specs(layer, self.cfg.n_layers)
        return spec

    def apply_units(self, params, h, start, size, ctx, cache=None,
                    reset_mask=None):
        lp = _scan_slice(params["layers"], start, size)
        zero = jnp.zeros((), jnp.float32)

        if cache is None:
            assert reset_mask is None
            def step_nc(carry, p):
                h, aux = carry
                h, new_c, a = C.tlayer_apply(p, h, ctx,
                                             moe_layer=self.is_moe, cache=None)
                return (h, aux + a), new_c

            (h, aux), caches = uscan(step_nc, (h, zero), lp)
            return h, caches if ctx.mode == "prefill" else None, aux

        h0 = h   # block-boundary reset value (commit scan: raw embeddings)

        def step(carry, xs):
            h, aux = carry
            if reset_mask is None:
                p, c = xs
            else:
                p, c, rflag = xs
                h = jnp.where(rflag, h0, h)
            h, new_c, a = C.tlayer_apply(p, h, ctx, moe_layer=self.is_moe,
                                         cache=c)
            return (h, aux + a), new_c

        xs = (lp, cache) if reset_mask is None else (lp, cache, reset_mask)
        (h, aux), new_cache = uscan(step, (h, zero), xs)
        return h, new_cache, aux

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        lp = _scan_slice(params["layers"], start, size)

        def step(carry, p):
            hc, hn, aux = carry
            hc, hn, a = C.tlayer_two_pass(p, hc, hn, ctx,
                                          moe_layer=self.is_moe)
            return (hc, hn, aux + a), None

        (h_clean, h_noisy, aux), _ = uscan(
            step, (h_clean, h_noisy, jnp.zeros((), jnp.float32)), lp)
        return h_clean, h_noisy, aux

    def init_cache(self, batch, cache_len, dtype=jnp.bfloat16, start=0,
                   size=None):
        size = self.n_units if size is None else size
        cfg = self.cfg
        clen = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = A.init_kv_cache(batch, clen, dims, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (size,) + x.shape), one)

    def init_paged_cache(self, num_slots, n_pages, page_size, policy=None):
        pol = precision_mod.get_policy(policy)
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = KVC.init_paged_kv(n_pages, page_size, dims, pol.kv)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.n_units,) + x.shape),
            one)


@register(VLM)
class VLMModel(BaseModel):
    """Llama-3.2-Vision-style decoder: superblocks of (k-1) self layers + 1
    gated cross-attention layer to stubbed image patch embeddings."""

    @property
    def k_self(self) -> int:
        return self.cfg.cross_attn_every - 1

    @property
    def n_units(self) -> int:
        return self.cfg.n_layers // self.cfg.cross_attn_every

    @property
    def kv_carries_all_state(self) -> bool:
        # sequence history is all in paged self-attn KV; the cross (image)
        # block is per-request conditioning, not sequence state — sharing is
        # sound for a common TEXT prefix under the same conditioning
        return True

    def build_spec(self):
        db = self.db is not None
        self_layer = C.tlayer_spec(self.cfg, db)
        cross_layer = C.tlayer_spec(self.cfg, db, cross=True)
        spec = self.common_spec()
        spec["units"] = {
            "self": stack_specs(stack_specs(self_layer, self.k_self, "inner"),
                                self.n_units),
            "cross": stack_specs(cross_layer, self.n_units),
        }
        return spec

    def apply_units(self, params, h, start, size, ctx, cache=None,
                    reset_mask=None):
        up = _scan_slice(params["units"], start, size)
        h0 = h

        def unit(carry, xs):
            h, aux = carry
            if reset_mask is not None:
                xs, rflag = xs
                h = jnp.where(rflag, h0, h)
            if cache is None:
                p, c = xs, None
            else:
                p, c = xs

            def inner(carry2, xs2):
                h2, aux2 = carry2
                if c is None:
                    p2, c2 = xs2, None
                else:
                    p2, c2 = xs2
                h2, nc2, a2 = C.tlayer_apply(p2, h2, ctx, cache=c2)
                return (h2, aux2 + a2), nc2

            inner_xs = p["self"] if c is None else (p["self"], c["self"])
            (h, aux), new_self = uscan(inner, (h, aux), inner_xs)
            h, new_cross, a = C.tlayer_apply(
                p["cross"], h, ctx, cross=True,
                cache=None if c is None else c["cross"])
            new_c = {"self": new_self, "cross": new_cross}
            return (h, aux + a), new_c

        xs = up if cache is None else (up, cache)
        if reset_mask is not None:
            xs = (xs, reset_mask)
        (h, aux), new_cache = uscan(
            unit, (h, jnp.zeros((), jnp.float32)), xs)
        keep = ctx.mode in ("prefill", "decode", "prefill_chunk")
        return h, new_cache if keep else None, aux

    def apply_units_two_pass(self, params, h_clean, h_noisy, start, size, ctx):
        up = _scan_slice(params["units"], start, size)

        def unit(carry, p):
            hc, hn, aux = carry

            def inner(carry2, p2):
                hc2, hn2, aux2 = carry2
                hc2, hn2, a2 = C.tlayer_two_pass(p2, hc2, hn2, ctx)
                return (hc2, hn2, aux2 + a2), None

            (hc, hn, aux), _ = uscan(inner, (hc, hn, aux), p["self"])
            # cross-attn: both streams attend the image memory (conditioning)
            hc, _, a1 = C.tlayer_apply(p["cross"], hc, ctx, cross=True)
            hn, _, a2 = C.tlayer_apply(p["cross"], hn, ctx, cross=True)
            return (hc, hn, aux + a1 + a2), None

        (h_clean, h_noisy, aux), _ = uscan(
            unit, (h_clean, h_noisy, jnp.zeros((), jnp.float32)), up)
        return h_clean, h_noisy, aux

    def cache_batch(self, cache) -> int:
        return cache["cross"]["k"].shape[1]

    def init_cache(self, batch, cache_len, dtype=jnp.bfloat16, start=0,
                   size=None):
        size = self.n_units if size is None else size
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = A.init_kv_cache(batch, cache_len, dims, dtype)
        x_one = A.init_kv_cache(batch, cfg.n_image_tokens, dims, dtype)
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        return {
            "self": jax.tree_util.tree_map(
                lambda x: bc(bc(x, self.k_self), size), one),
            "cross": jax.tree_util.tree_map(lambda x: bc(x, size), x_one),
        }

    def init_paged_cache(self, num_slots, n_pages, page_size, policy=None):
        """Self-attention KV is paged; the cross-attention (image) cache is a
        fixed per-slot block — its length never grows during decode."""
        pol = precision_mod.get_policy(policy)
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = KVC.init_paged_kv(n_pages, page_size, dims, pol.kv)
        # cross conditioning blocks are dense (no per-page scales): under an
        # int8 paged policy they stay in the compute dtype
        x_one = A.init_kv_cache(num_slots, cfg.n_image_tokens, dims,
                                pol.kv_dense)
        bc = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
        return {
            "self": jax.tree_util.tree_map(
                lambda x: bc(bc(x, self.k_self), self.n_units), one),
            "cross": jax.tree_util.tree_map(
                lambda x: bc(x, self.n_units), x_one),
        }

    def reset_paged_slots(self, cache, slot_mask):
        # cross (image) blocks are (units, B, n_image_tokens, ...): axis 1
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        one = A.init_kv_cache(int(slot_mask.shape[0]), cfg.n_image_tokens,
                              dims, jnp.float32)
        init = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.n_units,) + x.shape),
            one)
        return dict(cache, cross=KVC.reset_slots(cache["cross"], init,
                                                 slot_mask, 1))

    @property
    def paged_state_axes(self) -> dict:
        # cross (image) blocks are (units, B, n_image_tokens, ...): axis 1
        return {"cross": 1}

    # ---- conditioning (stubbed vision frontend) --------------------------
    @property
    def max_cond_tokens(self) -> int:
        return self.cfg.n_image_tokens

    def aux_input_specs(self, batch, dtype=jnp.bfloat16):
        return {"image_embs": jax.ShapeDtypeStruct(
            (batch, self.cfg.n_image_tokens, self.cfg.d_model), dtype)}

    def encode_conditioning(self, params, aux_inputs, ctx=None):
        if not aux_inputs or "image_embs" not in aux_inputs:
            return None
        return aux_inputs["image_embs"]

    def set_conditioning(self, params, cache, cond, slot=None):
        cfg = self.cfg
        dims = A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.rope_theta)
        cross = C.write_cross_block(cache["cross"],
                                    params["units"]["cross"]["attn"], cond,
                                    dims, cfg.n_image_tokens, slot)
        return dict(cache, cross=cross)
