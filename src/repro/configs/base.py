"""Config dataclasses for the repro framework.

Everything is a frozen dataclass so configs hash/compare cleanly and can be used
as jit static args. ``ModelConfig`` describes an architecture; ``DBConfig``
describes the DiffusionBlocks conversion (the paper's technique);
``ShapeConfig`` describes an assigned input shape; ``MeshConfig`` the target mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
HYBRID = "hybrid"   # mamba2 + shared attention (zamba2)
SSM = "ssm"         # xlstm
AUDIO = "audio"     # whisper enc-dec
VLM = "vlm"         # llama-3.2-vision style cross-attn decoder

ARCH_FAMILIES = (DENSE, MOE, HYBRID, SSM, AUDIO, VLM)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD parameters (used by hybrid family)."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block pattern: alternating sLSTM / mLSTM."""
    slstm_every: int = 2          # layer i is sLSTM if i % slstm_every == 0
    mlstm_qk_dim_factor: float = 0.5
    proj_factor: float = 2.0      # up-projection factor inside mLSTM/sLSTM blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of ARCH_FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""              # citation: paper / model card

    # attention details
    head_dim: Optional[int] = None           # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None     # SWA window (h2o-danube / variants)
    norm: str = "rmsnorm"                    # rmsnorm | layernorm | nonparam_ln (olmo)
    mlp: str = "swiglu"                      # swiglu | gelu
    tie_embeddings: bool = False

    # family-specific
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): attention super-block period: every `attn_every` mamba
    # layers one shared attention block is applied.
    attn_every: int = 0
    # vlm: one cross-attention layer every `cross_attn_every` layers
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # audio (whisper): encoder stack
    n_encoder_layers: int = 0
    n_audio_frames: int = 0

    # shape lowering policy
    supports_long_context: bool = False      # sub-quadratic / bounded-state decode
    is_encoder_decoder: bool = False

    def __post_init__(self):
        assert self.family in ARCH_FAMILIES, self.family
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads {self.n_heads} not divisible by "
            f"kv {self.n_kv_heads}")

    # ---- derived quantities -------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (approximate; matches init to ~1%)."""
        d, h, kv, hd, ff, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                  self.head_dim, self.d_ff, self.vocab_size,
                                  self.n_layers)
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.mlp == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.family == MOE:
            assert self.moe is not None
            mlp = mlp * self.moe.num_experts + d * self.moe.num_experts
        per_layer = attn + mlp + 2 * d
        if self.family == SSM:
            # xlstm blocks: rough count via projections
            assert self.xlstm is not None
            d_in = int(d * self.xlstm.proj_factor)
            per_layer = 2 * d * d_in + 4 * d_in * d_in // 4 + 2 * d
            return emb + L * per_layer
        if self.family == HYBRID:
            assert self.ssm is not None
            d_in = self.ssm.expand * d
            n_h = d_in // self.ssm.head_dim
            mamba = (d * (2 * d_in + 2 * n_h * self.ssm.d_state + n_h)
                     + d_in * d)
            return emb + L * (mamba + 2 * d) + (attn + mlp + 2 * d)  # + shared attn
        total = emb + L * per_layer
        if self.family == AUDIO:
            total += self.n_encoder_layers * (2 * attn + mlp + 3 * d)
        if self.family == VLM and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (attn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if self.family != MOE:
            return self.param_count()
        assert self.moe is not None
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        n_mats = 3 if self.mlp == "swiglu" else 2
        dense_like = self.param_count()
        all_experts = L * n_mats * d * ff * self.moe.num_experts
        active = L * n_mats * d * ff * self.moe.top_k
        return dense_like - all_experts + active


# ---------------------------------------------------------------------------
# DiffusionBlocks configuration (the paper's technique)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DBConfig:
    """Paper §3 + App. C/E defaults (EDM, Karras et al. 2022)."""
    num_blocks: int = 3
    p_mean: float = -1.2
    p_std: float = 1.2
    sigma_min: float = 0.002
    sigma_max: float = 80.0
    sigma_data: float = 0.5
    overlap_gamma: float = 0.05          # 0.1 for text per App. C
    partition: str = "equiprob"          # equiprob | uniform (ablation, Table 7)
    causal_mode: str = "concat"          # concat | two_pass (App. E.4)
    cond_dim: int = 256                  # sigma-embedding fourier dim
    num_sampling_steps: int = 50         # Euler steps at inference (App. E)
    embed_l2_normalize: bool = True      # App. C (anti embedding-collapse)
    loss: str = "ce"                     # ce (discrete targets) | l2 (continuous)

    def __post_init__(self):
        assert self.partition in ("equiprob", "uniform")
        assert self.causal_mode in ("concat", "two_pass")
        assert self.loss in ("ce", "l2")
        assert self.num_blocks >= 1


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# ---------------------------------------------------------------------------
# Training configuration (drivers / examples)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    batch_size: int = 16
    seq_len: int = 128
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.03
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 20
    ckpt_every: int = 0                 # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    remat: bool = False
