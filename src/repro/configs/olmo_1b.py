"""olmo-1b [dense] — non-parametric LayerNorm. [arXiv:2402.00838]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="olmo-1b",
    family=DENSE,
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",           # OLMo: LayerNorm without affine params
    mlp="swiglu",
    tie_embeddings=True,
    source="arXiv:2402.00838 (OLMo-1B)",
    supports_long_context=False,
)
