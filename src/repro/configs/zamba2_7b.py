"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

81 Mamba2 layers; one *shared-weight* attention+MLP block applied every 3 mamba
layers (27 applications of the same params), following the Zamba2 shared-block
design. Attention inside the shared block uses a bounded window so decode state
stays sub-quadratic-friendly for long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig, HYBRID

CONFIG = ModelConfig(
    name="zamba2-7b",
    family=HYBRID,
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=128),
    attn_every=3,                 # 81 = 27 super-blocks x 3 mamba layers
    sliding_window=4096,          # shared attn block uses a window (bounded state)
    norm="rmsnorm",
    mlp="swiglu",
    source="arXiv:2411.15242 (Zamba2-7B)",
    supports_long_context=True,
)
