"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks. [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, XLSTMConfig, SSM

CONFIG = ModelConfig(
    name="xlstm-125m",
    family=SSM,
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                       # xLSTM blocks carry their own up-projection
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0),
    norm="layernorm",
    mlp="gelu",
    source="arXiv:2405.04517 (xLSTM)",
    supports_long_context=True,   # O(1) recurrent state
)
