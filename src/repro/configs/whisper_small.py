"""whisper-small [audio] — enc-dec; conv/mel frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, AUDIO

CONFIG = ModelConfig(
    name="whisper-small",
    family=AUDIO,
    n_layers=12,                  # decoder layers
    n_encoder_layers=12,
    n_audio_frames=1500,          # 30s audio at 50 Hz post-conv
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm="layernorm",
    mlp="gelu",
    rope_theta=0.0,               # whisper uses learned/sinusoidal pos, not rope
    is_encoder_decoder=True,
    source="arXiv:2212.04356 (Whisper small)",
    supports_long_context=False,
)
