"""qwen1.5-32b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B family scaled
per assignment]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family=DENSE,
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B (family; dims per assignment)",
    supports_long_context=False,
)
