"""Config registry: ``get_config(name)``, ``reduced(cfg)`` smoke variants, shapes."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (ARCH_FAMILIES, AUDIO, DENSE, HYBRID, MOE, SSM,
                                VLM, DBConfig, MeshConfig, ModelConfig,
                                MoEConfig, SSMConfig, ShapeConfig, TrainConfig,
                                XLSTMConfig, INPUT_SHAPES)

from repro.configs.qwen1_5_32b import CONFIG as _QWEN
from repro.configs.h2o_danube3_4b import CONFIG as _DANUBE
from repro.configs.zamba2_7b import CONFIG as _ZAMBA
from repro.configs.phi3_5_moe import CONFIG as _PHI
from repro.configs.grok1_314b import CONFIG as _GROK
from repro.configs.whisper_small import CONFIG as _WHISPER
from repro.configs.stablelm_1_6b import CONFIG as _STABLELM
from repro.configs.xlstm_125m import CONFIG as _XLSTM
from repro.configs.olmo_1b import CONFIG as _OLMO
from repro.configs.llama32_vision_11b import CONFIG as _LLAMA_V

ARCH_CONFIGS: Dict[str, ModelConfig] = {
    c.name: c for c in
    [_QWEN, _DANUBE, _ZAMBA, _PHI, _GROK, _WHISPER, _STABLELM, _XLSTM, _OLMO,
     _LLAMA_V]
}

# Default DiffusionBlocks config per assigned arch (text domain: gamma=0.1, CE).
DEFAULT_DB = DBConfig(num_blocks=4, overlap_gamma=0.1, loss="ce")


def list_archs() -> List[str]:
    return sorted(ARCH_CONFIGS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return ARCH_CONFIGS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers, d_model<=512, <=4 experts.

    Preserves every structural trait (GQA ratio, SWA, MoE, hybrid interleave,
    enc-dec, cross-attn, norm type) while shrinking dims for CPU execution.
    """
    kv = max(1, n_heads // max(1, cfg.q_per_kv))
    changes = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=min(cfg.vocab_size, vocab) if cfg.vocab_size else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk_size=16)
    if cfg.attn_every:
        changes["attn_every"] = 1
        changes["n_layers"] = 2
    if cfg.cross_attn_every:
        changes["cross_attn_every"] = 2
        changes["n_layers"] = 2
        changes["n_image_tokens"] = 16
    if cfg.is_encoder_decoder:
        changes["n_encoder_layers"] = 2
        changes["n_audio_frames"] = 32
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ARCH_CONFIGS", "ARCH_FAMILIES", "AUDIO", "DENSE", "HYBRID", "MOE", "SSM",
    "VLM", "DBConfig", "DEFAULT_DB", "INPUT_SHAPES", "MeshConfig",
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "TrainConfig",
    "XLSTMConfig", "get_config", "get_shape", "list_archs", "reduced",
]
