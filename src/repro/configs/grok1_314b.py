"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="grok-1-314b",
    family=MOE,
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    norm="rmsnorm",
    mlp="swiglu",                 # grok experts are GeGLU-style (3 matrices)
    source="hf:xai-org/grok-1",
    supports_long_context=False,
)
