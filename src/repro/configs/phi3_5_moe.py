"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family=MOE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25),
    norm="layernorm",
    mlp="swiglu",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    supports_long_context=False,
)
