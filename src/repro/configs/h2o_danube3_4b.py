"""h2o-danube-3-4b [dense] — llama+mistral mix, sliding-window
attention. [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family=DENSE,
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,          # mistral-style SWA -> bounded KV cache
    norm="rmsnorm",
    mlp="swiglu",
    source="arXiv:2401.16818 (H2O-Danube3)",
    supports_long_context=True,   # SWA bounds decode state -> long_500k runs
)
