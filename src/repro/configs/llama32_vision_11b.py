"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer; vision
encoder STUBBED (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family=VLM,
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,           # 8 cross-attn layers in 40
    n_image_tokens=1601,          # ViT-H/14 @ 560px + cls, per model card
    norm="rmsnorm",
    mlp="swiglu",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    supports_long_context=False,
)
