"""The paper's own experimental architectures (Section 5 / Appendix E).

These drive the benchmarks (one per paper table) and the examples. Dims follow
Appendix E; the data is synthetic (no external datasets offline), so the sizes
used by benchmarks are reduced via ``reduced()`` in the registry.
"""
from repro.configs.base import ModelConfig, DBConfig, DENSE

# §5.1 / E.1: 12-layer ViT, patch 4, hidden 128, 4 heads, B=3
VIT_CIFAR = ModelConfig(
    name="vit-cifar",
    family=DENSE,
    n_layers=12,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=100,               # classes
    norm="layernorm",
    mlp="gelu",
    rope_theta=0.0,
    source="paper §5.1 (ViT CIFAR-100)",
)
VIT_DB = DBConfig(num_blocks=3, overlap_gamma=0.05, loss="ce")

# §5.2 / E.2: DiT-S/2 (12 layers, d=384, 6 heads)
DIT_S2 = ModelConfig(
    name="dit-s2",
    family=DENSE,
    n_layers=12,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=0,                 # continuous targets
    norm="layernorm",
    mlp="gelu",
    rope_theta=0.0,
    source="paper §5.2 (DiT-S/2)",
)
DIT_DB = DBConfig(num_blocks=3, overlap_gamma=0.05, loss="l2")

# §5.4 / E.4: 12-layer Llama-2-style AR transformer, d=768, 12 heads, B=4
AR_LM = ModelConfig(
    name="ar-lm",
    family=DENSE,
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab_size=32000,
    norm="rmsnorm",
    mlp="swiglu",
    source="paper §5.4 (Llama-2-style AR)",
)
AR_DB = DBConfig(num_blocks=4, overlap_gamma=0.1, loss="ce")

# §5.3 / E.3: 12-layer DiT-based MDM transformer, d=768, 12 heads, B=3
MDM = ModelConfig(
    name="mdm-text8",
    family=DENSE,
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32,                # text8: a-z + specials + [MASK]
    norm="layernorm",
    mlp="gelu",
    source="paper §5.3 (MD4 / text8)",
)
MDM_DB = DBConfig(num_blocks=3, overlap_gamma=0.05, loss="ce")

# §5.5 / E.5: Huginn recurrent-depth: 2 prelude + 4 recurrent + 2 coda, d=512, 8H
HUGINN = ModelConfig(
    name="huginn",
    family=DENSE,
    n_layers=4,                   # the recurrent core
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=32000,
    norm="rmsnorm",
    mlp="swiglu",
    source="paper §5.5 (Huginn / Pythia-70M dims)",
)
HUGINN_DB = DBConfig(num_blocks=1, overlap_gamma=0.0, loss="ce")
HUGINN_PRELUDE_LAYERS = 2
HUGINN_CODA_LAYERS = 2
HUGINN_RECURRENCE = 32            # mean recurrence depth at inference
