"""stablelm-1.6b [dense]. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family=DENSE,
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layernorm",
    mlp="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b",
    supports_long_context=False,
)
