"""Fault-tolerant supervised training loop.

``TrainRunner`` is the training-side counterpart of the serving stack's
``EngineRunner``/router supervisors: it wraps the block-cycling sequential
trainer (``--mode db``) and the block-parallel engine (``--block-parallel``)
in one supervision loop that owns

  * crash-consistent CHECKPOINTS — a ``repro.checkpoint.CheckpointManager``
    generation every ``ckpt_every`` batches (parallel) / steps (db), whose
    manifest carries the step, rng key, data-loader cursor, guard counters,
    and periphery policy, so ``resume=True`` continues BIT-IDENTICALLY to an
    uninterrupted run (same params, same optimizer moments, same batches,
    same per-block rng draws);
  * per-block ANOMALY REWIND — when a block's guard streak reaches
    ``GuardConfig.rewind_after`` consecutive anomalies, ONLY that block's
    params + optimizer moments are restored from the last good generation
    (the shared periphery and every other block are untouched — the paper's
    §3 independence result as a fault boundary);
  * HEARTBEATS — per-block last-clean-update markers (batch index), the
    signal that distinguishes "one block is being skipped every step" from
    "training is healthy";
  * FAULT INJECTION — a shared ``repro.launch.faults.FaultInjector``
    consulted at the training hook points (``pod_die``, ``grad_nan``,
    ``data_stall``; ``ckpt_corrupt`` fires inside the manager).

Pod death semantics differ by mode, deliberately:

  block-parallel   the victim block's pod (and the device copy of its state)
                   is lost: the block rewinds to its last checkpoint
                   generation and DEGRADES to the round-robin path — each
                   batch runs one mesh step for the survivors plus one
                   round-robin orphan pass (``update_periphery=False``, so
                   the mesh stays the single periphery writer). When the pod
                   revives after ``pod_restart_after`` batches the block is
                   re-adopted onto the mesh automatically.
  db               there is no pod to lose a block to — ``pod_die`` is
                   simulated PROCESS death: the runner restarts from the
                   latest good generation (bounded by ``max_restarts``,
                   then ``TrainFailed``).

``halt_after`` stops the run abruptly at a batch/step index WITHOUT a final
checkpoint — kill semantics. Work since the last cadence checkpoint is lost
and deterministically replayed on ``resume=True``; the resume-parity gate in
``benchmarks/table21_faulttrain.py`` asserts the replay is bit-identical.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, key_from_json, key_to_json
from repro.configs.base import TrainConfig
from repro.core.blocks import DiffusionBlocksModel
from repro.core.training import (STACK_KEYS, GuardConfig, extract_block_view,
                                 make_db_train_step, write_back_block_view)
from repro.launch.faults import PodDied
from repro.parallel.engine import BlockParallelTrainer
from repro.parallel.state import BlockParallelState


class TrainFailed(RuntimeError):
    """The supervisor exhausted its restart budget, or had no checkpoint to
    restart/resume from."""


def _bname(b: int) -> str:
    return f"block_{b:02d}"


class TrainRunner:
    """Supervised training driver; see module docstring.

    ``make_data`` (passed to :meth:`train`) is ``cursor -> iterator``: called
    with ``None`` for a fresh stream and with a manifest cursor on resume /
    restart (``repro.data.MarkovStream.from_cursor`` is the canonical
    implementation). ``ckpt_every`` counts batches in block-parallel mode and
    steps in db mode.
    """

    def __init__(self, dbm: DiffusionBlocksModel, tcfg: TrainConfig,
                 mode: str = "db", *, periphery: str = "replicate+psum-mean",
                 impl: str = "auto", precision=None, periphery_lr_scale=None,
                 guard: Optional[GuardConfig] = None, devices=None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 5,
                 keep: int = 3, faults=None, max_restarts: int = 3,
                 pod_restart_after: int = 2, log: Callable = print):
        if mode not in ("db", "block-parallel"):
            raise ValueError(f"unknown TrainRunner mode {mode!r}")
        self.dbm, self.tcfg, self.mode = dbm, tcfg, mode
        self.periphery, self.impl, self.precision = periphery, impl, precision
        self.periphery_lr_scale = periphery_lr_scale
        self.guard = GuardConfig() if guard is None else guard
        self.devices = devices
        self.ckpt_every = max(1, int(ckpt_every))
        self.faults = faults
        self.max_restarts = int(max_restarts)
        self.pod_restart_after = int(pod_restart_after)
        self.log = log
        self.manager = (CheckpointManager(ckpt_dir, keep=keep, faults=faults)
                        if ckpt_dir else None)
        self.counters = {"pod_deaths": 0, "readoptions": 0, "rewinds": 0,
                         "restarts": 0, "data_stalls": 0, "nan_injected": 0,
                         "degraded_batches": 0, "ckpt_saves": 0}
        self.heartbeats: Dict[int, int] = {}
        self._rr: Optional[BlockParallelTrainer] = None
        # debug handles populated by train() for tests/benchmarks
        self.trainer: Optional[BlockParallelTrainer] = None
        self.state: Optional[BlockParallelState] = None

    # ------------------------------------------------------------------
    def train(self, make_data: Callable, rng, params=None,
              resume: bool = False, halt_after: Optional[int] = None):
        """Run to ``tcfg.steps`` (or ``halt_after``); returns
        ``(params, history)`` with the same history convention as
        ``train_db`` / ``BlockParallelTrainer.train``."""
        if resume and self.manager is None:
            raise TrainFailed("resume=True requires a ckpt_dir")
        if self.mode == "block-parallel":
            return self._train_parallel(make_data, rng, params, resume,
                                        halt_after)
        return self._train_db(make_data, rng, params, resume, halt_after)

    def stats(self) -> dict:
        out = {"counters": dict(self.counters),
               "heartbeats": {str(k): int(v)
                              for k, v in sorted(self.heartbeats.items())}}
        if self.faults is not None:
            out["faults"] = self.faults.stats()
        return out

    # ------------------------------------------------------------------
    def _maybe_stall(self) -> None:
        """``data_stall`` hook around the data fetch (counted)."""
        f = self.faults
        if f is None:
            return
        before = f.fired.get("data_stall", 0)
        f.maybe_sleep("data_stall")
        if f.fired.get("data_stall", 0) > before:
            self.counters["data_stalls"] += 1

    @staticmethod
    def _cursor(data):
        return data.cursor() if hasattr(data, "cursor") else None

    # ==================================================================
    # block-parallel mode
    # ==================================================================
    def _parallel_trees(self, trainer: BlockParallelTrainer, state):
        trees = {}
        for b in range(trainer.B):
            s, o = trainer.block_trees(state, b)
            trees[_bname(b)] = s
            trees[_bname(b) + ".opt"] = o
        trees["periphery"] = jax.device_get(state.periph)
        trees["periphery.opt"] = jax.device_get(state.periph_opt)
        return trees

    def _save_parallel(self, trainer, state, bt, it, rng, data) -> None:
        st = {"mode": "block-parallel", "engine": trainer.mode,
              "policy": trainer.policy, "batch": int(bt), "it": int(it),
              "rng": key_to_json(rng), "data_cursor": self._cursor(data),
              "guard": trainer.guard_state(),
              "heartbeats": {str(k): int(v)
                             for k, v in self.heartbeats.items()},
              "counters": dict(self.counters)}
        gen = self.manager.save(self._parallel_trees(trainer, state), st)
        self.counters["ckpt_saves"] += 1
        self.log(f"[runner] checkpoint generation {gen} at batch {bt}")

    def _parallel_from_trees(self, trainer, state, trees):
        for b in range(trainer.B):
            state = trainer.write_block(state, b, trees[_bname(b)],
                                        trees[_bname(b) + ".opt"])
        periph = jax.tree_util.tree_map(
            lambda t, x: jnp.asarray(t, x.dtype), trees["periphery"],
            state.periph)
        popt = jax.tree_util.tree_map(
            lambda t, x: jnp.asarray(t, x.dtype), trees["periphery.opt"],
            state.periph_opt)
        if trainer.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.sharding import rules
            rp = NamedSharding(trainer.mesh,
                               rules.block_state_specs()["replicated"])
            periph = jax.device_put(periph, rp)
            popt = jax.device_put(popt, rp)
        return BlockParallelState(state.stacks, periph, state.stack_opt, popt)

    def _orphan_trainer(self, trainer: BlockParallelTrainer):
        """Round-robin engine for orphaned blocks. When the main engine is
        already round-robin it IS the orphan path; under shard_map a
        single-device sibling trainer (same math, compiled once on first pod
        death) carries the orphans so the dead pod's program never runs."""
        if trainer.mode == "round_robin":
            return trainer
        if self._rr is None:
            self.log("[runner] degrading orphaned blocks to round-robin")
            self._rr = BlockParallelTrainer(
                self.dbm, self.tcfg, periphery=self.periphery,
                impl=self.impl, precision=self.precision,
                periphery_lr_scale=self.periphery_lr_scale, guard=self.guard,
                devices=[jax.devices()[0]])
        return self._rr

    def _rewind_block(self, trainer, state, b: int, why: str):
        """Restore ONE block's stack + optimizer moments from the latest good
        generation (periphery and other blocks untouched)."""
        gen = self.manager.latest_good_generation() if self.manager else None
        if gen is None:
            trainer.anomaly_streak[b] = 0
            self.log(f"[runner] {why}; no checkpoint generation to rewind "
                     f"block {b} — keeping current state")
            return state
        stack_t, opt_t = trainer.block_trees(state, b)
        stack = self.manager.load_tree(gen, _bname(b), stack_t)
        opt = self.manager.load_tree(gen, _bname(b) + ".opt", opt_t)
        self.log(f"[runner] {why}; block {b} rewound to generation {gen}")
        return trainer.write_block(state, b, stack, opt)

    def _pick_victim(self, B: int, dead) -> Optional[int]:
        start = self.counters["pod_deaths"] % B
        for d in range(B):
            v = (start + d) % B
            if v not in dead:
                return v
        return None

    def _train_parallel(self, make_data, rng, params, resume, halt_after):
        tcfg = self.tcfg
        trainer = BlockParallelTrainer(
            self.dbm, tcfg, periphery=self.periphery, impl=self.impl,
            precision=self.precision,
            periphery_lr_scale=self.periphery_lr_scale, guard=self.guard,
            devices=self.devices)
        B = trainer.B
        rng, r0 = jax.random.split(rng)
        if params is None:
            params = self.dbm.init(r0)
        state = trainer.init_state(params)
        start_batch, it, data = 0, 0, None
        if resume:
            templates = self._parallel_trees(trainer, state)
            trees, manifest = self.manager.load_latest(templates, log=self.log)
            if trees is None:
                raise TrainFailed("resume=True but no loadable generation in "
                                  f"{self.manager.ckpt_dir!r}")
            state = self._parallel_from_trees(trainer, state, trees)
            st = manifest["state"]
            start_batch, it = int(st["batch"]), int(st["it"])
            rng = key_from_json(st["rng"])
            trainer.set_guard_state(st.get("guard"))
            self.heartbeats = {int(k): int(v)
                               for k, v in st.get("heartbeats", {}).items()}
            if st.get("data_cursor") is not None:
                data = make_data(st["data_cursor"])
            self.log(f"[runner] resumed generation {manifest['generation']} "
                     f"at batch {start_batch}")
        if data is None:
            data = make_data(None)
        if self.manager is not None and not self.manager.generations():
            # generation 0-equivalent: the rewind target before the first
            # cadence checkpoint exists
            self._save_parallel(trainer, state, start_batch, it, rng, data)
        dead_until: Dict[int, int] = {}
        history = []
        batches = math.ceil(tcfg.steps / B)
        bt = start_batch
        while bt < batches:
            # -- pod lifecycle ------------------------------------------
            for b in [b for b, until in sorted(dead_until.items())
                      if bt >= until]:
                del dead_until[b]
                self.counters["readoptions"] += 1
                self.log(f"[runner] pod {b} recovered at batch {bt}; block "
                         f"re-adopted onto the mesh")
            if self.faults is not None and self.faults.fire("pod_die"):
                v = self._pick_victim(B, dead_until)
                if v is not None:
                    self.counters["pod_deaths"] += 1
                    dead_until[v] = bt + self.pod_restart_after
                    state = self._rewind_block(
                        trainer, state, v,
                        f"pod {v} died at batch {bt} (device state lost)")
            # -- fault hooks + data -------------------------------------
            loss_mult = None
            if self.faults is not None and self.faults.fire("grad_nan"):
                # victim: pinned via {"block": b} in the spec, else rotate
                v = self.faults.specs["grad_nan"].get(
                    "block", (self.faults.fired["grad_nan"] - 1) % B)
                loss_mult = np.ones(B, np.float32)
                loss_mult[v] = np.nan
                self.counters["nan_injected"] += 1
                self.log(f"[runner] injected NaN loss for block {v} at "
                         f"batch {bt}")
            self._maybe_stall()
            tokens = next(data)
            rng, rs = jax.random.split(rng)
            rngs = jax.random.split(rs, B)
            # -- advance ------------------------------------------------
            if dead_until:
                dead = sorted(dead_until)
                active = np.ones(B, np.float32)
                active[dead] = 0.0
                state, losses, gnorms = trainer.step(
                    state, tokens, rngs, loss_mult=loss_mult, active=active)
                ok_main = trainer.last_ok.copy()
                m = np.zeros(B, bool)
                m[dead] = True
                rr = self._orphan_trainer(trainer)
                if rr is not trainer:
                    rr.guard_ewma = trainer.guard_ewma
                    rr.anomaly_streak = trainer.anomaly_streak.copy()
                    rr.anomalies = trainer.anomalies.copy()
                state, l2, g2 = rr.step(
                    state, tokens, rngs, loss_mult=loss_mult,
                    active=m.astype(np.float32), update_periphery=False)
                trainer.guard_ewma = jnp.where(
                    jnp.asarray(m), rr.guard_ewma, trainer.guard_ewma)
                trainer.anomaly_streak = np.where(
                    m, rr.anomaly_streak, trainer.anomaly_streak)
                trainer.anomalies = np.where(m, rr.anomalies,
                                             trainer.anomalies)
                trainer.last_ok = np.where(m, rr.last_ok, ok_main)
                losses = np.where(m, np.asarray(l2), np.asarray(losses))
                gnorms = np.where(m, np.asarray(g2), np.asarray(gnorms))
                self.counters["degraded_batches"] += 1
            else:
                state, losses, gnorms = trainer.step(
                    state, tokens, rngs, loss_mult=loss_mult)
            losses = np.asarray(losses)
            for b in range(B):
                if trainer.last_ok[b]:
                    self.heartbeats[b] = bt
                if it < tcfg.steps:
                    history.append((it, b, float(losses[b])))
                it += 1
            # -- guard rewind -------------------------------------------
            for b in np.nonzero(
                    trainer.anomaly_streak >= self.guard.rewind_after)[0]:
                state = self._rewind_block(
                    trainer, state, int(b),
                    f"block {int(b)} hit {int(trainer.anomaly_streak[b])} "
                    f"consecutive anomalies")
                self.counters["rewinds"] += 1
            bt += 1
            if tcfg.log_every and (bt - 1) % tcfg.log_every == 0:
                self.log(f"[runner/{trainer.mode}] batch={bt - 1} "
                         f"loss={losses.mean():.4f} dead={sorted(dead_until)}")
            if self.manager is not None and (bt % self.ckpt_every == 0
                                             or bt == batches):
                self._save_parallel(trainer, state, bt, it, rng, data)
            if halt_after is not None and bt >= halt_after:
                self.log(f"[runner] halting at batch {bt} (halt_after; no "
                         f"checkpoint — kill semantics)")
                break
        if hasattr(data, "close"):
            data.close()
        self.trainer, self.state = trainer, state
        return trainer.full_params(state), history

    # ==================================================================
    # db (sequential block-cycling) mode
    # ==================================================================
    def _db_templates(self, params, opts):
        trees = {}
        for b, (start, size) in enumerate(self.dbm.ranges):
            trees[_bname(b)] = extract_block_view(params, start, size)
            trees[_bname(b) + ".opt"] = opts[b]
        return trees

    def _save_db(self, params, opts, it, rng, data, ewma, streak,
                 anomalies) -> None:
        st = {"mode": "db", "it": int(it), "rng": key_to_json(rng),
              "data_cursor": self._cursor(data),
              "guard": {"ewma": [float(e) for e in ewma],
                        "streak": [int(s) for s in streak],
                        "anomalies": [int(a) for a in anomalies]},
              "heartbeats": {str(k): int(v)
                             for k, v in self.heartbeats.items()},
              "counters": dict(self.counters)}
        gen = self.manager.save(self._db_templates(params, opts), st)
        self.counters["ckpt_saves"] += 1
        self.log(f"[runner] checkpoint generation {gen} at it={it}")

    def _load_db(self, params, opts):
        """(params, opts, guard, it, rng, cursor, heartbeats) from the latest
        good generation, or None."""
        trees, manifest = self.manager.load_latest(
            self._db_templates(params, opts), log=self.log)
        if trees is None:
            return None
        for b, (start, size) in enumerate(self.dbm.ranges):
            params = write_back_block_view(params, trees[_bname(b)], start)
            opts[b] = trees[_bname(b) + ".opt"]
        st = manifest["state"]
        g = st["guard"]
        return (params, opts, g, int(st["it"]), key_from_json(st["rng"]),
                st.get("data_cursor"), st.get("heartbeats", {}))

    def _rewind_db_block(self, params, opt_b, b: int, why: str):
        """Restore ONLY block ``b``'s stack slice (+ its private optimizer
        view) from the latest good generation; the shared periphery keeps its
        CURRENT values — other blocks must not observe the rewind."""
        gen = self.manager.latest_good_generation() if self.manager else None
        if gen is None:
            self.log(f"[runner] {why}; no checkpoint generation to rewind "
                     f"block {b} — keeping current state")
            return params, opt_b, False
        start, size = self.dbm.ranges[b]
        cur_view = extract_block_view(params, start, size)
        old_view = self.manager.load_tree(gen, _bname(b), cur_view)
        merged = {k: (old_view[k] if k in STACK_KEYS else cur_view[k])
                  for k in cur_view}
        params = write_back_block_view(params, merged, start)
        opt_b = self.manager.load_tree(gen, _bname(b) + ".opt", opt_b)
        self.log(f"[runner] {why}; block {b} rewound to generation {gen}")
        return params, opt_b, True

    def _train_db(self, make_data, rng, params, resume, halt_after):
        dbm, tcfg = self.dbm, self.tcfg
        B = dbm.num_blocks
        rng, r0 = jax.random.split(rng)
        if params is None:
            params = dbm.init(r0)
        steppers, opts = [], []
        for b in range(B):
            io, st = make_db_train_step(dbm, b, tcfg, impl=self.impl,
                                        precision=self.precision,
                                        guard=self.guard)
            steppers.append(st)
            opts.append(io(params))
        ewma = [jnp.float32(-1.0)] * B
        streak = [0] * B
        anomalies = [0] * B
        it, data, history = 0, None, []

        def restore(loaded):
            nonlocal params, opts, ewma, streak, anomalies, it, rng, data
            params, opts, g, it, rng, cur, hb = loaded
            ewma = [jnp.float32(e) for e in g["ewma"]]
            streak = [int(s) for s in g["streak"]]
            anomalies = [int(a) for a in g["anomalies"]]
            self.heartbeats = {int(k): int(v) for k, v in hb.items()}
            if data is not None and hasattr(data, "close"):
                data.close()
            data = make_data(cur)

        if resume:
            loaded = self._load_db(params, opts)
            if loaded is None:
                raise TrainFailed("resume=True but no loadable generation in "
                                  f"{self.manager.ckpt_dir!r}")
            restore(loaded)
            self.log(f"[runner] resumed at it={it}")
        if data is None:
            data = make_data(None)
        if self.manager is not None and not self.manager.generations():
            self._save_db(params, opts, it, rng, data, ewma, streak,
                          anomalies)
        while it < tcfg.steps:
            if self.faults is not None:
                try:
                    self.faults.maybe_raise("pod_die", PodDied)
                except PodDied:
                    # db mode has no pod to orphan a block to: pod_die is
                    # simulated PROCESS death → bounded restart from the
                    # latest good generation
                    self.counters["pod_deaths"] += 1
                    self.counters["restarts"] += 1
                    if self.counters["restarts"] > self.max_restarts:
                        raise TrainFailed(
                            f"restart budget exhausted "
                            f"({self.max_restarts})")
                    if self.manager is None:
                        raise TrainFailed(
                            "pod_die fired with no ckpt_dir to restart from")
                    loaded = self._load_db(params, opts)
                    if loaded is None:
                        raise TrainFailed("no loadable checkpoint generation")
                    restore(loaded)
                    self.log(f"[runner] restarted from it={it} (restart "
                             f"{self.counters['restarts']}/"
                             f"{self.max_restarts})")
                    continue
            mult = 1.0
            if self.faults is not None and self.faults.fire("grad_nan"):
                mult = float("nan")
                self.counters["nan_injected"] += 1
                self.log(f"[runner] injected NaN loss at it={it}")
            self._maybe_stall()
            tokens = next(data)
            rng, rb, rs = jax.random.split(rng, 3)
            b = int(jax.random.randint(rb, (), 0, B))
            params, opts[b], ewma[b], loss, m = steppers[b](
                params, opts[b], ewma[b], tokens, rs, None, mult)
            if bool(m["ok"]):
                streak[b] = 0
                self.heartbeats[b] = it
            else:
                streak[b] += 1
                anomalies[b] += 1
                self.log(f"[runner] anomaly at it={it} block={b} "
                         f"(streak {streak[b]})")
            history.append((it, b, float(loss)))
            if streak[b] >= self.guard.rewind_after:
                params, opts[b], did = self._rewind_db_block(
                    params, opts[b], b,
                    f"block {b} hit {streak[b]} consecutive anomalies")
                if did:
                    ewma[b] = jnp.float32(-1.0)
                    self.counters["rewinds"] += 1
                streak[b] = 0
            it += 1
            if tcfg.log_every and (it - 1) % tcfg.log_every == 0:
                self.log(f"[runner/db] it={it - 1} block={b} "
                         f"loss={float(loss):.4f}")
            if self.manager is not None and (it % self.ckpt_every == 0
                                             or it == tcfg.steps):
                self._save_db(params, opts, it, rng, data, ewma, streak,
                              anomalies)
            if halt_after is not None and it >= halt_after:
                self.log(f"[runner] halting at it={it} (halt_after; no "
                         f"checkpoint — kill semantics)")
                break
        if hasattr(data, "close"):
            data.close()
        self.opt_states, self.ewma, self.streak = opts, ewma, streak
        return params, history
