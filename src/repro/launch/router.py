"""Disaggregated prefill/decode coordinator with fault-tolerant migration.

``DisaggRouter`` fronts a fleet of supervised workers (``launch.workers``):
prompts route to PREFILL workers, and the moment a prompt is fully
committed the request MIGRATES — as a ``SpilledSlot`` byte-copy payload
(``handoff="copy"``, separate pools) or a page-table handle
(``handoff="pages"``, one ``SharedPagePool``) — to a DECODE worker, so
long-prompt ingest never steals chunk dispatches from latency-sensitive
decode segments. Migration is rng-neutral: the prefill side never runs a
decode step for a migrating request, so the decode worker's greedy output
is bit-identical to an uninterrupted unified run.

Every seam is designed to fail:

  handoff loss      the ``handoff_drop`` chaos hook loses the payload in
                    transit → the router RE-PREFILLS: a fresh inner request
                    whose prompt is the original prompt plus every token
                    already delivered (greedy determinism makes the
                    continuation exact), served from the prefix cache when
                    one is configured.
  handoff timeout   a send slower than ``handoff_timeout_s`` (the
                    ``handoff_stall`` hook) retains the payload and retries
                    with exponential backoff, ``handoff_max_retries`` times
                    — then falls back to re-prefill.
  worker death      ``WorkerDied`` (the ``worker_die`` hook) kills the
                    engine thread with NO recovery and NO stream cleanup —
                    a dead process cannot apologize. The router's sweep
                    notices (thread dead / ``died`` flag), harvests the
                    batcher (``extract_all``), and fails survivors over:
                    payload-intact requests re-migrate (page handles still
                    valid on a shared pool), the rest re-prefill from
                    prompt + delivered tokens. Workers optionally restart
                    after ``restart_dead_after_s``.
  role wipe-out     all workers of one role down → DEGRADED UNIFIED mode:
                    the survivors serve prefill AND decode
                    (``PrefillBatcher.boundary_spill = False``) and pending
                    handoffs land wherever there is life. When both roles
                    have survivors again the router RE-SPLITS; requests
                    caught mid-decode on a prefill worker simply hit the
                    boundary condition next step and migrate out.

Admission control mirrors the single-engine path (PR 7): ``max_queue``
sheds by priority-aware backlog, ``shed_below_pages`` sheds batch-class
work under decode-pool pressure, both with ``AdmissionError`` carrying a
service-time ``retry_after`` hint.

Threading: worker engine threads call ``_worker_tokens`` / ``_worker_finish``
(router lock only); the router's tick thread owns handoffs, failover,
mode flips and cancellation. The tick thread NEVER takes a batcher pool
lock while holding the router lock (pool locks are taken by engine threads
that then call back into the router lock — holding both the other way
would deadlock).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.launch.faults import FaultInjector
from repro.launch.serve import (AdmissionError, ContinuousBatcher,
                                PRIORITY_CLASSES, Request, SharedPagePool)
from repro.launch.server import EngineRunner
from repro.launch.workers import PrefillBatcher, Worker
from repro.nn import cache as KVC


@dataclasses.dataclass
class RoutedRequest:
    """The router's client-facing request record. Worker-side ``Request``
    objects (``inner``) come and go — migration moves one between workers,
    failover may replace it entirely — but THIS object owns the delivered
    token list and the terminal flags, and it quacks enough like a
    ``Request`` (``out`` / ``ttft`` / ``cancelled`` / ``error`` /
    ``preempt_count`` / ``deadline_blown``) for the HTTP frontend's
    ``TokenStream`` + ``_final_payload`` path to use unchanged."""
    rid: int
    prompt: np.ndarray
    max_new: int
    aux_inputs: Optional[dict] = None
    cond_fp: int = 0
    priority: int = PRIORITY_CLASSES["standard"]
    ttft_deadline: Optional[float] = None
    tpot_deadline_s: Optional[float] = None
    out: List[int] = dataclasses.field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    cancelled: bool = False
    error: Optional[str] = None
    deadline_blown: bool = False
    preempt_count: int = 0
    shared_tokens: int = 0
    migrations: int = 0          # completed prefill->decode handoffs
    failovers: int = 0           # re-routed off a dead worker
    paused: bool = False         # consumer backpressure (survives migration)
    phase: str = "prefill"       # prefill | handoff | decode | done
    where: Optional[str] = None  # name of the worker currently holding it
    inner: Optional[Request] = None
    finished: bool = False
    # rng stream adoption: a dead DECODE worker's engine rng, captured at
    # failover (worker_die raises before the step consumes any rng, so this
    # is exactly the resume state). An idle receiving decode engine adopts
    # it, making the failed-over continuation bit-identical to the
    # uninterrupted run; a busy receiver keeps its own stream (the
    # continuation is then a different — still valid — sample).
    resume_rng: Optional[object] = None

    @property
    def done(self) -> bool:
        return self.finished

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)


@dataclasses.dataclass
class _Handoff:
    """One in-transit prefill->decode migration owned by the router."""
    inner: Request
    routed: RoutedRequest
    attempts: int = 0
    due: float = 0.0             # earliest send time (backoff)


class DisaggRouter:
    """Coordinator over ``n_prefill`` + ``n_decode`` supervised workers.

    Exposes enough of the ``ContinuousBatcher`` surface (``submit`` /
    ``cancel`` / ``pause`` / ``resume`` / ``retry_after_hint`` / ``dbm`` /
    ``max_prompt`` / ``max_len`` / ``eng`` / ``token_cb``) that
    ``InferenceServer`` drives it through a thin ``RouterRunner`` facade;
    ``is_router`` is the discriminator."""

    is_router = True

    def __init__(self, dbm, params, *, n_prefill: int = 1, n_decode: int = 1,
                 handoff: str = "copy",
                 shared_pages: Optional[int] = None,
                 handoff_timeout_s: float = 0.5,
                 handoff_max_retries: int = 3,
                 handoff_backoff_s: float = 0.02,
                 restart_dead_after_s: Optional[float] = None,
                 tick_s: float = 0.002,
                 max_queue: Optional[int] = None,
                 shed_below_pages: int = 0,
                 faults: Optional[FaultInjector] = None,
                 rng=None, max_restarts: int = 3, **cb_kw):
        if n_prefill < 1 or n_decode < 1:
            raise ValueError("need at least one worker per role")
        if handoff not in ("copy", "pages"):
            raise ValueError(f"handoff must be 'copy' or 'pages', "
                             f"got {handoff!r}")
        self.dbm, self.params = dbm, params
        self.handoff = handoff
        self.handoff_timeout_s = float(handoff_timeout_s)
        self.handoff_max_retries = int(handoff_max_retries)
        self.handoff_backoff_s = float(handoff_backoff_s)
        self.restart_dead_after_s = restart_dead_after_s
        self.tick_s = float(tick_s)
        self.max_queue = max_queue
        self.shed_below_pages = int(shed_below_pages)
        self.faults = faults
        self.max_prompt = int(cb_kw.get("max_prompt", 64))
        self.max_len = int(cb_kw.get("max_len", 128))
        # worker batchers take prompts up to max_len: a failover re-prefill
        # replays (original prompt + delivered tokens) as the new prompt
        inner_kw = dict(cb_kw, max_prompt=self.max_len, faults=faults)
        self.pool: Optional[SharedPagePool] = None
        if handoff == "pages":
            if shared_pages is None:
                slots = int(cb_kw.get("num_slots", 8))
                pps = KVC.pages_for(self.max_len,
                                    int(cb_kw.get("page_size",
                                                  KVC.DEFAULT_PAGE_SIZE)))
                shared_pages = 1 + (n_prefill + n_decode) * slots * pps
            self.pool = SharedPagePool(shared_pages)
            inner_kw["shared_pool"] = self.pool
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        rngs = list(jax.random.split(rng, n_prefill + n_decode))
        self.prefill_workers: List[Worker] = []
        self.decode_workers: List[Worker] = []
        for i in range(n_prefill):
            cb = PrefillBatcher(dbm, params, handoff=handoff, **inner_kw)
            self.prefill_workers.append(self._make_worker(
                f"prefill{i}", "prefill", cb, rngs.pop(), max_restarts))
        # decode workers never admit fresh prompts in split mode, so the
        # prefix cache would only ever take refs without hits — disable it
        dec_kw = dict(inner_kw, prefix_cache=False)
        for i in range(n_decode):
            cb = ContinuousBatcher(dbm, params, **dec_kw)
            self.decode_workers.append(self._make_worker(
                f"decode{i}", "decode", cb, rngs.pop(), max_restarts))
        self.workers = self.prefill_workers + self.decode_workers
        self._by_name = {w.name: w for w in self.workers}
        # ---- router state (guarded by _lock) ----
        self._lock = threading.RLock()
        self.requests: Dict[int, RoutedRequest] = {}
        self._handoffs: collections.deque = collections.deque()
        self._pending_submit: collections.deque = collections.deque()
        self._cancel_pending: set = set()
        self._next_rid = 0
        self.mode = "split"          # split | unified (degraded)
        # ---- counters ----
        self.migrations = 0
        self.failovers = 0
        self.handoff_retries = 0
        self.handoff_drops = 0
        self.re_prefills = 0
        self.degradations = 0
        self.resplits = 0
        self.completed = 0
        self.shed_count = 0
        self._svc_ewma: Optional[float] = None
        # ---- frontend hooks ----
        self.token_cb: Optional[Callable] = None
        self.finish_cb: Optional[Callable] = None
        self._stopping = threading.Event()
        self._thread = threading.Thread(target=self._main, name="router",
                                        daemon=True)

    def _make_worker(self, name, role, cb, rng, max_restarts) -> Worker:
        w = Worker(name, role, cb, rng=rng, max_restarts=max_restarts)
        w._on_tokens = lambda req, toks, w=w: self._worker_tokens(w, req,
                                                                  toks)
        w._on_finish = lambda req, w=w: self._worker_finish(w, req)
        # rebind onto the already-built runner
        w.runner._cb_tokens = w._on_tokens
        w.runner._cb_finish = w._on_finish
        return w

    # ---- engine surface for InferenceServer ---------------------------
    @property
    def eng(self):
        return self.prefill_workers[0].cb.eng

    def retry_after_hint(self) -> float:
        return float(min(5.0, max(0.1, self._svc_ewma or 0.5)))

    def _note_service(self, dt: float):
        a = 0.2
        self._svc_ewma = (dt if self._svc_ewma is None
                          else a * dt + (1 - a) * self._svc_ewma)

    # ---- lifecycle -----------------------------------------------------
    def start(self):
        for w in self.workers:
            w.start()
        self._thread.start()

    def wake(self):
        for w in self.workers:
            w.wake()

    def stop(self, timeout: Optional[float] = 60.0):
        """Drain: wait for every accepted request to finish (the tick
        thread keeps migrating / failing over while we wait), force-error
        stragglers past ``timeout`` so no stream ever hangs, then stop the
        workers and the tick thread."""
        deadline = time.time() + (timeout if timeout is not None else 60.0)
        while time.time() < deadline:
            with self._lock:
                if all(r.finished for r in self.requests.values()):
                    break
            self.wake()
            time.sleep(0.01)
        stuck = []
        with self._lock:
            for r in self.requests.values():
                if not r.finished:
                    r.error = r.error or "router drain timeout"
                    stuck.append(r)
        for r in stuck:
            if r.inner is not None:
                self._drop_payload(r.inner)
            self._finish_routed(r)
        self._stopping.set()
        for w in self.workers:
            if w.runner._thread.is_alive():
                w.stop(5.0)
        if self._thread.is_alive():
            self._thread.join(5.0)

    # ---- submission ----------------------------------------------------
    def submit(self, prompt, max_new: int, aux_inputs=None, *,
               priority="standard", ttft_slo_s: Optional[float] = None,
               tpot_slo_s: Optional[float] = None) -> int:
        if isinstance(priority, str):
            if priority not in PRIORITY_CLASSES:
                raise ValueError(f"unknown priority class {priority!r}: "
                                 f"expected {sorted(PRIORITY_CLASSES)}")
            priority = PRIORITY_CLASSES[priority]
        priority = int(priority)
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size <= self.max_prompt, "prompt exceeds max_prompt"
        assert prompt.size + max_new <= self.max_len, \
            "request exceeds max_len"
        if aux_inputs:
            cap = self.dbm.model.max_cond_tokens
            if cap == 0:
                raise ValueError(f"family {self.dbm.cfg.family!r} takes no "
                                 "aux conditioning inputs")
            aux_inputs = {k: np.asarray(v, np.float32)
                          for k, v in aux_inputs.items()}
        with self._lock:
            if self.max_queue is not None:
                backlog = sum(1 for r in self.requests.values()
                              if not r.finished and r.phase != "decode"
                              and r.priority >= priority)
                if backlog >= self.max_queue:
                    self.shed_count += 1
                    raise AdmissionError(
                        f"pre-decode backlog {backlog} at priority >= "
                        f"{priority} over threshold {self.max_queue}",
                        self.retry_after_hint())
            if self.shed_below_pages and priority <= 0:
                free = self._decode_free_pages()
                if free < self.shed_below_pages:
                    self.shed_count += 1
                    raise AdmissionError(
                        f"decode pool pressure: {free} free pages below "
                        f"threshold {self.shed_below_pages}",
                        self.retry_after_hint())
            rid = self._next_rid
            self._next_rid += 1
            routed = RoutedRequest(
                rid, prompt, int(max_new), aux_inputs=aux_inputs or None,
                cond_fp=KVC.conditioning_fingerprint(aux_inputs),
                priority=priority, tpot_deadline_s=tpot_slo_s)
            routed.submit_t = time.time()
            if ttft_slo_s is not None:
                routed.ttft_deadline = routed.submit_t + float(ttft_slo_s)
            self.requests[rid] = routed
            inner = self._make_inner(routed, prompt, int(max_new))
            target = self._ingest_target()
            if target is None:       # no life anywhere: park for restarts
                self._pending_submit.append((inner, routed))
            else:
                self._place(inner, routed, target)
        if target is not None:
            target.wake()
        return rid

    def _make_inner(self, routed: RoutedRequest, prompt,
                    max_new: int) -> Request:
        inner = Request(routed.rid, np.asarray(prompt, np.int32), max_new,
                        aux_inputs=routed.aux_inputs,
                        cond_fp=routed.cond_fp, priority=routed.priority,
                        tpot_deadline_s=routed.tpot_deadline_s)
        inner.submit_t = routed.submit_t
        # TTFT only binds until the first token was DELIVERED — a failover
        # re-prefill after first-token must not re-arm the deadline
        if routed.first_token_t is None:
            inner.ttft_deadline = routed.ttft_deadline
        routed.inner = inner
        return inner

    def _place(self, inner: Request, routed: RoutedRequest, target: Worker):
        decode_ready = (inner.spilled is not None
                        and inner.spill_meta["length"] >= len(inner.prompt))
        routed.phase = "decode" if decode_ready else "prefill"
        routed.where = target.name
        if decode_ready:
            self._maybe_adopt_rng(routed, target)
        target.cb.submit_request(inner)
        if routed.paused:
            target.cb.pause(inner.rid)
        target.wake()

    # ---- target selection ---------------------------------------------
    def _alive(self, workers: List[Worker]) -> List[Worker]:
        return [w for w in workers if w.alive]

    def _least_loaded(self, workers: List[Worker]) -> Optional[Worker]:
        if not workers:
            return None
        return min(workers, key=lambda w: (len(w.cb.queue)
                                           + int(w.cb.active.sum())))

    def _ingest_target(self) -> Optional[Worker]:
        cand = self._alive(self.prefill_workers)
        if not cand or self.mode == "unified":
            cand = cand or self._alive(self.workers)
        return self._least_loaded(cand)

    def _decode_target(self) -> Optional[Worker]:
        cand = self._alive(self.decode_workers)
        if not cand or self.mode == "unified":
            cand = cand or self._alive(self.workers)
        return self._least_loaded(cand)

    def _decode_free_pages(self) -> int:
        if self.pool is not None:
            return len(self.pool.free_pages)
        alive = self._alive(self.decode_workers) or self.decode_workers
        return max(len(w.cb.free_pages) for w in alive)

    # ---- flow control / cancellation ----------------------------------
    def cancel(self, rid: int) -> bool:
        with self._lock:
            routed = self.requests.get(rid)
            if routed is None or routed.finished:
                return False
            self._cancel_pending.add(rid)
        self.wake()
        return True

    def pause(self, rid: int):
        with self._lock:
            routed = self.requests.get(rid)
            if routed is None:
                return
            routed.paused = True
            w = self._by_name.get(routed.where)
        if w is not None:
            w.cb.pause(rid)

    def resume(self, rid: int):
        with self._lock:
            routed = self.requests.get(rid)
            if routed is None:
                return
            routed.paused = False
            w = self._by_name.get(routed.where)
        if w is not None:
            w.cb.resume(rid)
            w.wake()

    # ---- worker callbacks (engine threads) -----------------------------
    def _worker_tokens(self, worker: Worker, req: Request, toks: List[int]):
        with self._lock:
            routed = self.requests.get(req.rid)
            if routed is None or routed.finished:
                return
            if routed.first_token_t is None and toks:
                routed.first_token_t = time.time()
            routed.out.extend(toks)
            cb = self.token_cb
        if cb is not None:
            cb(routed, toks)

    def _worker_finish(self, worker: Worker, req: Request):
        with self._lock:
            routed = self.requests.get(req.rid)
            if routed is None or routed.finished:
                return
            if routed.inner is not req:
                return               # a superseded inner (failover race)
            routed.cancelled = routed.cancelled or req.cancelled
            routed.deadline_blown = routed.deadline_blown or \
                req.deadline_blown
            routed.error = routed.error or req.error
            routed.preempt_count += req.preempt_count
            routed.shared_tokens += req.shared_tokens
            # an abort/cancel can finish an inner while its payload is
            # still attached (it died in a worker queue) — nothing to free
            # beyond what the worker already dropped
            self._note_service(time.time() - routed.submit_t)
            self._finish_routed(routed)

    def _finish_routed(self, routed: RoutedRequest):
        """Terminal bookkeeping + frontend notification. Callable from any
        thread; idempotence is the caller's job (checked under _lock)."""
        routed.finished = True
        routed.phase = "done"
        routed.inner = None
        routed.where = None
        self.completed += 1
        cb = self.finish_cb
        if cb is not None:
            cb(routed)

    # ---- payload plumbing (tick thread; pool locks, NOT router lock) ---
    def _drop_payload(self, inner: Request):
        """Release an in-router migration payload: page-handle refs return
        to the shared pool, host snapshots drop."""
        cb = self.workers[0].cb
        with cb._pool_lock:
            cb._drop_payload(inner)

    def _re_prefill(self, routed: RoutedRequest, *, count_retry=False):
        """Last-resort recovery: rebuild the request from its delivered
        tokens. Greedy decoding makes the continuation exact: the new
        prompt is (original prompt + delivered tokens), max_new is the
        remainder — prefix caching turns the replay into a page-map when
        configured. Called with NO locks held."""
        with self._lock:
            if routed.finished:
                return
            delivered = list(routed.out)
            remaining = routed.max_new - len(delivered)
            if remaining <= 0:
                self._finish_routed(routed)
                return
            prompt = np.concatenate(
                [routed.prompt, np.asarray(delivered, np.int32)]) \
                if delivered else routed.prompt
            inner = self._make_inner(routed, prompt, remaining)
            self.re_prefills += 1
            target = self._ingest_target()
            if target is None:
                routed.phase = "prefill"
                routed.where = None
                self._pending_submit.append((inner, routed))
            else:
                self._place(inner, routed, target)
        if target is not None:
            target.wake()

    # ---- the tick loop --------------------------------------------------
    def _main(self):
        while not self._stopping.is_set():
            try:
                self._collect_ready()
                self._send_handoffs()
                self._check_workers()
                self._update_mode()
                self._apply_cancels()
                self._flush_pending()
            except Exception:        # noqa: BLE001 — the router must outlive
                import traceback     # any single tick's surprise
                traceback.print_exc()
            time.sleep(self.tick_s)

    def _collect_ready(self):
        """Drain boundary-spilled requests off every prefill worker into
        the handoff queue (dead requests drop their payload instead)."""
        drops = []
        for w in self.prefill_workers:
            for inner in w.cb.drain_ready():
                with self._lock:
                    routed = self.requests.get(inner.rid)
                    live = (routed is not None and not routed.finished
                            and routed.inner is inner)
                    if live:
                        routed.phase = "handoff"
                        routed.where = None
                        self._handoffs.append(_Handoff(
                            inner, routed, due=time.time()))
                if not live:
                    drops.append(inner)
        for inner in drops:
            self._drop_payload(inner)

    def _send_handoffs(self):
        """Deliver due handoffs to decode workers, with the three failure
        modes: drop (payload lost -> re-prefill), stall past the timeout
        (payload retained -> bounded backoff retry -> re-prefill), ok."""
        now = time.time()
        with self._lock:
            due, keep = [], collections.deque()
            while self._handoffs:
                h = self._handoffs.popleft()
                (due if h.due <= now else keep).append(h)
            self._handoffs = keep
        for h in due:
            with self._lock:
                if h.routed.finished or h.routed.inner is not h.inner:
                    dead = True
                else:
                    dead = False
            if dead:
                self._drop_payload(h.inner)
                continue
            target = self._decode_target()
            if target is None:       # nowhere to send: wait for a restart
                with self._lock:
                    self._handoffs.append(h)
                continue
            verdict = self._send(h, target)
            if verdict == "ok":
                with self._lock:
                    self.migrations += 1
                    h.inner.migrations += 1
                    h.routed.migrations += 1
                    h.routed.phase = "decode"
                    h.routed.where = target.name
                    paused = h.routed.paused
                if paused:
                    target.cb.pause(h.inner.rid)
                target.wake()
            elif verdict == "lost":
                with self._lock:
                    self.handoff_drops += 1
                self._drop_payload(h.inner)
                self._re_prefill(h.routed)
            else:                    # timeout: payload retained
                h.attempts += 1
                with self._lock:
                    self.handoff_retries += 1
                if h.attempts > self.handoff_max_retries:
                    self._drop_payload(h.inner)
                    self._re_prefill(h.routed)
                else:
                    h.due = time.time() + (self.handoff_backoff_s
                                           * 2 ** (h.attempts - 1))
                    with self._lock:
                        self._handoffs.append(h)

    def _send(self, h: _Handoff, target: Worker) -> str:
        if self.faults is not None and self.faults.fire("handoff_drop"):
            return "lost"
        t0 = time.time()
        if self.faults is not None:
            self.faults.maybe_sleep("handoff_stall")
        if time.time() - t0 > self.handoff_timeout_s:
            return "timeout"
        with self._lock:             # adopt BEFORE the engine can step
            self._maybe_adopt_rng(h.routed, target)
        target.cb.submit_request(h.inner)
        return "ok"

    def _maybe_adopt_rng(self, routed: RoutedRequest, target: Worker):
        """One-shot rng handover: an IDLE receiving engine adopts the dead
        worker's decode stream so the failed-over continuation is exact; a
        busy receiver keeps its own stream (adopting would perturb its
        current tenants)."""
        if routed.resume_rng is None:
            return
        cb = target.cb
        if not cb.active.any() and not cb.queue:
            target.runner.rng = routed.resume_rng
        routed.resume_rng = None

    def _check_workers(self):
        """Heartbeat sweep: harvest dead workers and fail their in-flight
        work over; restart them after ``restart_dead_after_s``."""
        now = time.time()
        for w in self.workers:
            if not w.started or self._stopping.is_set():
                continue
            r = w.runner
            dead = r.died or (not r._thread.is_alive())
            if dead and not w.failed_over:
                w.failed_over = True
                if self.restart_dead_after_s is not None:
                    w.restart_at = now + self.restart_dead_after_s
                self._failover(w)
            if (w.failed_over and w.restart_at is not None
                    and now >= w.restart_at
                    and not r._thread.is_alive()):
                w.restart()

    def _failover(self, worker: Worker):
        """Harvest a dead worker's batcher and re-route every survivor.
        Payload-intact requests (queued with an unrestored payload, or
        detached page handles on a shared pool) re-migrate without replay;
        requests whose device KV died with the worker re-prefill from
        prompt + delivered tokens."""
        worker.join_dead(2.0)
        # worker_die raises at the top of _step, before the aborted step
        # consumed any rng — the runner's rng IS the exact resume state of
        # this worker's decode stream. Prefill-role streams in split mode
        # were never consumed, so only decode/unified streams travel.
        resume_rng = (worker.runner.rng
                      if worker.role == "decode" or self.mode == "unified"
                      else None)
        # on a shared pool the KV physically survives the worker: detach
        # active slots into page handles instead of discarding them
        harvested = worker.cb.extract_all(detach=(self.handoff == "pages"))
        if isinstance(worker.cb, PrefillBatcher):
            harvested.extend(worker.cb.drain_ready())
        replays = []
        for inner in harvested:
            with self._lock:
                routed = self.requests.get(inner.rid)
                if (routed is None or routed.finished
                        or routed.inner is not inner):
                    drop = True
                else:
                    drop = False
                    self.failovers += 1
                    routed.failovers += 1
                    inner.failovers += 1
                    routed.preempt_count += inner.preempt_count
                    inner.preempt_count = 0
                    routed.resume_rng = resume_rng
                    if inner.spilled is not None:
                        # payload intact: still mid-prefill -> back to a
                        # prefill worker (restore + continue committing);
                        # decode-ready -> the handoff queue
                        if (inner.spill_meta["length"]
                                >= len(inner.prompt)):
                            routed.phase = "handoff"
                            routed.where = None
                            self._handoffs.append(_Handoff(
                                inner, routed, due=time.time()))
                        else:
                            target = self._ingest_target()
                            if target is None:
                                self._pending_submit.append((inner, routed))
                            else:
                                self._place(inner, routed, target)
                    else:
                        replays.append(routed)
            if drop:
                self._drop_payload(inner)
        for routed in replays:
            self._re_prefill(routed)

    def _update_mode(self):
        """Degrade to unified when one role has no survivors; re-split when
        both do. Mode flips only change where NEW work lands plus the
        ``boundary_spill`` flag — requests in flight migrate themselves."""
        p_alive = bool(self._alive(self.prefill_workers))
        d_alive = bool(self._alive(self.decode_workers))
        with self._lock:
            if self.mode == "split" and p_alive != d_alive:
                self.mode = "unified"
                self.degradations += 1
                flip = False
            elif self.mode == "unified" and p_alive and d_alive:
                self.mode = "split"
                self.resplits += 1
                flip = True
            else:
                return
        for w in self.prefill_workers:
            w.cb.boundary_spill = flip
            w.wake()

    def _apply_cancels(self):
        with self._lock:
            pending = list(self._cancel_pending)
        for rid in pending:
            drop_inner = None
            with self._lock:
                routed = self.requests.get(rid)
                if routed is None or routed.finished:
                    self._cancel_pending.discard(rid)
                    continue
                if routed.phase == "handoff":
                    self._handoffs = collections.deque(
                        h for h in self._handoffs if h.inner.rid != rid)
                    drop_inner = routed.inner
                    routed.cancelled = True
                    self._finish_routed(routed)
                    self._cancel_pending.discard(rid)
                    w = None
                elif routed.where is None:
                    # parked while no worker was alive: cancel it here
                    self._pending_submit = collections.deque(
                        (i, r) for i, r in self._pending_submit
                        if r.rid != rid)
                    drop_inner = routed.inner
                    routed.cancelled = True
                    self._finish_routed(routed)
                    self._cancel_pending.discard(rid)
                    w = None
                else:
                    w = self._by_name.get(routed.where)
            if drop_inner is not None:
                self._drop_payload(drop_inner)
            elif w is not None:
                # retried every tick until the worker's finish lands (the
                # request may be mid-migration when the cancel arrives)
                w.cb.cancel(rid)
                w.wake()

    def _flush_pending(self):
        """Re-route submissions parked while no worker was alive."""
        with self._lock:
            if not self._pending_submit:
                return
            parked, self._pending_submit = (list(self._pending_submit),
                                            collections.deque())
            for inner, routed in parked:
                if routed.finished:
                    continue
                target = (self._ingest_target()
                          if inner.spilled is None
                          or inner.spill_meta["length"] < len(inner.prompt)
                          else self._decode_target())
                if target is None:
                    self._pending_submit.append((inner, routed))
                else:
                    self._place(inner, routed, target)
                    target.wake()

    # ---- health ---------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            inflight = sum(1 for r in self.requests.values()
                           if not r.finished)
            pending_handoffs = len(self._handoffs)
        return {
            "router": True,
            "mode": self.mode,
            "handoff": self.handoff,
            "inflight": inflight,
            "completed": self.completed,
            "pending_handoffs": pending_handoffs,
            "migrations": self.migrations,
            "failovers": self.failovers,
            "handoff_retries": self.handoff_retries,
            "handoff_drops": self.handoff_drops,
            "re_prefills": self.re_prefills,
            "degradations": self.degradations,
            "resplits": self.resplits,
            "shared_pool_free": (len(self.pool.free_pages)
                                 if self.pool is not None else None),
            "workers": [w.stats() for w in self.workers],
        }


class RouterRunner(EngineRunner):
    """``EngineRunner``-shaped facade over a ``DisaggRouter`` for the HTTP
    frontend: no engine thread of its own (the router runs its workers and
    tick loop), but the same ``TokenStream`` attach/orphan bookkeeping —
    ``EngineRunner.__init__`` wires ``router.token_cb`` to the inherited
    ``_on_tokens`` and this subclass wires ``router.finish_cb`` to the
    inherited ``_finish``."""

    def __init__(self, router: DisaggRouter, rng=None,
                 max_restarts: int = 3):
        super().__init__(router, rng=rng, max_restarts=max_restarts,
                         name="router-facade")
        router.finish_cb = self._finish

    def start(self):
        self.cb.start()

    def wake(self):
        self.cb.wake()

    def stop(self, timeout: Optional[float] = None):
        self.cb.stop(timeout if timeout is not None else 60.0)
