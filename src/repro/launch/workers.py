"""Disaggregated serving workers: prefill-only batchers and the supervised
worker wrapper the router manages.

One ``ContinuousBatcher`` interleaves prompt ingest with decode on a single
pool — a long prompt steals one chunk dispatch from every decode segment,
and the whole engine is one point of failure. Disaggregation (Orca-style
iteration scheduling split across roles) gives each concern its own engine:

  PrefillBatcher  a ``ContinuousBatcher`` that stops at the prefill/decode
                  BOUNDARY: the moment a slot's prompt is fully committed it
                  spills to a migration payload (``Request.spilled`` host
                  snapshot, or ``Request.handoff_pages`` page handles on a
                  ``SharedPagePool``) and lands in ``ready`` for the router
                  to move to a decode worker. The spilled slot never enters
                  a decode segment, so migration is rng-neutral by
                  construction — the decode worker's scan sees exactly the
                  state an uninterrupted run would have had.
  Worker          one supervised engine: a batcher + a ``WorkerRunner``
                  thread (``EngineRunner`` with router callbacks instead of
                  per-request ``TokenStream``s), a role tag, a heartbeat,
                  and ``restart()`` for bringing a (simulated-)dead worker
                  back over the same batcher.

``WorkerDied`` (the ``worker_die`` chaos hook) is FATAL to a worker: the
engine thread exits without recovery and without erroring its streams — a
dead process cannot apologize. The router's heartbeat sweep notices the
death, harvests the batcher (``extract_all``), and fails the survivors over
(``repro.launch.router``).

Degraded (unified) mode: flipping ``PrefillBatcher.boundary_spill`` off
makes it a plain continuous batcher again — prefill AND decode on one
engine — which is how the router keeps serving when one role has no
survivors. Re-enabling it mid-flight is safe: slots already decoding simply
hit the boundary condition (``lengths >= plens``) on the next step and
migrate out like freshly-prefilled ones.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, List, Optional

import jax

from repro.launch.faults import WorkerDied
from repro.launch.serve import ContinuousBatcher, Request
from repro.launch.server import EngineRunner


class PrefillBatcher(ContinuousBatcher):
    """A ``ContinuousBatcher`` that spills requests at the prefill/decode
    boundary instead of decoding them.

    ``handoff="copy"``  boundary spill = ``_spill_slot``: USED page content
                        + dense per-slot rows snapshot to host, pages return
                        to THIS worker's pool; the decode worker restores
                        into its own free pages (byte-copy migration —
                        works across genuinely separate pools).
    ``handoff="pages"`` boundary spill = ``_detach_slot``: only dense rows
                        snapshot; the physical pages (and their refs) travel
                        with the request. Requires every sharing batcher to
                        sit on one ``SharedPagePool``.

    Boundary-spilled requests are parked in ``ready`` (thread-safe deque);
    the router drains it with ``drain_ready()``. They are excluded from
    decode segments via the paused mask BEFORE the spill happens, so not a
    single decode step ever runs on the prefill side — the migrated
    request's greedy continuation is bit-identical to an uninterrupted run.
    """

    def __init__(self, dbm, params, *, handoff: str = "copy", **kw):
        super().__init__(dbm, params, **kw)
        if not self.chunked:
            raise ValueError(
                "PrefillBatcher requires prefill='chunked': per-token mode "
                "commits prompt tokens inside decode segments, so there is "
                "no clean prefill/decode boundary to spill at")
        if handoff not in ("copy", "pages"):
            raise ValueError(f"handoff must be 'copy' or 'pages', "
                             f"got {handoff!r}")
        if handoff == "pages" and self._shared is None:
            raise ValueError("handoff='pages' moves page handles, which "
                             "only mean something on a SharedPagePool — "
                             "construct every worker with shared_pool=...")
        self.handoff = handoff
        self.boundary_spill = True     # False = degraded unified mode
        self.ready: collections.deque = collections.deque()
        self.migrated_out = 0          # boundary spills produced

    def _paused_mask(self):
        m = super()._paused_mask()
        if self.boundary_spill:
            # prefill-complete slots never decode here — they are about to
            # spill out (this also keeps the boundary rng-neutral: no decode
            # dispatch ever includes them)
            m = m | (self.active & (self.lengths >= self.plens))
        return m

    def drain_ready(self) -> List[Request]:
        """Pop every boundary-spilled request (router thread)."""
        out = []
        while True:
            try:
                out.append(self.ready.popleft())
            except IndexError:
                return out

    def _step(self, rng, *, strict: bool = True):
        rng, finished = super()._step(rng, strict=strict)
        if self.boundary_spill:
            for s in range(self.num_slots):
                if (self.slot_req[s] is not None and self.active[s]
                        and self.lengths[s] >= self.plens[s]):
                    req = (self._detach_slot(s) if self.handoff == "pages"
                           else self._spill_slot(s))
                    self.migrated_out += 1
                    self.ready.append(req)
        return rng, finished


class WorkerRunner(EngineRunner):
    """``EngineRunner`` for a router-managed worker: per-request
    ``TokenStream`` plumbing is replaced by two router callbacks
    (``on_tokens`` / ``on_finish``) and ``WorkerDied`` is FATAL — the
    thread exits without recovery or stream cleanup; the router's
    heartbeat check owns what happens next."""

    def __init__(self, batcher, *, rng=None, max_restarts: int = 3,
                 name: str = "worker",
                 on_tokens: Optional[Callable] = None,
                 on_finish: Optional[Callable] = None):
        self._cb_tokens = on_tokens
        self._cb_finish = on_finish
        super().__init__(batcher, rng=rng, max_restarts=max_restarts,
                         fatal_types=(WorkerDied,), name=name)

    def _on_tokens(self, req: Request, toks: List[int]):
        if self._cb_tokens is not None:
            self._cb_tokens(req, toks)

    def _finish(self, req: Request):
        self.served += 1
        if self._cb_finish is not None:
            self._cb_finish(req)


class Worker:
    """One supervised serving worker: a batcher, its engine thread, a role
    tag and liveness surface for the router.

    ``alive`` is the router's routing predicate: the engine thread is
    running, has not hit a fatal fault (``died``) and has not exhausted its
    crash budget (``gave_up``). ``restart()`` builds a FRESH supervised
    thread over the same batcher — valid only after the old thread exited
    and the router harvested the batcher, which is exactly the failover
    sequence."""

    def __init__(self, name: str, role: str, batcher: ContinuousBatcher, *,
                 rng=None, max_restarts: int = 3,
                 on_tokens: Optional[Callable] = None,
                 on_finish: Optional[Callable] = None):
        assert role in ("prefill", "decode")
        self.name, self.role, self.cb = name, role, batcher
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._max_restarts = max_restarts
        self._on_tokens, self._on_finish = on_tokens, on_finish
        self.served_total = 0        # completed before the current runner
        self.restarts = 0            # post-death worker restarts
        self.started = False
        self.restart_at: Optional[float] = None   # router's restart timer
        self.failed_over = False     # current death already harvested
        self.runner = self._make_runner()

    def _make_runner(self) -> WorkerRunner:
        return WorkerRunner(self.cb, rng=self._rng,
                            max_restarts=self._max_restarts,
                            name=f"{self.role}:{self.name}",
                            on_tokens=self._on_tokens,
                            on_finish=self._on_finish)

    # ---- liveness ------------------------------------------------------
    @property
    def alive(self) -> bool:
        r = self.runner
        return (self.started and r._thread.is_alive()
                and not r.died and not r.gave_up)

    @property
    def heartbeat_age(self) -> float:
        return time.time() - self.runner.last_beat

    # ---- lifecycle -----------------------------------------------------
    def start(self):
        self.runner.start()
        self.started = True

    def wake(self):
        self.runner.wake()

    def stop(self, timeout: Optional[float] = None):
        self.runner.stop(timeout)

    def join_dead(self, timeout: float = 1.0):
        """Wait for a dying engine thread to fully exit before harvesting
        its batcher (it may still be inside ``step``'s unwind)."""
        if self.runner._thread.is_alive():
            self.runner._thread.join(timeout)

    def restart(self):
        """Fresh supervised engine thread over the same batcher. The old
        thread must be dead and the batcher harvested (``extract_all``) —
        the new loop starts from an empty queue; the rng continues from the
        old runner's last value so a restarted worker's sampling stream
        stays deterministic."""
        assert not self.runner._thread.is_alive(), \
            "restart() on a live worker — stop or kill it first"
        self.served_total += self.runner.served
        self._rng = self.runner.rng
        self.restarts += 1
        self.restart_at = None
        self.failed_over = False
        self.runner = self._make_runner()
        self.runner.start()
        self.started = True

    # ---- health --------------------------------------------------------
    def stats(self) -> dict:
        r = self.runner
        return {
            "name": self.name, "role": self.role, "alive": self.alive,
            "heartbeat_age_s": round(self.heartbeat_age, 3),
            "free_pages": len(self.cb.free_pages),
            "total_pages": self.cb.total_pages,
            # pool BYTES, mixed-dtype aware (int8 pages + fp32 scales)
            **self.cb.kv_stats(),
            "inflight": int(self.cb.active.sum()),
            "queued": len(self.cb.queue),
            "served": self.served_total + r.served,
            "crashes": r.crashes,
            "engine_restarts": r.restarts,
            "worker_restarts": self.restarts,
            "migrated_out": getattr(self.cb, "migrated_out", 0),
        }
