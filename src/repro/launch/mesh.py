"""Production meshes.

Single-pod TPU v5e: 16×16 = 256 chips, axes ("data", "model").
Multi-pod: 2 pods × 256 = 512 chips, axes ("pod", "data", "model"). The pod
axis carries either extra data parallelism (default) or DiffusionBlocks
BLOCK-parallelism (blocks are gradient-isolated, so the pod axis then needs
ZERO optimizer/gradient collectives — the paper's embarrassing parallelism
realized as a mesh axis; see launch/train.py --block-parallel).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU dev)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
