"""Deterministic fault injection — ONE injector shared by serving AND
training.

Chaos hooks let tests and the harnesses force the stack down its rare paths
— allocator exhaustion, engine-thread crashes, token-stream stalls, pod
deaths, NaN gradients, torn checkpoint writes — on a SEEDED schedule, so
every failure a test provokes is reproducible bit-for-bit. Hosts never
import randomness for this themselves: a ``FaultInjector`` is handed in
(``ContinuousBatcher(faults=...)``, ``EngineRunner``, ``TrainRunner``,
``CheckpointManager(faults=...)``) and consulted at named hook points; with
no injector (the default) every hook is a no-op costing one attribute check.

The injector is HOST-AGNOSTIC: hooks are plain names, nothing here knows
about batcher or trainer call sites. Any host consults any hook with the
same four consumption patterns — ``fire`` (boolean), ``maybe_raise``
(raise a configurable exception), ``maybe_sleep`` (latency), and
``maybe_corrupt`` (truncate a file, for torn-write simulation) — so serve
and train share one injector and one schedule namespace.

Hook names used by the serving stack:

  ``alloc_exhaust``   ``ContinuousBatcher._alloc_page`` pretends the pool is
                      empty (returns no page) — exercises preemption and the
                      CoW-failure paths without actually shrinking the pool.
  ``engine_crash``    raises ``InjectedFault`` at the top of
                      ``ContinuousBatcher.step`` — exercises the
                      ``EngineRunner`` supervisor restart + in-flight
                      requeue.
  ``token_stall``     sleeps inside token delivery — exercises client
                      timeout / slow-stream handling in the load harness.
  ``worker_die``      raises ``WorkerDied`` at the top of
                      ``ContinuousBatcher.step`` — unlike ``engine_crash``
                      the supervisor treats it as FATAL (simulated process
                      death, no restart); the disaggregation router must
                      detect the dead worker by heartbeat and fail its
                      in-flight requests over (``repro.launch.router``).
  ``handoff_drop``    the router loses a prefill→decode migration payload
                      in transit — exercises the re-prefill fallback.
  ``handoff_stall``   sleeps inside the router's handoff send (pair with
                      ``{"sleep": s}`` above the router's handoff timeout)
                      — exercises the bounded retry/backoff path.

Hook names used by the training stack (``repro.launch.trainrunner``):

  ``pod_die``         block-parallel: the supervisor marks the victim
                      block's pod dead (device state lost → rewind to the
                      last generation), degrades it to the round-robin
                      orphan path, and re-adopts it onto the mesh when the
                      pod revives. db mode has no pods: ``pod_die`` raises
                      ``PodDied`` = simulated PROCESS death → bounded
                      restart from the latest good generation.
  ``grad_nan``        poisons ONE block's loss with NaN for one batch (via
                      the engine's per-block ``loss_mult``) — exercises the
                      per-block anomaly guard: only that block's update is
                      skipped. Optional ``{"block": b}`` pins the victim
                      (default: rotate by fire count).
  ``data_stall``      sleeps inside the training data fetch — exercises the
                      supervisor's heartbeat/stall accounting.
  ``ckpt_corrupt``    ``CheckpointManager`` truncates one freshly written
                      file after publishing a generation — exercises the
                      checksum fallback to the previous manifest generation.

Each hook is configured with ONE trigger spec:

  {"p": 0.05}            fire independently with probability p per call
  {"every": 40}          fire on every 40th call (1-indexed)
  {"at": [3, 7]}         fire on exactly these call indices (1-indexed)

plus optional ``{"start": a, "stop": b}`` bounds on the call index window
(half-open: fires only while ``start <= index < stop``) and, for
``token_stall``, ``{"sleep": seconds}``. Per-hook call counters and a
per-hook ``RandomState`` stream make schedules independent: adding a spec
for one hook never shifts another hook's schedule.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by a chaos hook; distinguishable from organic failures so the
    supervisor and the tests can tell injected crashes from real bugs."""


class WorkerDied(InjectedFault):
    """A ``worker_die`` hook fired: the worker process is (simulated) dead.
    Supervisors must NOT restart on this — recovery is the router's job
    (heartbeat detection → failover), which is exactly what the fault
    exists to exercise."""


class PodDied(InjectedFault):
    """A ``pod_die`` hook fired: one training pod (block group) is
    (simulated) dead. The training supervisor must NOT treat this as an
    engine crash — the other blocks keep training; the orphaned block
    degrades to the round-robin path until the pod revives."""


class FaultInjector:
    """Seeded chaos-hook scheduler. ``fire(hook)`` advances that hook's call
    counter and reports whether the fault triggers this call; ``maybe_raise``
    and ``maybe_sleep`` are the common consumption patterns."""

    def __init__(self, specs: Dict[str, dict], seed: int = 0):
        for name, spec in specs.items():
            keys = {"p", "every", "at"} & set(spec)
            if len(keys) != 1:
                raise ValueError(
                    f"hook {name!r} needs exactly one of p/every/at, got "
                    f"{sorted(spec)}")
        self.specs = {k: dict(v) for k, v in specs.items()}
        self.seed = seed
        self.calls: Dict[str, int] = {k: 0 for k in specs}
        self.fired: Dict[str, int] = {k: 0 for k in specs}
        self._rs = {k: np.random.RandomState((seed * 9176 + i) % (2**31 - 1))
                    for i, k in enumerate(sorted(specs))}

    def fire(self, hook: str) -> bool:
        """Advance ``hook``'s schedule by one call; True when the fault
        triggers now. Unknown hooks never fire (and aren't counted)."""
        spec = self.specs.get(hook)
        if spec is None:
            return False
        self.calls[hook] = idx = self.calls[hook] + 1
        if not (spec.get("start", 0) <= idx < spec.get("stop", float("inf"))):
            return False
        if "p" in spec:
            hit = bool(self._rs[hook].rand() < spec["p"])
        elif "every" in spec:
            hit = idx % spec["every"] == 0
        else:
            hit = idx in spec["at"]
        if hit:
            self.fired[hook] += 1
        return hit

    def maybe_raise(self, hook: str, exc: type = InjectedFault) -> None:
        """Raise ``exc`` when the hook fires (``exc`` lets hosts signal
        distinguishable failure classes — e.g. ``PodDied`` — without the
        injector knowing their call sites)."""
        if self.fire(hook):
            raise exc(
                f"injected fault {hook!r} (call {self.calls[hook]})")

    def maybe_sleep(self, hook: str, default: float = 0.05) -> None:
        if self.fire(hook):
            time.sleep(float(self.specs[hook].get("sleep", default)))

    def maybe_corrupt(self, hook: str, path: str) -> bool:
        """Truncate ``path`` to half its size when the hook fires (torn-write
        simulation); True when corruption happened. The file must exist."""
        if not self.fire(hook):
            return False
        import os
        with open(path, "r+b") as f:
            f.truncate(max(0, os.path.getsize(path) // 2))
        return True

    def stats(self) -> Dict[str, dict]:
        return {k: {"calls": self.calls[k], "fired": self.fired[k]}
                for k in self.specs}


def make_injector(specs: Optional[Dict[str, dict]], seed: int = 0):
    """None-tolerant constructor: ``make_injector(None)`` returns None so the
    engine's hot path stays a plain ``if self.faults`` check."""
    return FaultInjector(specs, seed) if specs else None
