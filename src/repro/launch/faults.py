"""Deterministic fault injection for the serving engine.

Chaos hooks let tests and the load harness force the engine down its rare
paths — allocator exhaustion, engine-thread crashes, token-stream stalls —
on a SEEDED schedule, so every failure a test provokes is reproducible
bit-for-bit. The engine never imports randomness for this itself: a
``FaultInjector`` is handed to ``ContinuousBatcher(faults=...)`` /
``EngineRunner`` and consulted at named hook points; with no injector (the
default) every hook is a no-op costing one attribute check.

Hook names used by the serving stack:

  ``alloc_exhaust``   ``ContinuousBatcher._alloc_page`` pretends the pool is
                      empty (returns no page) — exercises preemption and the
                      CoW-failure paths without actually shrinking the pool.
  ``engine_crash``    raises ``InjectedFault`` at the top of
                      ``ContinuousBatcher.step`` — exercises the
                      ``EngineRunner`` supervisor restart + in-flight
                      requeue.
  ``token_stall``     sleeps inside token delivery — exercises client
                      timeout / slow-stream handling in the load harness.
  ``worker_die``      raises ``WorkerDied`` at the top of
                      ``ContinuousBatcher.step`` — unlike ``engine_crash``
                      the supervisor treats it as FATAL (simulated process
                      death, no restart); the disaggregation router must
                      detect the dead worker by heartbeat and fail its
                      in-flight requests over (``repro.launch.router``).
  ``handoff_drop``    the router loses a prefill→decode migration payload
                      in transit — exercises the re-prefill fallback.
  ``handoff_stall``   sleeps inside the router's handoff send (pair with
                      ``{"sleep": s}`` above the router's handoff timeout)
                      — exercises the bounded retry/backoff path.

Each hook is configured with ONE trigger spec:

  {"p": 0.05}            fire independently with probability p per call
  {"every": 40}          fire on every 40th call (1-indexed)
  {"at": [3, 7]}         fire on exactly these call indices (1-indexed)

plus optional ``{"start": a, "stop": b}`` bounds on the call index window
(half-open: fires only while ``start <= index < stop``) and, for
``token_stall``, ``{"sleep": seconds}``. Per-hook call counters and a
per-hook ``RandomState`` stream make schedules independent: adding a spec
for one hook never shifts another hook's schedule.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by a chaos hook; distinguishable from organic failures so the
    supervisor and the tests can tell injected crashes from real bugs."""


class WorkerDied(InjectedFault):
    """A ``worker_die`` hook fired: the worker process is (simulated) dead.
    Supervisors must NOT restart on this — recovery is the router's job
    (heartbeat detection → failover), which is exactly what the fault
    exists to exercise."""


class FaultInjector:
    """Seeded chaos-hook scheduler. ``fire(hook)`` advances that hook's call
    counter and reports whether the fault triggers this call; ``maybe_raise``
    and ``maybe_sleep`` are the common consumption patterns."""

    def __init__(self, specs: Dict[str, dict], seed: int = 0):
        for name, spec in specs.items():
            keys = {"p", "every", "at"} & set(spec)
            if len(keys) != 1:
                raise ValueError(
                    f"hook {name!r} needs exactly one of p/every/at, got "
                    f"{sorted(spec)}")
        self.specs = {k: dict(v) for k, v in specs.items()}
        self.seed = seed
        self.calls: Dict[str, int] = {k: 0 for k in specs}
        self.fired: Dict[str, int] = {k: 0 for k in specs}
        self._rs = {k: np.random.RandomState((seed * 9176 + i) % (2**31 - 1))
                    for i, k in enumerate(sorted(specs))}

    def fire(self, hook: str) -> bool:
        """Advance ``hook``'s schedule by one call; True when the fault
        triggers now. Unknown hooks never fire (and aren't counted)."""
        spec = self.specs.get(hook)
        if spec is None:
            return False
        self.calls[hook] = idx = self.calls[hook] + 1
        if not (spec.get("start", 0) <= idx < spec.get("stop", float("inf"))):
            return False
        if "p" in spec:
            hit = bool(self._rs[hook].rand() < spec["p"])
        elif "every" in spec:
            hit = idx % spec["every"] == 0
        else:
            hit = idx in spec["at"]
        if hit:
            self.fired[hook] += 1
        return hit

    def maybe_raise(self, hook: str) -> None:
        if self.fire(hook):
            raise InjectedFault(
                f"injected fault {hook!r} (call {self.calls[hook]})")

    def maybe_sleep(self, hook: str, default: float = 0.05) -> None:
        if self.fire(hook):
            time.sleep(float(self.specs[hook].get("sleep", default)))

    def stats(self) -> Dict[str, dict]:
        return {k: {"calls": self.calls[k], "fired": self.fired[k]}
                for k in self.specs}


def make_injector(specs: Optional[Dict[str, dict]], seed: int = 0):
    """None-tolerant constructor: ``make_injector(None)`` returns None so the
    engine's hot path stays a plain ``if self.faults`` check."""
    return FaultInjector(specs, seed) if specs else None
