"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh with 512 placeholder host devices, and extract the
roofline terms from the compiled artifact.

MUST set XLA_FLAGS before any other import — jax locks the device count on
first init. Do NOT set this anywhere global (tests/benches must see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
      --shape train_4k [--multi-pod] [--mode db|e2e] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import os

if "--real-devices" not in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
# true trip-count FLOPs in cost analysis + per-layer activation remat
os.environ.setdefault("REPRO_SCAN_UNROLL", "1")
os.environ.setdefault("REPRO_LAYER_REMAT", "1")
os.environ.setdefault("REPRO_ATTN_CHUNK", "4096")

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import configs                                    # noqa: E402
from repro.configs import DBConfig, INPUT_SHAPES, get_config, get_shape  # noqa: E402
from repro.configs.base import TrainConfig                   # noqa: E402
from repro.core import DiffusionBlocksModel                  # noqa: E402
from repro.core.training import (extract_block_view,         # noqa: E402
                                 make_db_train_step, make_e2e_train_step)
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.optim import adamw                                # noqa: E402
from repro.roofline import analysis as RA                    # noqa: E402
from repro.sharding import (cache_sharding, param_shardings,  # noqa: E402
                            replicated, tokens_sharding)
from repro.sharding.rules import zero1_shardings  # noqa: E402

DTYPE = jnp.bfloat16


def input_specs(dbm, shape, dtype=DTYPE):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).
    Aux conditioning specs come from the model's own frontend declaration
    (``model.aux_input_specs``) — the ONE code path shared with the
    training losses and the batched serving engine."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    specs.update(dbm.model.aux_input_specs(B, dtype) or {})
    return specs


def aux_specs(dbm, batch, dtype=DTYPE):
    return dbm.model.aux_input_specs(batch, dtype)


def aux_shardings(dbm, mesh, batch):
    aux = aux_specs(dbm, batch)
    if aux is None:
        return None
    return {k: tokens_sharding(mesh, batch) for k in aux}


def set_unroll(on: bool) -> None:
    os.environ["REPRO_SCAN_UNROLL"] = "1" if on else "0"


# scans with more units than this use the 1-vs-2-unit probe extrapolation
# (XLA counts a rolled loop body once; fully unrolling 64-layer MoE stacks is
# compile-prohibitive on this 1-core container — see EXPERIMENTS.md §Dry-run)
PROBE_THRESHOLD = 2


def lower_train(dbm, shape, mesh, mode: str, block: int = 0,
                unit_range=None):
    cfg = dbm.cfg
    tcfg = TrainConfig(steps=1000)
    model = dbm.model
    abs_params = model.abstract_params(DTYPE)
    axes = model.axes()
    p_shard = param_shardings(axes, mesh, abs_params)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                  jnp.int32)
    t_shard = tokens_sharding(mesh, shape.global_batch)
    rng = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    aux = aux_specs(dbm, shape.global_batch)
    a_shard = aux_shardings(dbm, mesh, shape.global_batch)

    if mode == "db":
        init_opt, step = make_db_train_step(dbm, block, tcfg, jit=False,
                                            impl="chunked",
                                            unit_range=unit_range)
        opt_abs = jax.eval_shape(init_opt, abs_params)
        start, size = (unit_range if unit_range is not None
                       else dbm.ranges[block])
        view_axes = {k: axes[k] for k in axes}
        view_abs = jax.eval_shape(
            lambda p: extract_block_view(p, start, size), abs_params)
        if os.environ.get("REPRO_ZERO1", "0") == "1":   # §Perf P1
            view_shard = zero1_shardings(view_axes, mesh, view_abs)
        else:
            view_shard = param_shardings(view_axes, mesh, view_abs)
        opt_shard = type(opt_abs)(replicated(mesh), view_shard, view_shard)
    else:
        init_opt, step = make_e2e_train_step(dbm, tcfg, jit=False,
                                             impl="chunked")
        opt_abs = jax.eval_shape(init_opt, abs_params)
        opt_shard = type(opt_abs)(replicated(mesh), p_shard, p_shard)

    fn = jax.jit(step, in_shardings=(p_shard, opt_shard, t_shard,
                                     replicated(mesh),
                                     a_shard))
    with mesh:
        lowered = fn.lower(abs_params, opt_abs, tokens, rng, aux)
        compiled = lowered.compile()
    return lowered, compiled


def lower_prefill(dbm, shape, mesh, probe_k=None):
    cfg = dbm.cfg
    model = dbm.model
    abs_params = model.abstract_params(DTYPE)
    p_shard = param_shardings(model.axes(), mesh, abs_params)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                  jnp.int32)
    t_shard = tokens_sharding(mesh, shape.global_batch)
    aux = aux_specs(dbm, shape.global_batch)
    a_shard = aux_shardings(dbm, mesh, shape.global_batch)

    if probe_k is not None:
        def prefill(params, tokens, aux):
            return dbm.prefill_probe(params, tokens, probe_k,
                                     aux_inputs=aux, impl="chunked")
    else:
        def prefill(params, tokens, aux):
            return dbm.prefill(params, tokens, aux_inputs=aux,
                               impl="chunked")

    fn = jax.jit(prefill, in_shardings=(p_shard, t_shard, a_shard))
    with mesh:
        lowered = fn.lower(abs_params, tokens, aux)
        compiled = lowered.compile()
    return lowered, compiled


def _probe_dbm(dbm, k: int):
    """A DiffusionBlocksModel view whose single block covers units [0, k)."""
    import copy
    d2 = copy.copy(dbm)
    d2.ranges = [(0, k)]
    import dataclasses as _dc
    d2.db = _dc.replace(dbm.db, num_blocks=1)
    return d2


def lower_decode(dbm, shape, mesh):
    cfg = dbm.cfg
    model = dbm.model
    abs_params = model.abstract_params(DTYPE)
    p_shard = param_shardings(model.axes(), mesh, abs_params)
    B = shape.global_batch
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, DTYPE))
    c_shard = cache_sharding(mesh, cache_abs, B)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    rng = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    aux = aux_specs(dbm, B)
    a_shard = aux_shardings(dbm, mesh, B)

    def serve(params, cache, pos, rng, aux):
        return dbm.serve_step(params, cache, pos, rng, aux_inputs=aux)

    fn = jax.jit(serve, in_shardings=(p_shard, c_shard, replicated(mesh),
                                      replicated(mesh), a_shard))
    with mesh:
        lowered = fn.lower(abs_params, cache_abs, pos, rng, aux)
        compiled = lowered.compile()
    return lowered, compiled


def run_one(arch: str, shape_name: str, multi_pod: bool, mode: str,
            out_dir: str, num_blocks: int = 4, verbose: bool = True,
            mesh_shape=None, reduce_cfg: bool = False, shape_override=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name) if shape_override is None else shape_override
    if reduce_cfg:
        cfg = configs.reduced(cfg)
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch: unbounded 500k KV cache "
                          "(see DESIGN.md shape applicability)"}
    if mesh_shape is not None:
        axes = ("pod", "data", "model") if len(mesh_shape) == 3 else \
            ("data", "model")
        mesh = jax.make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    # at least 1 unit per block
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(num_blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)

    mf = RA.model_flops(cfg, shape, db_concat=(shape.kind == "train"
                                               and mode == "db"))
    if shape.kind == "train" and mode == "db":
        mf = mf / db.num_blocks        # block step: fwd+bwd of 1/B of stack
    chips = mesh.devices.size
    n_units = dbm.model.n_units
    block_size = dbm.ranges[0][1]
    t0 = time.time()

    no_probes = os.environ.get("REPRO_NO_PROBES", "0") == "1"
    if no_probes:
        # compile-proof only (multi-pod pass): rolled scans, fast compile;
        # roofline terms for the table come from the single-pod probed runs.
        set_unroll(False)
        if shape.kind == "train":
            lowered, compiled = lower_train(dbm, shape, mesh, mode)
        elif shape.kind == "prefill":
            lowered, compiled = lower_prefill(dbm, shape, mesh)
        else:
            lowered, compiled = lower_decode(dbm, shape, mesh)
        rec = RA.analyze(compiled, model_flops_per_step=mf, chips=chips)
        rec["rolled_only"] = True
    elif shape.kind == "train":
        scope = block_size if mode == "db" else n_units
        if scope <= PROBE_THRESHOLD:
            set_unroll(True)
            lowered, compiled = lower_train(dbm, shape, mesh, mode)
            rec = RA.analyze(compiled, model_flops_per_step=mf, chips=chips)
        else:
            set_unroll(False)   # full-size compile: memory proof
            lowered, compiled = lower_train(dbm, shape, mesh, mode)
            mem_rec = RA.analyze(compiled, chips=chips)
            set_unroll(True)    # 1- and 2-unit probes: exact costs
            _, c1 = lower_train(dbm, shape, mesh, mode, unit_range=(0, 1))
            _, c2 = lower_train(dbm, shape, mesh, mode, unit_range=(0, 2))
            r1 = RA.analyze(c1, chips=chips)
            r2 = RA.analyze(c2, model_flops_per_step=mf, chips=chips)
            rec = RA.extrapolate(r1, r2, scope, mem_rec)
    elif shape.kind == "prefill":
        if n_units <= PROBE_THRESHOLD:
            set_unroll(True)
            lowered, compiled = lower_prefill(dbm, shape, mesh)
            rec = RA.analyze(compiled, model_flops_per_step=mf, chips=chips)
        else:
            set_unroll(False)
            lowered, compiled = lower_prefill(dbm, shape, mesh)
            mem_rec = RA.analyze(compiled, chips=chips)
            set_unroll(True)
            _, c1 = lower_prefill(dbm, shape, mesh, probe_k=1)
            _, c2 = lower_prefill(dbm, shape, mesh, probe_k=2)
            r1 = RA.analyze(c1, chips=chips)
            r2 = RA.analyze(c2, model_flops_per_step=mf, chips=chips)
            rec = RA.extrapolate(r1, r2, n_units, mem_rec)
    else:
        if n_units <= PROBE_THRESHOLD:
            set_unroll(True)
            lowered, compiled = lower_decode(dbm, shape, mesh)
            rec = RA.analyze(compiled, model_flops_per_step=mf, chips=chips)
        else:
            set_unroll(False)
            lowered, compiled = lower_decode(dbm, shape, mesh)
            mem_rec = RA.analyze(compiled, chips=chips)
            set_unroll(True)
            _, c1 = lower_decode(_probe_dbm(dbm, 1), shape, mesh)
            _, c2 = lower_decode(_probe_dbm(dbm, 2), shape, mesh)
            r1 = RA.analyze(c1, chips=chips)
            r2 = RA.analyze(c2, model_flops_per_step=mf, chips=chips)
            rec = RA.extrapolate(r1, r2, n_units, mem_rec)
    compile_s = time.time() - t0
    # analytic per-chip memory lower bound (the CPU lowering is unfused, so
    # memory_analysis().temp_size overestimates what a TPU build needs; this
    # bound = sharded params + block-view grads/opt (f32) + remat-resident
    # activation streams). See EXPERIMENTS.md §Dry-run methodology.
    model_ax = dict(mesh.shape).get("model", 1)
    data_ax = max(dict(mesh.shape).get("data", 1)
                  * dict(mesh.shape).get("pod", 1), 1)
    p_bytes = sum(int(np.prod(l.shape)) * 2 for l in
                  jax.tree_util.tree_leaves(dbm.model.abstract_params()))
    view = jax.eval_shape(lambda p: extract_block_view(
        p, *dbm.ranges[0]), dbm.model.abstract_params())
    v_bytes = sum(int(np.prod(l.shape)) * 2 for l in
                  jax.tree_util.tree_leaves(view))
    b_local = max(shape.global_batch // data_ax, 1)
    s_eff = (2 * shape.seq_len if (shape.kind == "train" and mode == "db")
             else (shape.seq_len if shape.kind != "decode" else 1))
    stream = b_local * s_eff * cfg.d_model * 2
    n_resident = (dbm.ranges[0][1] if shape.kind == "train" else 4)
    analytic = {
        "params_bytes": p_bytes // model_ax,
        "grads_opt_bytes": (2 + 4 + 8) * v_bytes // 2 // model_ax
        if shape.kind == "train" else 0,
        "activation_bytes": stream * (n_resident + 4),
    }
    analytic["total"] = sum(analytic.values())
    rec["analytic_min_bytes_per_chip"] = analytic
    rec["analytic_fits_hbm"] = analytic["total"] <= 16e9

    rec.update({"arch": arch, "shape": shape_name, "mode": mode,
                "multi_pod": multi_pod, "compile_s": compile_s,
                "num_blocks": db.num_blocks, "skipped": False})
    if verbose:
        ma = compiled.memory_analysis()
        mesh_s = '2x16x16' if multi_pod else '16x16'
        print(f"== {arch} × {shape_name} mesh={mesh_s} mode={mode}")
        print(f"   memory_analysis: {ma}")
        print("   " + RA.format_row(f"{arch}/{shape_name}", rec))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}_{mode}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="db", choices=["db", "e2e"])
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--real-devices", action="store_true",
                    help="use the actual device count (tests)")
    ap.add_argument("--mesh", default=None,
                    help="override mesh shape, e.g. 4x2 (tests)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced arch config (tests)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()
    mesh_shape = (tuple(int(x) for x in args.mesh.split("x"))
                  if args.mesh else None)
    shape_override = None
    if args.batch or args.seq:
        import dataclasses as _dc
        base = get_shape(args.shape)
        shape_override = _dc.replace(base,
                                     global_batch=args.batch or base.global_batch,
                                     seq_len=args.seq or base.seq_len)

    pairs = []
    if args.all:
        order = sorted(configs.list_archs(),
                       key=lambda a: get_config(a).param_count())
        for a in order:                       # cheapest archs first
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        try:
            rec = run_one(arch, shape, args.multi_pod, args.mode, args.out,
                          args.blocks, mesh_shape=mesh_shape,
                          reduce_cfg=args.reduced,
                          shape_override=shape_override)
            if rec.get("skipped"):
                print(f"-- skipped {arch} × {shape}: {rec['reason']}")
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
