"""High-throughput block-wise serving: chunked prefill + scan-fused decode
over a paged bf16 KV cache, with static and continuous-batching schedulers
and a shared-prefix page cache.

The seed served one jitted dispatch PLUS a host sync per generated token and
kept a dense fp32 worst-case cache slab; PR 3 fused decode into one scan but
still committed ONE prompt token per scan step, so time-to-first-token scaled
with prompt length. This engine:

  * prefills prompts in CHUNKS of ``chunk_size`` tokens: each chunk is one
    sequence-level attention dispatch (``blocks.commit_prompt_chunk`` →
    ``cache.paged_prefill_attention`` / the Pallas flash-prefill kernel), so
    a prompt of S tokens costs ceil(S / C) serial attention steps instead of
    S — the per-token scan stays available as ``prefill="per-token"`` and is
    the numerical reference;
  * folds the whole denoise → sample → commit loop into ONE jitted
    ``lax.scan`` over new-token positions (greedy and temperature/top-k both
    traced — no per-token host round-trip);
  * handles ragged prompts inside one program with per-slot offsets and
    activity masks (masking is length-aware, never shape-aware);
  * stores KV in the paged pool of ``repro.nn.cache`` (bf16 under the
    default ``precision="bf16"`` policy, fp32 logsumexp in the attend);
  * optionally routes attention through the split-KV Pallas kernels
    (``--impl kernels``): flash-decode for generation, flash-prefill for
    ingest;
  * optionally shares prompt-PREFIX pages across requests
    (``prefix_cache=True``): finished prompts register their full prefix
    pages (hashed by token content) in a refcounted trie; a new request
    whose prompt extends a cached prefix maps those pages read-only and
    prefills only its non-shared suffix. Pages are copy-on-write: the first
    divergent write into a shared page (a matched partial tail page at
    admission, or a registered page the owner keeps generating into) gets a
    private copy first (``cache.copy_pool_pages``).

Schedulers (``--scheduler``):

  static      admit the whole batch, prefill (chunk scan), then one decode
              scan — O(1) dispatches for the entire batch of generations.
  continuous  slot-based continuous batching: a fixed number of request
              slots over a shared page pool. The host interleaves ONE
              prefill-chunk dispatch (advancing every still-prefilling slot
              by up to ``chunk_size`` tokens) with each ``seg_len``-step
              decode segment, so admitting a long prompt stalls decoding
              slots by at most one chunk per segment.

Compile-cache notes: ``steps_per_block`` / ``temperature`` / ``top_k`` /
``precision`` / ``impl`` / ``prefill`` / ``chunk_size`` are STATIC — they
select the trace. ``DecodeEngine`` instances are memoized per (dbm, static
config) by ``get_engine``, so repeated ``generate`` calls reuse compiled
programs; only a new padded prompt width or segment length triggers a
retrace.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import precision as precision_mod
from repro.configs import DBConfig, get_config, reduced
from repro.core import DiffusionBlocksModel
from repro.checkpoint import load_blocks
from repro.data import MarkovLM
from repro.launch.faults import WorkerDied
from repro.nn import cache as KVC

DEFAULT_CHUNK = 64


def _ragged_transition_accuracy(lm, seqs) -> float:
    """Mean legal-transition rate over variable-length sequences — scored
    per row so zero-padding never fabricates (or breaks) transitions."""
    return float(np.mean([lm.transition_accuracy(np.asarray(s)[None])
                          for s in seqs]))


class DecodeEngine:
    """Owns the jitted scan-fused programs for one (model, static config).

    All programs are length-aware over the paged cache:
      _prefill        per-token reference: scan over prompt positions,
                      committing where t < plens[b] (one serial attention
                      step per token — the seed ingest path)
      _prefill_chunks chunked prefill: scan over ceil(S/C) prompt CHUNKS;
                      each step commits up to C tokens per slot at its own
                      offset in ONE sequence-level attention dispatch
      _prefill_chunk1 a single chunk step (the continuous batcher interleaves
                      these with decode segments from the host)
      _decode         scan over new-token positions: denoise → sample → commit
      _serve          continuous-batching segment: each active slot either
                      commits its next PROMPT token (per-token mode) or a
                      GENERATED token
    """

    def __init__(self, dbm: DiffusionBlocksModel, *, steps_per_block: int = 1,
                 temperature: float = 0.0, top_k: int = 0,
                 precision="bf16", impl: str = "auto",
                 prefill: str = "chunked", chunk_size: int = DEFAULT_CHUNK):
        if prefill not in ("chunked", "per-token"):
            raise ValueError(f"prefill must be 'chunked' or 'per-token', "
                             f"got {prefill!r}")
        self.dbm = dbm
        self.pol = precision_mod.get_policy(precision)
        self.impl = impl
        self.prefill_mode = prefill
        self.chunk_size = int(chunk_size)
        self.temperature, self.top_k = float(temperature), int(top_k)
        self.dispatches = 0          # jitted-call count (throughput reporting)
        self.prefill_steps = 0       # serial attention steps spent in prefill
        pol, spb = self.pol, steps_per_block
        temp, tk = temperature, top_k
        Ck = self.chunk_size

        def prefill_scan(params, kv, page_table, lengths, prompts, plens,
                         cond_lengths):
            def body(carry, t):
                kv, lengths = carry
                act = t < plens
                tok = jnp.take(prompts, t, axis=1)
                kv, lengths = dbm.commit_prompt_token(
                    params, kv, page_table, lengths, tok[:, None],
                    active=act, precision=pol, impl=impl,
                    cond_lengths=cond_lengths)
                return (kv, lengths), None
            return jax.lax.scan(body, (kv, lengths),
                                jnp.arange(prompts.shape[1]))[0]

        def chunk_step(params, kv, page_table, lengths, prompt_buf, plens,
                       cond_lengths):
            # slot b's next chunk starts at its OWN offset lengths[b] (ragged
            # plens and prefix-cache hits put slots at different offsets)
            idx = lengths[:, None] + jnp.arange(Ck, dtype=lengths.dtype)
            tok = jnp.take_along_axis(
                prompt_buf, jnp.clip(idx, 0, prompt_buf.shape[1] - 1), axis=1)
            n_valid = jnp.clip(plens - lengths, 0, Ck)
            return dbm.commit_prompt_chunk(
                params, kv, page_table, lengths, tok, n_valid=n_valid,
                precision=pol, impl=impl, cond_lengths=cond_lengths)

        def prefill_chunk_scan(params, kv, page_table, lengths, prompts,
                               plens, cond_lengths, n_chunks):
            def body(carry, _):
                kv, lengths = carry
                return chunk_step(params, kv, page_table, lengths, prompts,
                                  plens, cond_lengths), None
            return jax.lax.scan(body, (kv, lengths), None, length=n_chunks)[0]

        def decode_scan(params, kv, page_table, lengths, stop_at, rng,
                        cond_lengths, n):
            def body(carry, _):
                kv, lengths, rng = carry
                rng, rs = jax.random.split(rng)
                act = lengths < stop_at
                tok, kv, lengths = dbm.serve_step_paged(
                    params, kv, page_table, lengths, rs, active=act,
                    steps_per_block=spb, temperature=temp, top_k=tk,
                    precision=pol, impl=impl, cond_lengths=cond_lengths)
                return (kv, lengths, rng), tok
            (kv, lengths, rng), toks = jax.lax.scan(
                body, (kv, lengths, rng), None, length=n)
            return kv, lengths, rng, toks.T          # (B, n)

        def serve_scan(params, kv, page_table, lengths, prompt_buf, plens,
                       stop_at, active, rng, cond_lengths, n):
            def body(carry, _):
                kv, lengths, rng = carry
                rng, rs = jax.random.split(rng)
                in_prompt = lengths < plens
                idx = jnp.clip(lengths, 0, prompt_buf.shape[1] - 1)
                ptok = jnp.take_along_axis(prompt_buf, idx[:, None], 1)[:, 0]
                act = active & (lengths < stop_at)
                ctx = dbm._paged_ctx(params, lengths, page_table, act, pol,
                                     impl, cond_lengths)
                rn, rsamp = jax.random.split(rs)
                d = dbm.denoise_next_token(params, kv, None, rn, ctx, spb)
                logits = dbm.model.logits(params, d)
                gtok = dbm.sample_token(logits[:, 0], rsamp, temp, tk)
                tok = jnp.where(in_prompt, ptok, gtok)
                kv = dbm.commit_token(params, kv, None, tok[:, None], ctx)
                emitted = jnp.where(act & ~in_prompt, tok, -1)
                lengths = lengths + act.astype(lengths.dtype)
                return (kv, lengths, rng), emitted
            (kv, lengths, rng), toks = jax.lax.scan(
                body, (kv, lengths, rng), None, length=n)
            return kv, lengths, rng, toks.T          # (B, n); -1 = no emit

        self._prefill = jax.jit(prefill_scan)
        self._prefill_chunk1 = jax.jit(chunk_step)
        self._prefill_chunks = jax.jit(prefill_chunk_scan,
                                       static_argnames=("n_chunks",))
        self._decode = jax.jit(decode_scan, static_argnames=("n",))
        self._serve = jax.jit(serve_scan, static_argnames=("n",))

    # ------------------------------------------------------------------
    def run_prefill(self, params, kv, table, lengths, prompts, plens,
                    cond_lengths=None):
        """Dispatch the configured prefill program over a whole (padded)
        prompt buffer; returns (kv, lengths) and accounts serial steps."""
        S0 = prompts.shape[1]
        if cond_lengths is None:
            cond_lengths = jnp.zeros((prompts.shape[0],), jnp.int32)
        if self.prefill_mode == "chunked":
            n_chunks = -(-S0 // self.chunk_size)
            kv, lengths = self._prefill_chunks(params, kv, table, lengths,
                                               prompts, plens, cond_lengths,
                                               n_chunks=n_chunks)
            self.prefill_steps += n_chunks
        else:
            kv, lengths = self._prefill(params, kv, table, lengths,
                                        prompts, plens, cond_lengths)
            self.prefill_steps += S0
        self.dispatches += 1
        return kv, lengths

    def generate(self, params, prompts, max_new: int, rng=None, *,
                 prompt_lengths=None, page_size: int = KVC.DEFAULT_PAGE_SIZE,
                 aux_inputs=None, cond_lengths=None,
                 reference: bool = False):
        """Static-batch generation. prompts: (B, S0) (right-padded when
        ``prompt_lengths`` is ragged) -> (B, S0 + max_new); row b holds its
        prompt then its ``max_new`` generated tokens starting at
        ``prompt_lengths[b]``.

        ``aux_inputs`` (dict of (B, Sk, d) conditioning embeddings —
        image_embs / audio_embs) is encoded ONCE through the model's
        frontend and written into every slot's cross block before prefill;
        the scan programs then read it from the cache under the per-slot
        valid lengths ``cond_lengths`` (default: the full encoded length for
        every row).

        ``reference=True`` replays the seed serving loop faithfully — one
        jitted dispatch + host sync per generated token — through the SAME
        step function, so greedy outputs are bit-identical to the fused scan
        (the decode-parity tests and ``benchmarks/table15_decode`` rely on
        this).
        """
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts)
        B, S0 = prompts.shape
        plens = (jnp.full((B,), S0, jnp.int32) if prompt_lengths is None
                 else jnp.asarray(prompt_lengths, jnp.int32))
        pps = KVC.pages_for(int(jnp.max(plens)) + max_new, page_size)
        kv = self.dbm.model.init_paged_cache(B, 1 + B * pps, page_size,
                                             self.pol)
        table = KVC.identity_page_table(B, pps)
        lengths = jnp.zeros((B,), jnp.int32)
        if aux_inputs:
            cond = self.dbm.model.encode_conditioning(params, aux_inputs)
            if cond is None:
                spec = self.dbm.model.aux_input_specs(B)
                raise ValueError(
                    f"aux_inputs {sorted(aux_inputs)} not understood by "
                    f"family {self.dbm.cfg.family!r}: expected "
                    f"{sorted(spec) if spec else 'no aux inputs'}")
            if (cond_lengths is not None
                    and not self.dbm.model.cond_padding_safe):
                raise ValueError(
                    "ragged cond_lengths through the static batch is "
                    f"unsound for family {self.dbm.cfg.family!r}: its "
                    "frontend (bidirectional encoder) mixes padded frames "
                    "into every row. Serve ragged conditioning through "
                    "ContinuousBatcher.submit, which encodes each request "
                    "at its true length.")
            kv = self.dbm.model.set_conditioning(params, kv, cond)
            clens = (jnp.full((B,), cond.shape[1], jnp.int32)
                     if cond_lengths is None
                     else jnp.asarray(cond_lengths, jnp.int32))
        else:
            clens = jnp.zeros((B,), jnp.int32)
        kv, lengths = self.run_prefill(params, kv, table, lengths,
                                       prompts.astype(jnp.int32), plens,
                                       clens)
        stop_at = plens + max_new
        if reference:
            cols = []
            for _ in range(max_new):
                kv, lengths, rng, t = self._decode(params, kv, table, lengths,
                                                   stop_at, rng, clens, n=1)
                self.dispatches += 1
                cols.append(np.asarray(t))       # host sync per token (seed)
            gen = np.concatenate(cols, axis=1)
        else:
            kv, lengths, rng, t = self._decode(params, kv, table, lengths,
                                               stop_at, rng, clens,
                                               n=max_new)
            self.dispatches += 1
            gen = np.asarray(t)
        out = np.zeros((B, S0 + max_new), dtype=np.asarray(prompts).dtype)
        pl = np.asarray(plens)
        pr = np.asarray(prompts)
        for b in range(B):
            out[b, :pl[b]] = pr[b, :pl[b]]
            out[b, pl[b]:pl[b] + max_new] = gen[b]
        return jnp.asarray(out)


_ENGINE_DEFAULTS = dict(steps_per_block=1, temperature=0.0, top_k=0,
                        precision="bf16", impl="auto", prefill="chunked",
                        chunk_size=DEFAULT_CHUNK, kv_dtype=None)


def get_engine(dbm: DiffusionBlocksModel, **config) -> DecodeEngine:
    """Memoized engine per (dbm, static config): repeated ``generate`` calls
    reuse the compiled scan programs instead of thrashing the jit cache.
    The key is normalized against the engine defaults, so ``get_engine(dbm)``
    and an explicit-defaults call share one engine. ``kv_dtype`` (the
    ``--kv-dtype`` flag: int8 | bf16 | None) is folded into the precision
    policy name — ``('bf16', 'int8')`` and ``('bf16_kvint8', None)`` resolve
    to the same engine."""
    cfg = {**_ENGINE_DEFAULTS, **config}
    cfg["precision"] = precision_mod.with_kv_dtype(
        cfg["precision"], cfg.pop("kv_dtype", None)).name
    key = tuple(sorted(cfg.items()))
    cache = dbm.__dict__.setdefault("_serve_engines", {})
    if key not in cache:
        cache[key] = DecodeEngine(dbm, **cfg)
    return cache[key]


def generate(dbm, params, prompts: jnp.ndarray, max_new: int,
             steps_per_block: int = 1, rng=None, *, prompt_lengths=None,
             temperature: float = 0.0, top_k: int = 0, precision="bf16",
             kv_dtype=None,
             impl: str = "auto", page_size: int = KVC.DEFAULT_PAGE_SIZE,
             prefill: str = "chunked", chunk_size: int = DEFAULT_CHUNK,
             aux_inputs=None, cond_lengths=None, reference: bool = False):
    """prompts: (B, S0) -> (B, S0 + max_new), scan-fused over the paged
    bf16 KV cache (see DecodeEngine). The cache dtype follows the
    ``repro.precision`` policy (bf16 KV by default; recurrent states keep
    their family override). ``prefill="chunked"`` (default) ingests the
    prompt ``chunk_size`` tokens per scan step; ``"per-token"`` is the
    seed-style one-token-per-step reference scan. ``aux_inputs`` conditions
    the batch (VLM image_embs / audio audio_embs, (B, Sk, d)): encoded once
    and served from the per-slot cross blocks. ``reference=True`` =
    seed-style per-token DECODE loop (same math, one dispatch + host sync
    per token)."""
    eng = get_engine(dbm, steps_per_block=steps_per_block,
                     temperature=temperature, top_k=top_k,
                     precision=precision, kv_dtype=kv_dtype, impl=impl,
                     prefill=prefill, chunk_size=chunk_size)
    return eng.generate(params, prompts, max_new, rng,
                        prompt_lengths=prompt_lengths, page_size=page_size,
                        aux_inputs=aux_inputs, cond_lengths=cond_lengths,
                        reference=reference)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

# Priority classes for SLO-aware scheduling: higher wins. Admission picks the
# best (priority, earliest TTFT deadline, oldest) queued request; preemption
# only ever spills STRICTLY lower-priority work for an admission, so classes
# are a total preorder, not advisory hints.
PRIORITY_CLASSES = {"batch": 0, "standard": 1, "interactive": 2}


class AdmissionError(RuntimeError):
    """Raised by ``submit`` when admission control sheds the request (queue
    depth or pool pressure over threshold). ``retry_after`` is the engine's
    service-time-based backoff hint in seconds (the HTTP frontend surfaces
    it as a ``Retry-After`` header on the 429)."""

    def __init__(self, msg: str, retry_after: float):
        super().__init__(msg)
        self.retry_after = float(retry_after)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    aux_inputs: Optional[dict] = None   # per-request conditioning (Sk, d)
    cond_fp: int = 0                    # conditioning fingerprint (0 = none)
    out: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    shared_tokens: int = 0        # prompt tokens served from the prefix cache
    registered: bool = False      # prefix pages inserted into the cache
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    cancelled: bool = False       # retired early via ``cancel(rid)``
    error: Optional[str] = None   # rejection reason (non-strict scheduling)
    # --- SLO-aware scheduling ---
    priority: int = PRIORITY_CLASSES["standard"]
    ttft_deadline: Optional[float] = None   # absolute wall-clock deadline
    tpot_deadline_s: Optional[float] = None  # max seconds per output token
    deadline_blown: bool = False  # retired by the deadline enforcer
    # --- preemption (page spill / restore) ---
    spilled: Optional[KVC.SpilledSlot] = None  # host snapshot while queued
    spill_meta: Optional[dict] = None          # lengths/cond row to restore
    preempt_count: int = 0
    # --- disaggregated prefill/decode migration (launch/router) ---
    # page-handle handoff over a SHARED pool: the physical pages holding this
    # request's committed KV, refs still held, travelling with the request —
    # admission maps them instead of allocating + byte-copying
    handoff_pages: Optional[List[int]] = None
    migrations: int = 0           # completed prefill->decode handoffs
    failovers: int = 0            # re-routed off a dead worker

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)


def _paged_leaves(kv) -> list:
    """The PagedKV leaves of a model cache, in flatten order (dense per-slot
    leaves excluded) — the part of the cache a SharedPagePool makes common."""
    return [x for x in jax.tree_util.tree_leaves(kv, is_leaf=KVC._is_pkv)
            if KVC._is_pkv(x)]


def _graft_paged(kv, leaves: list):
    """Replace the PagedKV leaves of ``kv`` with ``leaves`` (same order),
    leaving dense per-slot state untouched — a reference swap, no copy."""
    it = iter(leaves)
    return jax.tree_util.tree_map(
        lambda x: next(it) if KVC._is_pkv(x) else x, kv,
        is_leaf=KVC._is_pkv)


class SharedPagePool:
    """ONE physical page pool shared by several batchers (disaggregated
    prefill/decode with page-handle migration): the free list, the refcount
    map, and the canonical paged-KV leaves are common; each batcher keeps its
    own dense per-slot state (recurrent rows, cross blocks) and its own page
    table. Steps of every sharing batcher serialize under ``lock``; a
    stepping batcher PULLS the canonical paged leaves before mutating and
    PUBLISHES them after, so a page a prefill worker hands to a decode worker
    is visible there without copying a byte — the request carries only the
    physical page ids (``Request.handoff_pages``)."""

    def __init__(self, total_pages: int):
        self.total_pages = int(total_pages)
        self.free_pages: List[int] = list(range(1, self.total_pages))
        self.page_refs: Dict[int, int] = {}
        self.lock = threading.RLock()
        self.paged: Optional[list] = None    # canonical PagedKV leaves

    def release(self, batcher: "ContinuousBatcher", pages) -> None:
        """Return refs the ROUTER holds (a dropped in-transit handoff) to the
        shared pool, serialized against every sharing batcher's step."""
        with self.lock:
            batcher._release_pages(pages)


class ContinuousBatcher:
    """Slot-based continuous batching over a shared page pool.

    ``num_slots`` request slots share ``total_pages`` physical pages
    (physical page 0 reserved as the trash page). Between dispatches the host
    admits queued requests into free slots and retires finished sequences,
    returning pages whose refcount drops to zero to the free list.

    Scheduling (``prefill="chunked"``, the default): each loop iteration runs
    ONE prefill-chunk dispatch — advancing every still-prefilling slot by up
    to ``chunk_size`` prompt tokens at its own offset — then one
    ``seg_len``-step decode segment for the slots past their prompt. A long
    prompt therefore stalls decoding slots by at most one chunk per segment,
    and reaches its first token after ceil(S / C) chunks instead of S
    per-token steps. ``prefill="per-token"`` restores the PR 3 behavior
    (prompt tokens commit one per scan step inside the segment).

    ``prefix_cache=True`` shares prompt-prefix pages across requests (see
    ``repro.nn.cache.PrefixPageCache``): a request whose prompt extends a
    previously-served prefix maps those pages read-only, starts prefilling
    at the first non-shared token, and copy-on-writes the boundary page.
    Requires a model whose sequence state lives entirely in paged KV
    (``model.kv_carries_all_state`` — recurrent families raise here, at
    construction time, not mid-serve).

    CONDITIONED requests: ``submit(..., aux_inputs={"image_embs": (Sk, d)})``
    (or ``audio_embs``) attaches per-request conditioning. The modality
    frontend runs ONCE at admission (``model.encode_conditioning`` — for
    audio that is the whole encoder stack, at the request's true frame
    count) and the projected result is written into the slot's fixed cross
    block (``model.set_conditioning``); every subsequent chunk/decode
    dispatch reads it from the cache under the per-slot valid length, so
    conditioned and unconditioned slots mix in ONE compiled program
    (``cond_lengths[s] == 0`` makes a slot's cross term exactly zero).
    Prefix sharing keys on (token content, conditioning fingerprint):
    identical text under different conditioning never shares pages.

    FRONTEND HOOKS (the asyncio server in ``repro.launch.server`` and the
    load harness in ``benchmarks/loadgen.py`` drive the batcher through
    these; plain ``run()`` keeps the original drain-the-queue semantics):

      step(rng)       ONE scheduling iteration — apply pending cancels,
                      admit, one prefill-chunk dispatch, one decode segment,
                      retire — returning the requests finished this
                      iteration. ``run()`` is now a loop over ``step``.
      cancel(rid)     thread-safe mid-flight abort: a queued request is
                      dropped, an admitted one retires its slot BETWEEN
                      segments — its pages return to the pool immediately,
                      respecting prefix-cache refcounts (shared pages only
                      drop this slot's ref).
      pause(rid) /    thread-safe flow control: a paused request keeps its
      resume(rid)     slot and pages but is excluded from decode segments —
                      slow-consumer backpressure without losing work.
      token_cb        optional ``(Request, list[int]) -> None`` called from
                      the scheduling thread with each segment's newly
                      emitted tokens (SSE streaming taps this).

    ``submit``/``cancel``/``pause``/``resume`` may be called from any
    thread; mutations are applied by the scheduling thread at the next
    ``step`` boundary — engine dispatches never race host bookkeeping.
    """

    def __init__(self, dbm, params, *, num_slots: int = 8,
                 page_size: int = KVC.DEFAULT_PAGE_SIZE,
                 max_prompt: int = 64, max_len: int = 128,
                 total_pages: Optional[int] = None, seg_len: int = 16,
                 steps_per_block: int = 1, temperature: float = 0.0,
                 top_k: int = 0, precision="bf16", kv_dtype=None,
                 impl: str = "auto",
                 prefill: str = "chunked",
                 chunk_size: Optional[int] = None,
                 prefix_cache: bool = False,
                 max_queue: Optional[int] = None,
                 shed_below_pages: int = 0,
                 faults=None,
                 shared_pool: Optional[SharedPagePool] = None):
        self.dbm, self.params = dbm, params
        chunk_size = (min(DEFAULT_CHUNK, max_prompt) if chunk_size is None
                      else chunk_size)
        self.eng = get_engine(dbm, steps_per_block=steps_per_block,
                              temperature=temperature, top_k=top_k,
                              precision=precision, kv_dtype=kv_dtype,
                              impl=impl,
                              prefill=prefill, chunk_size=chunk_size)
        self.chunked = prefill == "chunked"
        self.chunk_size = chunk_size
        if prefix_cache and not dbm.model.kv_carries_all_state:
            raise ValueError(
                f"prefix_cache=True is unsound for family "
                f"{dbm.cfg.family!r}: per-slot recurrent state is not paged, "
                "so mapping shared prefix pages would skip the recurrence. "
                "Serve this model with prefix_cache=False.")
        self.prefix = KVC.PrefixPageCache(page_size) if prefix_cache else None
        self.page_size, self.seg_len = page_size, seg_len
        self.max_prompt, self.max_len = max_prompt, max_len
        pps = KVC.pages_for(max_len, page_size)
        # default pool: worst-case pages per slot, plus — under prefix
        # sharing — one copy-on-write spare per slot (a decode write into a
        # cache-RETAINED boundary page copies it even when every mapped page
        # is live, so a zero-slack pool would deadlock on its own request)
        cow_spare = num_slots if prefix_cache else 0
        self.total_pages = (1 + num_slots * pps + cow_spare
                            if total_pages is None else total_pages)
        self._shared = shared_pool
        if shared_pool is not None:
            self.total_pages = shared_pool.total_pages
        self.kv = dbm.model.init_paged_cache(num_slots, self.total_pages,
                                             page_size, self.eng.pol)
        if shared_pool is None:
            self.free_pages = list(range(1, self.total_pages))
            self.page_refs = {}      # phys page -> refcount (slots + cache)
            self._pool_lock = threading.RLock()
        else:
            # shared pool: common free list / refcounts / paged leaves, one
            # lock serializing every sharing batcher's step. The FIRST
            # registrant's freshly-initialized paged leaves become canonical;
            # later registrants drop their own and adopt (shapes must match
            # — same model, page size and pool size).
            self.free_pages = shared_pool.free_pages
            self.page_refs = shared_pool.page_refs
            self._pool_lock = shared_pool.lock
            mine = _paged_leaves(self.kv)
            if shared_pool.paged is None:
                shared_pool.paged = mine
            else:
                assert len(shared_pool.paged) == len(mine) and all(
                    a.k.shape == b.k.shape and a.k.dtype == b.k.dtype
                    and a.quantized == b.quantized for a, b in
                    zip(shared_pool.paged, mine)), \
                    "batchers sharing a pool must serve the same model with " \
                    "the same page_size/total_pages/kv_dtype"
                self.kv = _graft_paged(self.kv, shared_pool.paged)
        self.num_slots = num_slots
        self.table = np.zeros((num_slots, pps), np.int32)   # 0 = trash page
        self.lengths = np.zeros(num_slots, np.int32)
        self.plens = np.zeros(num_slots, np.int32)
        self.stop_at = np.zeros(num_slots, np.int32)
        self.active = np.zeros(num_slots, bool)
        self.prompt_buf = np.zeros((num_slots, max_prompt), np.int32)
        self.cond_lengths = np.zeros(num_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque = collections.deque()
        self._next_rid = 0
        self.steps = 0               # decode-segment scan steps (all slots)
        self.ingest_dispatches = 0   # prefill-chunk calls THIS batcher made
        self.decode_dispatches = 0   # decode-segment calls THIS batcher made
        self.cow_copies = 0          # copy-on-write page copies performed
        self._lock = threading.Lock()        # guards queue/cancel/pause sets
        self._cancel_pending: set = set()    # rids to abort at next step
        self._paused: set = set()            # rids excluded from decode
        self.cancelled_count = 0
        self.token_cb: Optional[Callable[[Request, List[int]], None]] = None
        # --- SLO scheduling / preemption / admission control / chaos ---
        self._axes = dbm.model.paged_state_axes  # dense per-slot slot axes
        self.max_queue = max_queue           # class-aware queue-depth shed
        self.shed_below_pages = shed_below_pages  # pool-pressure shed (prio 0)
        self.faults = faults                 # repro.launch.faults injector
        self._preempt_pending: set = set()   # rids to spill at next step
        self.preemptions = 0                 # slots spilled to host
        self.restores = 0                    # spilled requests re-admitted
        self.deadline_cancels = 0            # requests retired by SLO misses
        self.shed_count = 0                  # submissions refused (429)
        self._svc_ewma: Optional[float] = None  # submit->finish seconds

    def submit(self, prompt, max_new: int, aux_inputs=None, *,
               priority="standard", ttft_slo_s: Optional[float] = None,
               tpot_slo_s: Optional[float] = None) -> int:
        """Queue a request. ``aux_inputs``: optional per-request conditioning
        — {"image_embs": (Sk, d)} / {"audio_embs": (Sk, d)} numpy/jax arrays
        WITHOUT a batch dim. The fingerprint for conditioning-aware prefix
        sharing is taken here (content hash); the encoder itself runs at
        admission.

        ``priority`` (a ``PRIORITY_CLASSES`` name or an int) orders admission
        and selects preemption victims; ``ttft_slo_s`` / ``tpot_slo_s`` are
        relative SLOs — a request that blows one is retired with its partial
        output and ``error`` set, never silently served late. Admission
        control (``max_queue`` / ``shed_below_pages``) raises
        ``AdmissionError`` instead of queueing; the backlog check only counts
        queued work at >= this request's priority, so under mixed overload
        the low classes shed first while the high classes still admit."""
        if isinstance(priority, str):
            if priority not in PRIORITY_CLASSES:
                raise ValueError(f"unknown priority class {priority!r}: "
                                 f"expected {sorted(PRIORITY_CLASSES)}")
            priority = PRIORITY_CLASSES[priority]
        priority = int(priority)
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        # Reject degenerate requests BEFORE any state is touched: an empty
        # prompt allocates zero pages (pages_for(0) == 0) and would dispatch
        # a prefill chunk whose every write lands in the trash page; a
        # max_new < 1 request could never retire through the stop_at check.
        # ValueError (not assert) so the HTTP frontend maps these to a 400.
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: a request must carry at least one token "
                "(the serving stack has no BOS convention to invent one)")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        assert prompt.size <= self.max_prompt, "prompt exceeds max_prompt"
        assert prompt.size + max_new <= self.max_len, "request exceeds max_len"
        if aux_inputs:
            cap = self.dbm.model.max_cond_tokens
            if cap == 0:
                raise ValueError(
                    f"family {self.dbm.cfg.family!r} takes no aux "
                    "conditioning inputs")
            aux_inputs = {k: np.asarray(v, np.float32)
                          for k, v in aux_inputs.items()}
            for k, v in aux_inputs.items():
                assert v.ndim == 2 and v.shape[1] == self.dbm.cfg.d_model, \
                    f"{k}: expected (Sk, d_model), got {v.shape}"
                assert v.shape[0] <= cap, \
                    f"{k}: {v.shape[0]} tokens exceed the conditioning " \
                    f"block capacity {cap}"
        with self._lock:
            if self.max_queue is not None:
                backlog = sum(1 for r in self.queue if r.priority >= priority)
                if backlog >= self.max_queue:
                    self.shed_count += 1
                    raise AdmissionError(
                        f"queue depth {backlog} at priority >= {priority} "
                        f"over threshold {self.max_queue}",
                        self.retry_after_hint())
            if (self.shed_below_pages and priority <= 0
                    and len(self.free_pages) < self.shed_below_pages):
                self.shed_count += 1
                raise AdmissionError(
                    f"pool pressure: {len(self.free_pages)} free pages below "
                    f"threshold {self.shed_below_pages} (batch class shed)",
                    self.retry_after_hint())
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid, prompt, max_new, aux_inputs=aux_inputs or None,
                      cond_fp=KVC.conditioning_fingerprint(aux_inputs),
                      priority=priority, tpot_deadline_s=tpot_slo_s)
        req.submit_t = time.time()
        if ttft_slo_s is not None:
            req.ttft_deadline = req.submit_t + float(ttft_slo_s)
        with self._lock:
            self.queue.append(req)
        return rid

    def kv_stats(self) -> dict:
        """Pool-bytes surface for ``/v1/health``: the pool storage dtype and
        total cache bytes counted per leaf — mixed-dtype aware, so an int8
        pool reports its fp32 per-page scale arrays instead of silently
        under-reporting them."""
        leaves = _paged_leaves(self.kv)
        return {
            "kv_dtype": (jnp.dtype(leaves[0].k.dtype).name if leaves
                         else None),
            "kv_quantized": bool(leaves and leaves[0].quantized),
            "kv_bytes": int(KVC.cache_bytes(self.kv)),
            "kv_bytes_by_dtype": KVC.cache_bytes_by_dtype(self.kv),
        }

    def submit_request(self, req: Request) -> None:
        """Enqueue a pre-built ``Request`` (thread-safe). The disaggregation
        router hands work over this way: rids are allocated globally by the
        router and admission control already ran there, so the request lands
        in the queue untouched — including a migration payload
        (``req.spilled`` / ``req.handoff_pages``) to restore at admission."""
        with self._lock:
            self.queue.append(req)

    def cancel(self, rid: int) -> bool:
        """Abort request ``rid`` (thread-safe). Applied at the next ``step``
        boundary: a queued request is dropped before admission; an admitted
        one retires its slot between segments and frees its pages
        immediately (shared prefix pages only drop this slot's refcount —
        cache-retained copies survive). Returns False when ``rid`` is
        unknown or already finished."""
        with self._lock:
            known = (any(r.rid == rid for r in self.queue)
                     or any(r is not None and r.rid == rid
                            for r in self.slot_req))
            if known:
                self._cancel_pending.add(rid)
        return known

    def pause(self, rid: int):
        """Exclude ``rid`` from decode segments (thread-safe): the request
        keeps its slot and pages but emits no tokens until ``resume`` —
        slow-consumer backpressure."""
        with self._lock:
            self._paused.add(rid)

    def resume(self, rid: int):
        with self._lock:
            self._paused.discard(rid)

    def preempt(self, rid: int) -> bool:
        """Force-preempt an ADMITTED request (thread-safe, applied at the
        next ``step`` boundary): its slot state spills to host memory, its
        pages and slot free, and it re-queues for restore when capacity
        allows. The scheduler invokes the same mechanism automatically under
        pool pressure; this entry point exists for tests and operators.
        Returns False when ``rid`` is not currently in a slot."""
        with self._lock:
            known = any(r is not None and r.rid == rid for r in self.slot_req)
            if known:
                self._preempt_pending.add(rid)
        return known

    def retry_after_hint(self) -> float:
        """Backoff hint for shed requests: the smoothed submit→finish
        service time, clipped to [0.1s, 5s] (0.5s before any completion)."""
        return float(min(5.0, max(0.1, self._svc_ewma or 0.5)))

    def _note_service(self, dt: float):
        a = 0.2
        self._svc_ewma = (dt if self._svc_ewma is None
                          else a * dt + (1 - a) * self._svc_ewma)

    # ---- page accounting ---------------------------------------------
    def _alloc_page(self) -> Optional[int]:
        """Pop a free page, evicting prefix-cache entries under pressure."""
        if self.faults is not None and self.faults.fire("alloc_exhaust"):
            return None              # injected exhaustion: pretend pool empty
        if not self.free_pages and self.prefix is not None:
            self.prefix.evict(self.page_refs, self.free_pages, need=1)
        if not self.free_pages:
            return None
        page = self.free_pages.pop()
        self.page_refs[page] = self.page_refs.get(page, 0) + 1
        return page

    def _release_pages(self, pages):
        for p in pages:
            self.page_refs[p] -= 1
            if self.page_refs[p] == 0:
                del self.page_refs[p]
                self.free_pages.append(p)

    def _cow(self, slot: int, logical: int) -> bool:
        """Give ``slot`` a private copy of its ``logical``-th page (the page
        is shared / cache-retained and about to be written). Returns False
        when no page could be allocated."""
        src = int(self.table[slot, logical])
        dst = self._alloc_page()
        if dst is None:
            return False
        self.kv = KVC.copy_pool_pages(self.kv, src, dst)
        self.cow_copies += 1
        self.table[slot, logical] = dst
        req = self.slot_req[slot]
        req.pages[logical] = dst
        self._release_pages([src])   # drop this slot's ref on the shared page
        return True

    def _make_writable(self, slot: int, lo: int, hi: int) -> bool:
        """Copy-on-write every shared page overlapping token positions
        [lo, hi) of ``slot`` before a dispatch writes there."""
        psz = self.page_size
        for lp in range(lo // psz, (max(hi, lo + 1) - 1) // psz + 1):
            phys = int(self.table[slot, lp])
            if phys != KVC.TRASH_PAGE and self.page_refs.get(phys, 0) > 1:
                if not self._cow(slot, lp):
                    return False
        return True

    # ---- host-side scheduling between dispatches ---------------------
    def _write_conditioning(self, slot: int, req: Request):
        """Encode a newly-admitted request's conditioning ONCE and write it
        into the slot's cross block. One jitted program per aux shape set
        (the audio encoder runs at the request's TRUE frame count — padding
        frames through a bidirectional encoder would change its output);
        ``slot`` stays a traced scalar so all slots share the program."""
        if req.aux_inputs is None:
            self.cond_lengths[slot] = 0
            return
        # memoized on the dbm (like the engines): every batcher over the
        # same model reuses one compiled program per aux shape set
        progs = self.dbm.__dict__.setdefault("_cond_write_progs", {})
        key = tuple(sorted((k, v.shape) for k, v in req.aux_inputs.items()))
        key = (key, self.num_slots)
        fn = progs.get(key)
        if fn is None:
            model = self.dbm.model

            def encode_write(params, kv, aux, slot):
                cond = model.encode_conditioning(params, aux)
                return model.set_conditioning(params, kv, cond, slot)

            # donate the pool: without it every conditioned admission would
            # copy the whole paged cache to build the updated one (CPU
            # backends ignore donation with a warning, so skip it there)
            donate = () if jax.default_backend() == "cpu" else (1,)
            fn = progs[key] = jax.jit(encode_write, donate_argnums=donate)
        aux = {k: jnp.asarray(v)[None] for k, v in req.aux_inputs.items()}
        self.kv = fn(self.params, self.kv, aux, jnp.asarray(slot, jnp.int32))
        self.cond_lengths[slot] = next(iter(req.aux_inputs.values())).shape[0]

    def _order_key(self, r: Request):
        return (-r.priority,
                r.ttft_deadline if r.ttft_deadline is not None
                else float("inf"),
                r.rid)

    def _pop_best(self) -> Optional[Request]:
        """Pop the best queued candidate: highest priority class first, then
        earliest TTFT deadline, then oldest rid (FIFO within a class —
        preempted requests keep their original rid, so a restore naturally
        goes ahead of newer peers)."""
        with self._lock:
            if not self.queue:
                return None
            i = min(range(len(self.queue)),
                    key=lambda i: self._order_key(self.queue[i]))
            req = self.queue[i]
            del self.queue[i]
        return req

    def _requeue(self, req: Request):
        with self._lock:
            self.queue.appendleft(req)

    def _admit(self) -> int:
        new_slots = np.zeros(self.num_slots, bool)
        admitted = []
        budget = self.num_slots     # preemptions allowed per admission pass
        for s in range(self.num_slots):
            if self.active[s]:
                continue
            req = self._pop_best()
            if req is None:
                break
            # a spilled request restores into PRIVATE pages — its snapshot
            # already holds the prefix content, so no prefix matching
            restoring = req.spilled is not None
            match = (self.prefix.match(req.prompt, req.cond_fp)
                     if self.prefix is not None and not restoring
                     else KVC.PrefixMatch([], 0, 0))
            # PIN every matched page before any eviction can run: under pool
            # pressure evict() drops cache-held refs deepest-first, and
            # without the pin it could free (and later re-allocate) the very
            # pages this admission is about to map / CoW-copy from.
            for p in match.pages:
                self.page_refs[p] += 1
            total = KVC.pages_for(len(req.prompt) + req.max_new,
                                  self.page_size)
            # page-handle migration (shared pool): the request arrives
            # already holding refs on the physical pages with its committed
            # KV — they map directly, only the scratch tail allocates
            handed = req.handoff_pages or []
            # fresh pages: everything past the shared prefix, PLUS a copy
            # destination for a matched partial tail page (it is CoW'd at
            # admission — the slot's first write lands inside it)
            need = (total - len(match.pages) - len(handed)
                    + (1 if match.tail_tokens else 0))
            if need > len(self.free_pages) and self.prefix is not None:
                self.prefix.evict(self.page_refs, self.free_pages, need)
            # preempt STRICTLY lower-priority running work for the shortfall.
            # Victims never outrank the candidate, so a preempted request can
            # never preempt its preemptor back; the per-pass budget bounds
            # the spill churn a single admission wave can cause.
            while need > len(self.free_pages) and budget > 0:
                victims = [v for v in range(self.num_slots) if self.active[v]
                           and self.slot_req[v].priority < req.priority]
                if not victims:
                    break
                v = min(victims, key=lambda v: (self.slot_req[v].priority,
                                                -self.slot_req[v].rid))
                self._preempt_slot(v)
                budget -= 1
            if need > len(self.free_pages):
                self._release_pages(match.pages)   # unpin; retry next round
                self._requeue(req)
                break                      # wait for retirements
            row: List[int] = []
            ok = True
            pinned_tail = [match.pages[-1]] if match.tail_tokens else []
            shared_full = (match.pages[:-1] if match.tail_tokens
                           else match.pages)
            row.extend(shared_full)        # pin becomes the slot's map ref
            if match.tail_tokens:          # copy-on-write the boundary page
                dst = self._alloc_page()
                if dst is None:
                    ok = False
                else:
                    self.kv = KVC.copy_pool_pages(self.kv, match.pages[-1],
                                                  dst)
                    self.cow_copies += 1
                    self._release_pages(pinned_tail)   # unpin the source
                    pinned_tail = []
                    row.append(dst)
            row.extend(handed)         # page-handle: refs already travelled
            while ok and len(row) < total:
                p = self._alloc_page()
                if p is None:
                    ok = False
                else:
                    row.append(p)
            if not ok:
                # the allocator refused mid-build (fault injection, or a
                # racing eviction): unwind every ref this admission took —
                # NOT the handed migration pages, whose refs belong to the
                # in-transit request — and retry next step; never leave a
                # half-mapped slot
                keep = set(handed)
                self._release_pages([p for p in row if p not in keep]
                                    + pinned_tail)
                self._requeue(req)
                break
            req.pages = row
            if not restoring:
                req.shared_tokens = match.n_tokens
            if self.prefix is not None and match.n_tokens > 0:
                self.prefix.hits += 1
                self.prefix.tokens_shared += match.n_tokens
            self.table[s, :] = KVC.TRASH_PAGE
            self.table[s, :len(row)] = row
            self.lengths[s] = match.n_tokens   # prefill resumes at the suffix
            self.plens[s] = len(req.prompt)
            self.stop_at[s] = len(req.prompt) + req.max_new
            self.prompt_buf[s, :] = 0
            self.prompt_buf[s, :len(req.prompt)] = req.prompt
            self.slot_req[s] = req
            self.active[s] = True
            new_slots[s] = True
            admitted.append((s, req, restoring))
        if new_slots.any():
            # recycled slots must not inherit the previous occupant's
            # per-slot state (recurrent mamba/xLSTM, cross blocks); paged KV
            # needs no reset — length masking hides stale pages.
            self.kv = self.dbm.model.reset_paged_slots(
                self.kv, jnp.asarray(new_slots))
        for s, req, restoring in admitted:   # AFTER the reset:
            if restoring:                    # scatter the spill snapshot back
                self._restore_into_slot(s, req)
            else:                            # encode-once-per-request
                self._write_conditioning(s, req)
        return int(new_slots.sum())

    def _register_prefixes(self):
        """Insert freshly-completed prompts' prefix pages into the cache so
        later requests can share them (the cache takes one ref per page)."""
        if self.prefix is None:
            return
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if (req is None or req.registered or not self.active[s]
                    or self.lengths[s] < self.plens[s]):
                continue
            npg = KVC.pages_for(int(self.plens[s]), self.page_size)
            self.prefix.insert(req.prompt,
                               [int(self.table[s, i]) for i in range(npg)],
                               self.page_refs, req.cond_fp)
            req.registered = True

    # ---- preemption / migration: page spill, detach, restore ----------
    def _clear_slot_row(self, s: int) -> None:
        """Blank slot ``s``'s scheduling row after its request left (spill,
        detach or retire) — the slot is recyclable afterwards."""
        self.table[s, :] = KVC.TRASH_PAGE
        self.active[s] = False
        self.cond_lengths[s] = 0
        self.lengths[s] = self.plens[s] = self.stop_at[s] = 0
        self.slot_req[s] = None

    def _spill_slot(self, s: int) -> Request:
        """Spill slot ``s`` to host memory and free it: the content of its
        USED pages (``pages_for(lengths[s])`` — later pages are scratch
        hidden by length-aware masking) and its dense per-slot rows
        (recurrent / cross state, ``model.paged_state_axes``) snapshot to
        numpy, its page refs release, and the request pops with the snapshot
        attached. Restore happens at a later admission — possibly into a
        DIFFERENT batcher's pool (the disaggregation router migrates
        finished-prefill requests this way) — via ``_restore_into_slot``;
        the round trip is rng-neutral: no dispatch runs for a spilled slot,
        so nothing perturbs the decode rng stream (same discipline as
        ``pause``)."""
        req = self.slot_req[s]
        n_used = KVC.pages_for(int(self.lengths[s]), self.page_size)
        used = [int(self.table[s, i]) for i in range(n_used)]
        req.spilled = KVC.spill_slot(self.kv, s, used, self._axes)
        req.spill_meta = dict(length=int(self.lengths[s]),
                              cond_length=int(self.cond_lengths[s]))
        self._release_pages(req.pages)
        req.pages = []
        self._clear_slot_row(s)
        return req

    def _detach_slot(self, s: int) -> Request:
        """Page-handle variant of ``_spill_slot`` for batchers on a SHARED
        pool: snapshot only the dense per-slot rows and hand the USED
        physical pages themselves to the request (``handoff_pages`` — their
        refs travel with it; scratch tail pages release). The receiving
        batcher maps those pages instead of allocating + byte-copying, so
        the migration moves the page table, not the KV bytes. Shared prefix
        pages stay shared: their refcount rides along and the receiver's
        copy-on-write machinery still guards divergent writes."""
        req = self.slot_req[s]
        n_used = KVC.pages_for(int(self.lengths[s]), self.page_size)
        req.handoff_pages = [int(self.table[s, i]) for i in range(n_used)]
        req.spilled = KVC.spill_slot(self.kv, s, [], self._axes)
        req.spill_meta = dict(length=int(self.lengths[s]),
                              cond_length=int(self.cond_lengths[s]))
        self._release_pages(req.pages[n_used:])
        req.pages = []
        self._clear_slot_row(s)
        return req

    def _preempt_slot(self, s: int) -> Request:
        """Spill slot ``s`` and re-queue its request at the FRONT with its
        original rid, partial output intact (pool-pressure preemption)."""
        req = self._spill_slot(s)
        req.preempt_count += 1
        self.preemptions += 1
        self._requeue(req)
        return req

    def _drop_payload(self, req: Request) -> None:
        """Discard an unrestored migration/preemption payload when its
        request dies in the queue (cancel, deadline, abort): the host
        snapshot drops, and page-handle refs return to the shared pool —
        queued requests must never keep pages past their death."""
        if req.handoff_pages:
            self._release_pages(req.handoff_pages)
        req.handoff_pages = None
        req.spilled = req.spill_meta = None

    def _restore_into_slot(self, s: int, req: Request):
        """Scatter a spilled request's snapshot into its freshly mapped slot
        (after ``reset_paged_slots`` zeroed the row): page content lands in
        the slot's new private pages (none for a page-handle migration — the
        handed pages already hold it), dense rows overwrite the reset state,
        and the scheduling row resumes at the spilled length. The physical
        page ids usually differ from the spill-time ones — only the logical
        order matters."""
        meta, n = req.spill_meta, req.spilled.n_pages
        self.kv = KVC.restore_slot(self.kv, s, req.pages[:n], req.spilled,
                                   self._axes)
        self.lengths[s] = meta["length"]
        self.cond_lengths[s] = meta["cond_length"]
        req.spilled = req.spill_meta = None
        req.handoff_pages = None
        self.restores += 1

    def _apply_preemptions(self):
        """Apply pending ``preempt`` calls (scheduling thread, between
        dispatches) — the forced-preemption twin of
        ``_apply_cancellations``."""
        with self._lock:
            pre, self._preempt_pending = self._preempt_pending, set()
        if not pre:
            return
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is not None and self.active[s] and req.rid in pre:
                self._preempt_slot(s)

    def _make_writable_or_preempt(self, s: int, lo: int, hi: int) -> bool:
        """Copy-on-write with a preemption fallback — the no-deadlock
        replacement for raising on pool exhaustion. On CoW failure the
        lowest-priority active peer at <= this slot's priority spills
        (freeing its pages) and the CoW retries; when no peer is eligible,
        ``s`` ITSELF spills — spilling needs no allocation, so this always
        terminates with the pool whole. Returns False when ``s`` was
        spilled (the caller excludes it from the dispatch)."""
        while True:
            if self._make_writable(s, lo, hi):
                return True
            me = self.slot_req[s]
            victims = [v for v in range(self.num_slots)
                       if v != s and self.active[v]
                       and self.slot_req[v].priority <= me.priority]
            if not victims:
                self._preempt_slot(s)
                return False
            v = min(victims, key=lambda v: (self.slot_req[v].priority,
                                            -self.slot_req[v].rid))
            self._preempt_slot(v)

    # ---- SLO deadlines -----------------------------------------------
    def _enforce_deadlines(self) -> List[Request]:
        """Retire deadline-blown requests with their partial output: queued
        requests past their TTFT deadline are dropped before wasting
        admission; active slots are retired when the first token is late
        (TTFT) or the output pace falls behind ``tpot_deadline_s`` (measured
        over emitted tokens; paused slots are the CONSUMER's stall, not
        ours, and are exempt while paused)."""
        now = time.time()
        out: List[Request] = []
        with self._lock:
            kept: collections.deque = collections.deque()
            for r in self.queue:
                if (r.ttft_deadline is not None and r.first_token_t is None
                        and now > r.ttft_deadline):
                    r.deadline_blown = True
                    r.error = "ttft deadline exceeded"
                    self._drop_payload(r)
                    out.append(r)
                else:
                    kept.append(r)
            self.queue = kept
            paused = set(self._paused)
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None or not self.active[s] or req.rid in paused:
                continue
            blown = None
            if (req.ttft_deadline is not None and req.first_token_t is None
                    and now > req.ttft_deadline):
                blown = "ttft deadline exceeded"
            elif (req.tpot_deadline_s is not None and len(req.out) >= 2
                  and ((now - req.first_token_t) / (len(req.out) - 1)
                       > req.tpot_deadline_s)):
                blown = "tpot deadline exceeded"
            if blown:
                req.deadline_blown = True
                req.error = blown
                out.append(self._retire_slot(s))
        self.deadline_cancels += len(out)
        return out

    def recover(self):
        """Crash recovery (the ``EngineRunner`` supervisor calls this before
        restarting the engine thread): spill every active slot back to the
        queue, so the fresh loop re-admits and resumes them with no token
        loss or duplication — ``req.out`` persists and ``_collect`` only
        appends newly emitted tokens."""
        with self._pool_lock:
            for s in range(self.num_slots):
                if self.active[s]:
                    self._preempt_slot(s)

    def abort_all(self, msg: str) -> List[Request]:
        """Error out every queued and active request (the supervisor giving
        up after repeated crashes): slots retire, pages return to the pool,
        and each request carries ``error=msg`` so its stream can finish
        cleanly instead of hanging. Returns the aborted requests."""
        with self._pool_lock:
            with self._lock:
                reqs = list(self.queue)
                self.queue.clear()
            for r in reqs:
                self._drop_payload(r)
            for s in range(self.num_slots):
                if self.slot_req[s] is not None and self.active[s]:
                    reqs.append(self._retire_slot(s))
        for r in reqs:
            r.error = r.error or msg
        return reqs

    def extract_all(self, detach: bool = False) -> List[Request]:
        """Pop every queued and active request WITHOUT erroring them — the
        failover harvest after this batcher's worker died. By default active
        slots release their pages (their device KV died with the worker;
        partial output and any unrestored migration payload survive on the
        host, so the router re-prefills). ``detach=True`` — shared-pool
        failover, where the KV physically survives in the common segment —
        hands each active slot's used pages to its request
        (``handoff_pages``) so the router can re-migrate without replay.
        Queued requests pop as-is, payloads intact. The pool ends whole and
        the router re-routes the survivors."""
        with self._pool_lock:
            with self._lock:
                reqs = list(self.queue)
                self.queue.clear()
            for s in range(self.num_slots):
                if self.slot_req[s] is not None and self.active[s]:
                    reqs.append(self._detach_slot(s) if detach
                                else self._retire_slot(s))
        return reqs

    def _retire_slot(self, s: int) -> Request:
        """Free slot ``s``: release its request's page refs (shared pages
        survive while the prefix cache or another slot still holds them),
        blank the page-table row, and mark the slot recyclable."""
        req = self.slot_req[s]
        self._release_pages(req.pages)
        req.pages = []
        self.table[s, :] = KVC.TRASH_PAGE
        self.active[s] = False
        self.cond_lengths[s] = 0
        # zero the scheduling row: a slot cancelled mid-prefill would
        # otherwise keep lengths < plens and make every later chunk dispatch
        # commit its dead prompt into the trash page
        self.lengths[s] = self.plens[s] = self.stop_at[s] = 0
        self.slot_req[s] = None
        with self._lock:
            self._paused.discard(req.rid)
        return req

    def _retire(self) -> List[Request]:
        out = []
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None or not self.active[s]:
                continue
            if self.lengths[s] >= self.stop_at[s]:
                self._note_service(time.time() - req.submit_t)
                out.append(self._retire_slot(s))
        return out

    def _apply_cancellations(self) -> List[Request]:
        """Apply pending ``cancel`` calls (scheduling thread, between
        dispatches): drop queued requests, retire cancelled slots and free
        their pages. Returns the cancelled requests."""
        with self._lock:
            cancels, self._cancel_pending = self._cancel_pending, set()
        if not cancels:
            return []
        out = []
        with self._lock:
            kept = collections.deque()
            for r in self.queue:
                if r.rid in cancels:
                    r.cancelled = True
                    self._drop_payload(r)
                    out.append(r)
                else:
                    kept.append(r)
            self.queue = kept
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is not None and req.rid in cancels:
                req.cancelled = True
                out.append(self._retire_slot(s))
        self.cancelled_count += len(out)
        return out

    def _collect(self, emitted: np.ndarray):
        now = time.time()
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None:
                continue
            toks = [int(t) for t in emitted[s] if t >= 0]
            if toks and req.first_token_t is None:
                req.first_token_t = now
            req.out.extend(toks)
            if toks and self.faults is not None:
                self.faults.maybe_sleep("token_stall")
            if toks and self.token_cb is not None:
                self.token_cb(req, toks)

    def _paused_mask(self) -> np.ndarray:
        with self._lock:
            paused = set(self._paused)
        if not paused:
            return np.zeros(self.num_slots, bool)
        return np.array([self.slot_req[s] is not None
                         and self.slot_req[s].rid in paused
                         for s in range(self.num_slots)])

    def has_work(self) -> bool:
        """True while a step could make progress OR bookkeeping is pending
        (queued/active requests, unapplied cancels)."""
        with self._lock:
            pending = bool(self._cancel_pending)
        return pending or bool(self.queue) or bool(self.active.any())

    def step(self, rng, *, strict: bool = True):
        """ONE scheduling iteration: apply pending cancellations, admit
        queued requests into free slots, run one prefill-chunk dispatch
        (chunked mode) and one ``seg_len``-step decode segment, then retire
        finished slots. Returns ``(rng, finished)`` — the requests that
        completed (or were cancelled / rejected) this iteration.

        ``strict=True`` (the ``run()`` default) raises when the head of the
        queue can never be admitted (pool too small and nothing running);
        ``strict=False`` — the serving frontend — instead pops that request
        with ``req.error`` set so one impossible request cannot wedge the
        engine loop.

        Copy-on-write exhaustion no longer raises in EITHER mode: the
        scheduler spills the lowest-priority active slot to host memory
        instead (``_make_writable_or_preempt``), so pool pressure degrades
        to preemption latency, never a deadlock or a lost request.

        On a ``SharedPagePool`` the step serializes with every sharing
        batcher under the pool lock, pulling the canonical paged leaves
        before mutating and publishing them after — even when the body
        raises (an injected crash), so the pool view other workers adopt is
        never lost."""
        with self._pool_lock:
            if self._shared is not None:
                self.kv = _graft_paged(self.kv, self._shared.paged)
            try:
                return self._step(rng, strict=strict)
            finally:
                if self._shared is not None:
                    self._shared.paged = _paged_leaves(self.kv)

    def _step(self, rng, *, strict: bool = True):
        if self.faults is not None:
            # injected BEFORE any bookkeeping mutates, so a crash at this
            # hook leaves the batcher consistent for recover(); worker_die
            # is the harder failure — the supervisor treats it as process
            # death (no restart), the ROUTER must fail the work over
            self.faults.maybe_raise("engine_crash")
            if self.faults.fire("worker_die"):
                raise WorkerDied(
                    f"injected worker_die "
                    f"(call {self.faults.calls['worker_die']})")
        finished = self._apply_cancellations()
        self._apply_preemptions()
        finished.extend(self._enforce_deadlines())
        if not (self.queue or self.active.any()):
            return rng, finished
        if not self._admit() and not self.active.any():
            # nothing running and nothing admitted: IMPOSSIBLE only when the
            # head request needs more pages than the pool can ever hold — a
            # transient allocator refusal (fault injection, racing eviction)
            # just retries next step
            req = self._pop_best()
            if req is None:
                return rng, finished
            need = KVC.pages_for(len(req.prompt) + req.max_new,
                                 self.page_size)
            if need <= self.total_pages - 1:
                self._requeue(req)
                return rng, finished
            msg = ("page pool too small for the next queued request "
                   f"(needs {need} of {self.total_pages - 1} pages)")
            if strict:
                self._requeue(req)
                raise RuntimeError(msg)
            req.error = msg
            self._drop_payload(req)
            finished.append(req)
            return rng, finished
        in_prompt = self.active & (self.lengths < self.plens)
        if self.chunked and in_prompt.any():
            # ONE chunk dispatch advances every prefilling slot by up to
            # chunk_size tokens at its own offset; decode-only slots see
            # n_valid == 0 inside the program.
            for s in np.nonzero(in_prompt)[0]:
                if not self.active[s]:
                    continue        # spilled by an earlier slot's CoW relief
                lo = int(self.lengths[s])
                hi = min(lo + self.chunk_size, int(self.plens[s]))
                self._make_writable_or_preempt(s, lo, hi)
            in_prompt = self.active & (self.lengths < self.plens)
        if self.chunked and in_prompt.any():
            self.kv, lengths = self.eng._prefill_chunk1(
                self.params, self.kv, jnp.asarray(self.table),
                jnp.asarray(self.lengths), jnp.asarray(self.prompt_buf),
                jnp.asarray(self.plens), jnp.asarray(self.cond_lengths))
            self.lengths = np.array(lengths)
            self.eng.dispatches += 1
            self.eng.prefill_steps += 1
            self.ingest_dispatches += 1
            self._register_prefixes()
        decode_ready = (self.active & (self.lengths >= self.plens)
                        if self.chunked else self.active)
        decode_ready = decode_ready & ~self._paused_mask()
        if decode_ready.any():
            for s in np.nonzero(decode_ready)[0]:
                if not self.active[s]:
                    continue        # spilled by an earlier slot's CoW relief
                lo = int(self.lengths[s])
                hi = min(lo + self.seg_len, int(self.stop_at[s]))
                self._make_writable_or_preempt(s, lo, hi)
            decode_ready = decode_ready & self.active
        if decode_ready.any():
            self.kv, lengths, rng, emitted = self.eng._serve(
                self.params, self.kv, jnp.asarray(self.table),
                jnp.asarray(self.lengths), jnp.asarray(self.prompt_buf),
                jnp.asarray(self.plens), jnp.asarray(self.stop_at),
                jnp.asarray(decode_ready), rng,
                jnp.asarray(self.cond_lengths), n=self.seg_len)
            self.eng.dispatches += 1
            self.decode_dispatches += 1
            self.steps += self.seg_len
            self.lengths = np.array(lengths)           # host copy
            self._collect(np.asarray(emitted))         # (slots, seg)
            if not self.chunked:
                self._register_prefixes()
        finished.extend(self._retire())
        return rng, finished

    def run(self, rng=None) -> List[Request]:
        """Drain the queue; returns finished requests (ordered by rid)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        finished = []
        while self.has_work():
            rng, fin = self.step(rng)
            finished.extend(fin)
        return sorted(finished, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--scheduler", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--steps-per-block", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--precision", default="bf16")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("int8", "bf16", "fp32", "auto"),
                    help="paged KV pool storage dtype: int8 quantizes pages "
                         "with one fp32 absmax scale per page per tensor "
                         "(halves pool bytes again vs bf16); default follows "
                         "--precision")
    ap.add_argument("--impl", default="auto",
                    help="attention impl: auto | kernels (Pallas flash-"
                         "decode + flash-prefill; interpret-mode on CPU)")
    ap.add_argument("--prefill", choices=("chunked", "per-token"),
                    default="chunked",
                    help="prompt ingest: chunked (C tokens per scan step) "
                         "or the per-token reference scan")
    ap.add_argument("--chunk-size", type=int, default=DEFAULT_CHUNK,
                    help="prompt tokens per chunked-prefill dispatch")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous: share prompt-prefix pages across "
                         "requests (copy-on-write)")
    ap.add_argument("--page-size", type=int, default=KVC.DEFAULT_PAGE_SIZE)
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous: concurrent request slots")
    ap.add_argument("--seg-len", type=int, default=16,
                    help="continuous: scan steps between host scheduling")
    ap.add_argument("--requests", type=int, default=12,
                    help="continuous: queued requests (ragged prompts)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch/queue")
    ap.add_argument("--conditioned", action="store_true",
                    help="attach aux conditioning (VLM/audio archs): random "
                         "image/audio embeddings drawn from a small pool so "
                         "the conditioning-aware prefix cache can hit")
    ap.add_argument("--cond-pool", type=int, default=3,
                    help="distinct conditioning inputs in the pool")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(args.blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params = load_blocks(args.ckpt_dir, params, dbm.ranges)

    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=7)
    rs = np.random.RandomState(1)
    aux_key, cond_pool = None, []
    if args.conditioned:
        specs = dbm.model.aux_input_specs(1)
        if not specs:
            raise SystemExit(f"--conditioned: family {cfg.family!r} takes "
                             "no aux inputs (pick a vlm/audio arch)")
        aux_key = next(iter(specs))
        Sk = dbm.model.max_cond_tokens
        cond_pool = [rs.randn(Sk, cfg.d_model).astype(np.float32)
                     for _ in range(args.cond_pool)]
    kw = dict(steps_per_block=args.steps_per_block,
              temperature=args.temperature, top_k=args.top_k,
              precision=args.precision, kv_dtype=args.kv_dtype,
              impl=args.impl,
              prefill=args.prefill,
              chunk_size=min(args.chunk_size, max(args.prompt_len, 1)))

    if args.scheduler == "static":
        prompts = jnp.asarray(lm.sample(rs, args.batch, args.prompt_len))
        plens = None
        if args.ragged:
            plens = rs.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1, size=args.batch)
        aux = (None if aux_key is None else
               {aux_key: jnp.asarray(np.stack([cond_pool[0]] * args.batch))})
        eng = get_engine(dbm, **kw)
        t0 = time.time()
        out = eng.generate(params, prompts, args.max_new,
                           prompt_lengths=plens, page_size=args.page_size,
                           aux_inputs=aux)
        jax.block_until_ready(out)
        dt = time.time() - t0
        n_tok = args.batch * args.max_new
        pps = KVC.pages_for(args.prompt_len + args.max_new, args.page_size)
        pool_abstract = jax.eval_shape(          # report size; allocate nothing
            lambda: dbm.model.init_paged_cache(
                args.batch, 1 + args.batch * pps, args.page_size,
                precision_mod.with_kv_dtype(args.precision, args.kv_dtype)))
        print(f"[static] generated {args.batch}x{args.max_new} tokens in "
              f"{dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile) | "
              f"dispatches={eng.dispatches} "
              f"({eng.dispatches/n_tok:.3f}/token) | "
              f"prefill={args.prefill} "
              f"({eng.prefill_steps} serial steps for "
              f"{args.batch}x{args.prompt_len} prompt tokens) | "
              f"cache={KVC.cache_bytes(pool_abstract)/1e6:.1f}MB paged")
        rows = np.array(out)
        lens = (np.asarray(plens) if plens is not None
                else np.full(args.batch, args.prompt_len)) + args.max_new
        print("legal-transition rate:", _ragged_transition_accuracy(
            lm, [rows[b, :lens[b]] for b in range(args.batch)]))
    else:
        cb = ContinuousBatcher(dbm, params, num_slots=args.num_slots,
                               page_size=args.page_size,
                               max_prompt=args.prompt_len,
                               max_len=args.prompt_len + args.max_new,
                               seg_len=args.seg_len,
                               prefix_cache=args.prefix_cache, **kw)
        for i in range(args.requests):
            plen = (rs.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1)
                    if args.ragged else args.prompt_len)
            aux = (None if aux_key is None else
                   {aux_key: cond_pool[i % len(cond_pool)]})
            cb.submit(lm.sample(rs, 1, plen)[0], args.max_new,
                      aux_inputs=aux)
        t0 = time.time()
        done = cb.run(jax.random.PRNGKey(0))
        dt = time.time() - t0
        n_tok = sum(len(r.out) for r in done)
        ttfts = [r.ttft for r in done if r.ttft is not None]
        shared = sum(r.shared_tokens for r in done)
        print(f"[continuous] served {len(done)} requests / {n_tok} tokens "
              f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile) | "
              f"slots={args.num_slots} pool={cb.total_pages} pages x "
              f"{args.page_size} | dispatches={cb.eng.dispatches} "
              f"({cb.eng.dispatches/max(n_tok,1):.3f}/token) | "
              f"mean TTFT {np.mean(ttfts):.3f}s | "
              f"cache={KVC.cache_bytes(cb.kv)/1e6:.1f}MB paged")
        if cb.prefix is not None:
            print(f"prefix cache: {cb.prefix.hits} hits, {shared} prompt "
                  f"tokens served from shared pages, {cb.cow_copies} "
                  f"copy-on-write page copies")
        seqs = [np.concatenate([r.prompt, np.asarray(r.out, np.int64)])
                for r in done]
        print("legal-transition rate:",
              _ragged_transition_accuracy(lm, seqs))


if __name__ == "__main__":
    main()
