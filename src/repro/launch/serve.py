"""Batched block-wise serving driver: prefill a batch of prompts, then
generate with the DiffusionBlocks sampler (one Euler step per block per token
by default — compute-equivalent to a standard forward pass, paper App. H).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig, get_config, reduced
from repro.core import DiffusionBlocksModel
from repro.checkpoint import load_blocks
from repro.data import MarkovLM


def generate(dbm, params, prompts: jnp.ndarray, max_new: int,
             steps_per_block: int = 1, rng=None):
    """prompts: (B, S0) -> (B, S0+max_new).

    Prefill commits the whole prompt inside ONE jitted ``lax.scan`` over
    positions — O(1) dispatches instead of one jitted call per prompt token
    (the per-token Python loop paid ~1 dispatch + host sync per token)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B, S0 = prompts.shape
    cache = dbm.model.init_cache(B, S0 + max_new, jnp.float32)
    ctx0 = dbm.make_ctx(params, 1, "decode")
    ctx0.positions = None
    serve = jax.jit(lambda p, c, pos, r: dbm.serve_step(
        p, c, pos, r, steps_per_block=steps_per_block))

    @jax.jit
    def prefill_commits(p, c, toks):
        def body(c, xs):
            pos, tok = xs
            return dbm.commit_token(p, c, pos, tok[:, None], ctx0), None
        c, _ = jax.lax.scan(body, c, (jnp.arange(S0), toks.T))
        return c

    cache = prefill_commits(params, cache, prompts)
    out = [prompts]
    for t in range(S0, S0 + max_new):
        rng, rs = jax.random.split(rng)
        tok, cache = serve(params, cache, t, rs)
        out.append(tok[:, None])
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(args.blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params = load_blocks(args.ckpt_dir, params, dbm.ranges)

    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=7)
    prompts = jnp.asarray(lm.sample(np.random.RandomState(1), args.batch,
                                    args.prompt_len))
    t0 = time.time()
    out = generate(dbm, params, prompts, args.max_new)
    dt = time.time() - t0
    gen = np.array(out[:, args.prompt_len:])
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")
    print("legal-transition rate:", lm.transition_accuracy(np.array(out)))


if __name__ == "__main__":
    main()
