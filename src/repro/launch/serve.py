"""High-throughput block-wise serving: scan-fused generation over a paged
bf16 KV cache, with static and continuous-batching schedulers.

The seed served one jitted dispatch PLUS a host sync per generated token and
kept a dense fp32 worst-case cache slab. This engine:

  * folds the whole denoise → sample → commit loop into ONE jitted
    ``lax.scan`` over new-token positions (greedy and temperature/top-k both
    traced — no per-token host round-trip);
  * prefills ragged prompts inside one scan with per-slot activity masks —
    different prompt lengths share ONE compiled program (masking is
    length-aware, never shape-aware);
  * stores KV in the paged pool of ``repro.nn.cache`` (bf16 under the
    default ``precision="bf16"`` policy, fp32 logsumexp in the attend);
  * optionally routes decode attention through the split-KV Pallas
    flash-decode kernel (``--impl kernels``).

Schedulers (``--scheduler``):

  static      admit the whole batch, prefill, then one decode scan —
              O(1) dispatches for the entire batch of generations.
  continuous  slot-based continuous batching: a fixed number of request
              slots over a shared page pool. Between scan SEGMENTS the host
              admits queued requests into freed slots/pages and retires
              finished sequences; inside a segment, slots still consuming
              their prompt commit prompt tokens while neighbors generate.

Compile-cache notes: ``steps_per_block`` / ``temperature`` / ``top_k`` /
``precision`` / ``impl`` are STATIC — they select the trace. ``DecodeEngine``
instances are memoized per (dbm, static config) by ``get_engine``, so
repeated ``generate`` calls reuse compiled programs; only a new padded
prompt width or segment length triggers a retrace.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _ragged_transition_accuracy(lm, seqs) -> float:
    """Mean legal-transition rate over variable-length sequences — scored
    per row so zero-padding never fabricates (or breaks) transitions."""
    return float(np.mean([lm.transition_accuracy(np.asarray(s)[None])
                          for s in seqs]))

from repro import precision as precision_mod
from repro.configs import DBConfig, get_config, reduced
from repro.core import DiffusionBlocksModel
from repro.checkpoint import load_blocks
from repro.data import MarkovLM
from repro.nn import cache as KVC


class DecodeEngine:
    """Owns the jitted scan-fused programs for one (model, static config).

    Three programs, all length-aware over the paged cache:
      _prefill  scan over prompt positions, committing where t < plens[b]
      _decode   scan over new-token positions: denoise → sample → commit
      _serve    continuous-batching segment: each slot either commits its
                next PROMPT token (still prefilling) or a GENERATED token
    """

    def __init__(self, dbm: DiffusionBlocksModel, *, steps_per_block: int = 1,
                 temperature: float = 0.0, top_k: int = 0,
                 precision="bf16", impl: str = "auto"):
        self.dbm = dbm
        self.pol = precision_mod.get_policy(precision)
        self.impl = impl
        self.dispatches = 0          # jitted-call count (throughput reporting)
        pol, spb = self.pol, steps_per_block
        temp, tk = temperature, top_k

        def prefill_scan(params, kv, page_table, lengths, prompts, plens):
            def body(carry, t):
                kv, lengths = carry
                act = t < plens
                tok = jnp.take(prompts, t, axis=1)
                kv, lengths = dbm.commit_prompt_token(
                    params, kv, page_table, lengths, tok[:, None],
                    active=act, precision=pol, impl=impl)
                return (kv, lengths), None
            return jax.lax.scan(body, (kv, lengths),
                                jnp.arange(prompts.shape[1]))[0]

        def decode_scan(params, kv, page_table, lengths, stop_at, rng, n):
            def body(carry, _):
                kv, lengths, rng = carry
                rng, rs = jax.random.split(rng)
                act = lengths < stop_at
                tok, kv, lengths = dbm.serve_step_paged(
                    params, kv, page_table, lengths, rs, active=act,
                    steps_per_block=spb, temperature=temp, top_k=tk,
                    precision=pol, impl=impl)
                return (kv, lengths, rng), tok
            (kv, lengths, rng), toks = jax.lax.scan(
                body, (kv, lengths, rng), None, length=n)
            return kv, lengths, rng, toks.T          # (B, n)

        def serve_scan(params, kv, page_table, lengths, prompt_buf, plens,
                       stop_at, active, rng, n):
            def body(carry, _):
                kv, lengths, rng = carry
                rng, rs = jax.random.split(rng)
                in_prompt = lengths < plens
                idx = jnp.clip(lengths, 0, prompt_buf.shape[1] - 1)
                ptok = jnp.take_along_axis(prompt_buf, idx[:, None], 1)[:, 0]
                act = active & (lengths < stop_at)
                ctx = dbm._paged_ctx(params, lengths, page_table, act, pol,
                                     impl)
                rn, rsamp = jax.random.split(rs)
                d = dbm.denoise_next_token(params, kv, None, rn, ctx, spb)
                logits = dbm.model.logits(params, d)
                gtok = dbm.sample_token(logits[:, 0], rsamp, temp, tk)
                tok = jnp.where(in_prompt, ptok, gtok)
                kv = dbm.commit_token(params, kv, None, tok[:, None], ctx)
                emitted = jnp.where(act & ~in_prompt, tok, -1)
                lengths = lengths + act.astype(lengths.dtype)
                return (kv, lengths, rng), emitted
            (kv, lengths, rng), toks = jax.lax.scan(
                body, (kv, lengths, rng), None, length=n)
            return kv, lengths, rng, toks.T          # (B, n); -1 = no emit

        self._prefill = jax.jit(prefill_scan)
        self._decode = jax.jit(decode_scan, static_argnames=("n",))
        self._serve = jax.jit(serve_scan, static_argnames=("n",))

    # ------------------------------------------------------------------
    def generate(self, params, prompts, max_new: int, rng=None, *,
                 prompt_lengths=None, page_size: int = KVC.DEFAULT_PAGE_SIZE,
                 reference: bool = False):
        """Static-batch generation. prompts: (B, S0) (right-padded when
        ``prompt_lengths`` is ragged) -> (B, S0 + max_new); row b holds its
        prompt then its ``max_new`` generated tokens starting at
        ``prompt_lengths[b]``.

        ``reference=True`` replays the seed serving loop faithfully — one
        jitted dispatch + host sync per generated token — through the SAME
        step function, so greedy outputs are bit-identical to the fused scan
        (the decode-parity tests and ``benchmarks/table15_decode`` rely on
        this).
        """
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts)
        B, S0 = prompts.shape
        plens = (jnp.full((B,), S0, jnp.int32) if prompt_lengths is None
                 else jnp.asarray(prompt_lengths, jnp.int32))
        pps = KVC.pages_for(int(jnp.max(plens)) + max_new, page_size)
        kv = self.dbm.model.init_paged_cache(B, 1 + B * pps, page_size,
                                             self.pol)
        table = KVC.identity_page_table(B, pps)
        lengths = jnp.zeros((B,), jnp.int32)
        kv, lengths = self._prefill(params, kv, table, lengths,
                                    prompts.astype(jnp.int32), plens)
        self.dispatches += 1
        stop_at = plens + max_new
        if reference:
            cols = []
            for _ in range(max_new):
                kv, lengths, rng, t = self._decode(params, kv, table, lengths,
                                                   stop_at, rng, n=1)
                self.dispatches += 1
                cols.append(np.asarray(t))       # host sync per token (seed)
            gen = np.concatenate(cols, axis=1)
        else:
            kv, lengths, rng, t = self._decode(params, kv, table, lengths,
                                               stop_at, rng, n=max_new)
            self.dispatches += 1
            gen = np.asarray(t)
        out = np.zeros((B, S0 + max_new), dtype=np.asarray(prompts).dtype)
        pl = np.asarray(plens)
        pr = np.asarray(prompts)
        for b in range(B):
            out[b, :pl[b]] = pr[b, :pl[b]]
            out[b, pl[b]:pl[b] + max_new] = gen[b]
        return jnp.asarray(out)


_ENGINE_DEFAULTS = dict(steps_per_block=1, temperature=0.0, top_k=0,
                        precision="bf16", impl="auto")


def get_engine(dbm: DiffusionBlocksModel, **config) -> DecodeEngine:
    """Memoized engine per (dbm, static config): repeated ``generate`` calls
    reuse the compiled scan programs instead of thrashing the jit cache.
    The key is normalized against the engine defaults, so ``get_engine(dbm)``
    and an explicit-defaults call share one engine."""
    cfg = {**_ENGINE_DEFAULTS, **config}
    cfg["precision"] = precision_mod.get_policy(cfg["precision"]).name
    key = tuple(sorted(cfg.items()))
    cache = dbm.__dict__.setdefault("_serve_engines", {})
    if key not in cache:
        cache[key] = DecodeEngine(dbm, **cfg)
    return cache[key]


def generate(dbm, params, prompts: jnp.ndarray, max_new: int,
             steps_per_block: int = 1, rng=None, *, prompt_lengths=None,
             temperature: float = 0.0, top_k: int = 0, precision="bf16",
             impl: str = "auto", page_size: int = KVC.DEFAULT_PAGE_SIZE,
             reference: bool = False):
    """prompts: (B, S0) -> (B, S0 + max_new), scan-fused over the paged
    bf16 KV cache (see DecodeEngine). The cache dtype follows the
    ``repro.precision`` policy (bf16 KV by default; recurrent states keep
    their family override). ``reference=True`` = seed-style per-token loop
    (same math, one dispatch + host sync per token)."""
    eng = get_engine(dbm, steps_per_block=steps_per_block,
                     temperature=temperature, top_k=top_k,
                     precision=precision, impl=impl)
    return eng.generate(params, prompts, max_new, rng,
                        prompt_lengths=prompt_lengths, page_size=page_size,
                        reference=reference)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ContinuousBatcher:
    """Slot-based continuous batching over a shared page pool.

    ``num_slots`` request slots share ``total_pages`` physical pages
    (physical page 0 reserved as the trash page). Between scan segments of
    ``seg_len`` steps the host admits queued requests into free slots —
    allocating ``ceil((prompt + max_new) / page_size)`` pages each — and
    retires finished sequences, returning their pages to the free list.
    Inside a segment everything is one compiled program: slots still
    consuming their prompt commit prompt tokens, the rest generate.
    """

    def __init__(self, dbm, params, *, num_slots: int = 8,
                 page_size: int = KVC.DEFAULT_PAGE_SIZE,
                 max_prompt: int = 64, max_len: int = 128,
                 total_pages: Optional[int] = None, seg_len: int = 16,
                 steps_per_block: int = 1, temperature: float = 0.0,
                 top_k: int = 0, precision="bf16", impl: str = "auto"):
        self.dbm, self.params = dbm, params
        self.eng = get_engine(dbm, steps_per_block=steps_per_block,
                              temperature=temperature, top_k=top_k,
                              precision=precision, impl=impl)
        self.page_size, self.seg_len = page_size, seg_len
        self.max_prompt, self.max_len = max_prompt, max_len
        pps = KVC.pages_for(max_len, page_size)
        self.total_pages = (1 + num_slots * pps if total_pages is None
                            else total_pages)
        self.kv = dbm.model.init_paged_cache(num_slots, self.total_pages,
                                             page_size, self.eng.pol)
        self.free_pages = list(range(1, self.total_pages))
        self.num_slots = num_slots
        self.table = np.zeros((num_slots, pps), np.int32)   # 0 = trash page
        self.lengths = np.zeros(num_slots, np.int32)
        self.plens = np.zeros(num_slots, np.int32)
        self.stop_at = np.zeros(num_slots, np.int32)
        self.active = np.zeros(num_slots, bool)
        self.prompt_buf = np.zeros((num_slots, max_prompt), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque = collections.deque()
        self._next_rid = 0
        self.steps = 0               # scan steps executed (all slots)

    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size <= self.max_prompt, "prompt exceeds max_prompt"
        assert prompt.size + max_new <= self.max_len, "request exceeds max_len"
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new))
        return rid

    # ---- host-side scheduling between segments -----------------------
    def _admit(self) -> int:
        new_slots = np.zeros(self.num_slots, bool)
        for s in range(self.num_slots):
            if self.active[s] or not self.queue:
                continue
            req = self.queue[0]
            need = KVC.pages_for(len(req.prompt) + req.max_new,
                                 self.page_size)
            if need > len(self.free_pages):
                break                      # wait for retirements
            self.queue.popleft()
            req.pages = [self.free_pages.pop() for _ in range(need)]
            self.table[s, :] = KVC.TRASH_PAGE
            self.table[s, :need] = req.pages
            self.lengths[s] = 0
            self.plens[s] = len(req.prompt)
            self.stop_at[s] = len(req.prompt) + req.max_new
            self.prompt_buf[s, :] = 0
            self.prompt_buf[s, :len(req.prompt)] = req.prompt
            self.slot_req[s] = req
            self.active[s] = True
            new_slots[s] = True
        if new_slots.any():
            # recycled slots must not inherit the previous occupant's
            # per-slot state (recurrent mamba/xLSTM, cross blocks); paged KV
            # needs no reset — length masking hides stale pages.
            self.kv = self.dbm.model.reset_paged_slots(
                self.kv, jnp.asarray(new_slots))
        return int(new_slots.sum())

    def _retire(self) -> List[Request]:
        out = []
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None or not self.active[s]:
                continue
            if self.lengths[s] >= self.stop_at[s]:
                self.free_pages.extend(req.pages)
                req.pages = []
                self.table[s, :] = KVC.TRASH_PAGE
                self.active[s] = False
                self.slot_req[s] = None
                out.append(req)
        return out

    def run(self, rng=None) -> List[Request]:
        """Drain the queue; returns finished requests (ordered by rid)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        finished = []
        while self.queue or self.active.any():
            if not self._admit() and not self.active.any():
                raise RuntimeError(
                    "page pool too small for the next queued request "
                    f"(free={len(self.free_pages)} pages)")
            self.kv, lengths, rng, emitted = self.eng._serve(
                self.params, self.kv, jnp.asarray(self.table),
                jnp.asarray(self.lengths), jnp.asarray(self.prompt_buf),
                jnp.asarray(self.plens), jnp.asarray(self.stop_at),
                jnp.asarray(self.active), rng, n=self.seg_len)
            self.eng.dispatches += 1
            self.steps += self.seg_len
            self.lengths = np.array(lengths)               # host copy (mutable)
            emitted = np.asarray(emitted)                  # (slots, seg)
            for s in range(self.num_slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                req.out.extend(int(t) for t in emitted[s] if t >= 0)
            finished.extend(self._retire())
        return sorted(finished, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--scheduler", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--steps-per-block", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--precision", default="bf16")
    ap.add_argument("--impl", default="auto",
                    help="decode attention impl: auto | kernels (Pallas "
                         "flash-decode; interpret-mode on CPU)")
    ap.add_argument("--page-size", type=int, default=KVC.DEFAULT_PAGE_SIZE)
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous: concurrent request slots")
    ap.add_argument("--seg-len", type=int, default=16,
                    help="continuous: scan steps between host scheduling")
    ap.add_argument("--requests", type=int, default=12,
                    help="continuous: queued requests (ragged prompts)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch/queue")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(args.blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params = load_blocks(args.ckpt_dir, params, dbm.ranges)

    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=7)
    rs = np.random.RandomState(1)
    kw = dict(steps_per_block=args.steps_per_block,
              temperature=args.temperature, top_k=args.top_k,
              precision=args.precision, impl=args.impl)

    if args.scheduler == "static":
        prompts = jnp.asarray(lm.sample(rs, args.batch, args.prompt_len))
        plens = None
        if args.ragged:
            plens = rs.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1, size=args.batch)
        eng = get_engine(dbm, **kw)
        t0 = time.time()
        out = eng.generate(params, prompts, args.max_new,
                           prompt_lengths=plens, page_size=args.page_size)
        jax.block_until_ready(out)
        dt = time.time() - t0
        n_tok = args.batch * args.max_new
        pps = KVC.pages_for(args.prompt_len + args.max_new, args.page_size)
        pool_abstract = jax.eval_shape(          # report size; allocate nothing
            lambda: dbm.model.init_paged_cache(
                args.batch, 1 + args.batch * pps, args.page_size,
                args.precision))
        print(f"[static] generated {args.batch}x{args.max_new} tokens in "
              f"{dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile) | "
              f"dispatches={eng.dispatches} "
              f"({eng.dispatches/n_tok:.3f}/token) | "
              f"cache={KVC.cache_bytes(pool_abstract)/1e6:.1f}MB paged")
        rows = np.array(out)
        lens = (np.asarray(plens) if plens is not None
                else np.full(args.batch, args.prompt_len)) + args.max_new
        print("legal-transition rate:", _ragged_transition_accuracy(
            lm, [rows[b, :lens[b]] for b in range(args.batch)]))
    else:
        cb = ContinuousBatcher(dbm, params, num_slots=args.num_slots,
                               page_size=args.page_size,
                               max_prompt=args.prompt_len,
                               max_len=args.prompt_len + args.max_new,
                               seg_len=args.seg_len, **kw)
        for _ in range(args.requests):
            plen = (rs.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1)
                    if args.ragged else args.prompt_len)
            cb.submit(lm.sample(rs, 1, plen)[0], args.max_new)
        t0 = time.time()
        done = cb.run(jax.random.PRNGKey(0))
        dt = time.time() - t0
        n_tok = sum(len(r.out) for r in done)
        print(f"[continuous] served {len(done)} requests / {n_tok} tokens "
              f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile) | "
              f"slots={args.num_slots} pool={cb.total_pages} pages x "
              f"{args.page_size} | dispatches={cb.eng.dispatches} "
              f"({cb.eng.dispatches/max(n_tok,1):.3f}/token) | "
              f"cache={KVC.cache_bytes(cb.kv)/1e6:.1f}MB paged")
        seqs = [np.concatenate([r.prompt, np.asarray(r.out, np.int64)])
                for r in done]
        print("legal-transition rate:",
              _ragged_transition_accuracy(lm, seqs))


if __name__ == "__main__":
    main()
