"""Distributed training driver.

Wires the mesh + sharding rules into the DiffusionBlocks training loop:

  * --mode db  (default): block-cycling DB training (paper Fig. 3) — each
    step trains one uniformly-sampled block; gradients/optimizer exist for
    L/B units only.
  * --mode e2e: end-to-end backprop baseline.
  * --block-parallel: every pod trains a DIFFERENT block concurrently via
    repro.parallel — blocks share zero gradients, so the pod axis carries no
    optimizer collectives; the shared periphery is reconciled by --periphery
    and per-block checkpoints (repro.checkpoint) are the merge points. With
    fewer devices than blocks the engine degrades to the round-robin scan.

  * --supervise (implied by --resume / --faults): the TrainRunner
    fault-tolerant loop — generational crash-consistent checkpoints in
    --ckpt-dir, per-block anomaly guards with rewind, heartbeats, pod-death
    degradation/re-adoption, bounded restart, and seeded fault injection
    (docs/training.md).

Runs on real local devices (CPU dev: 1 device; tests use
--xla_force_host_platform_device_count to exercise sharding).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs import DBConfig, get_config, reduced
from repro.configs.base import TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import make_db_train_step, make_e2e_train_step
from repro.checkpoint import save_block
from repro.data import MarkovLM, HostDataLoader
from repro.launch.mesh import make_host_mesh
from repro.sharding import param_shardings, tokens_sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (CPU-feasible); full config needs TPU")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mode", default="db", choices=["db", "e2e"])
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--block-parallel", action="store_true")
    ap.add_argument("--precision", default="fp32", choices=["fp32", "bf16"],
                    help="mixed-precision policy (repro.precision): fp32 "
                         "masters + bf16 compute + fp32 reductions, or pure "
                         "fp32")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "naive", "chunked", "triangle",
                             "kernels"],
                    help="attention/elementwise implementation; 'kernels' "
                         "routes fwd+bwd through the custom-VJP Pallas "
                         "kernels")
    ap.add_argument("--periphery", default="replicate+psum-mean",
                    help="periphery sync policy for --block-parallel "
                         "(replicate+psum-mean | owner-broadcast | "
                         "freeze-after-warmup)")
    ap.add_argument("--periphery-lr-scale", default=None,
                    help="--block-parallel: compensate the periphery's "
                         "1-update-per-batch cadence ('auto' = scale by the "
                         "block count, or a float; default off)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    # -- fault-tolerant supervisor (repro.launch.trainrunner) --------------
    ap.add_argument("--supervise", action="store_true",
                    help="run under the TrainRunner supervisor: generational "
                         "crash-consistent checkpoints in --ckpt-dir, "
                         "per-block anomaly guards with rewind, heartbeats, "
                         "bounded restart (implied by --resume / --faults)")
    ap.add_argument("--ckpt-every", type=int, default=20,
                    help="supervisor checkpoint cadence (batches in "
                         "--block-parallel, steps in --mode db)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="checkpoint generations to retain")
    ap.add_argument("--resume", action="store_true",
                    help="resume bit-identically from the latest good "
                         "generation in --ckpt-dir")
    ap.add_argument("--faults", default="",
                    help="JSON fault-injection spec, e.g. "
                         "'{\"pod_die\": {\"every\": 50}, "
                         "\"grad_nan\": {\"p\": 0.02}}' "
                         "(hooks: pod_die grad_nan data_stall ckpt_corrupt)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restart budget for simulated process death "
                         "(--mode db pod_die)")
    ap.add_argument("--pod-restart-after", type=int, default=2,
                    help="batches a dead pod stays down before its block is "
                         "re-adopted (--block-parallel pod_die)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(args.blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    tcfg = TrainConfig(steps=args.steps, batch_size=args.batch,
                       seq_len=args.seq, lr=args.lr, seed=args.seed)

    mesh = make_host_mesh(args.model_parallel)
    print(f"mesh: {dict(mesh.shape)} | arch={cfg.name} units={n_units} "
          f"blocks={db.num_blocks} mode={args.mode} "
          f"block_parallel={args.block_parallel}")

    rng = jax.random.PRNGKey(args.seed)
    rng, r0 = jax.random.split(rng)
    with mesh:
        params = dbm.init(r0)
    p_shard = param_shardings(dbm.model.axes(), mesh,
                              jax.eval_shape(lambda: params))
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)

    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=7)
    t_shard = tokens_sharding(mesh, args.batch)

    supervise = args.supervise or args.resume or bool(args.faults)
    if supervise:
        # fault-tolerant path: TrainRunner owns checkpoints, guards,
        # restarts, and the (cursor-able) data stream
        if args.mode == "e2e":
            raise SystemExit("--supervise covers --mode db and "
                             "--block-parallel only")
        if args.block_parallel and args.model_parallel > 1:
            raise SystemExit(
                "--block-parallel builds its own (pod, data) mesh and does "
                "not compose with --model-parallel yet; drop one of the two")
        import json

        from repro.data import MarkovStream
        from repro.launch.faults import make_injector
        from repro.launch.trainrunner import TrainRunner

        faults = make_injector(json.loads(args.faults) if args.faults
                               else None, seed=args.fault_seed)

        def make_data(cur):
            src = (lm.stream(args.batch, args.seq) if cur is None
                   else MarkovStream.from_cursor(cur))
            return HostDataLoader(src, sharding=t_shard)

        runner = TrainRunner(
            dbm, tcfg,
            mode="block-parallel" if args.block_parallel else "db",
            periphery=args.periphery, impl=args.impl,
            precision=args.precision,
            periphery_lr_scale=args.periphery_lr_scale,
            ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
            keep=args.ckpt_keep, faults=faults,
            max_restarts=args.max_restarts,
            pod_restart_after=args.pod_restart_after)
        params, _ = runner.train(make_data, rng, params=params,
                                 resume=args.resume)
        print("supervisor stats:", json.dumps(runner.stats()))
        print("done")
        return

    data = HostDataLoader(lm.iterator(args.batch, args.seq),
                          sharding=t_shard)

    if args.mode == "e2e":
        init_opt, step = make_e2e_train_step(dbm, tcfg, impl=args.impl,
                                             precision=args.precision,
                                             donate=True)
        opt = init_opt(params)
        for it in range(args.steps):
            rng, rs = jax.random.split(rng)
            t0 = time.time()
            params, opt, loss, m = step(params, opt, next(data), rs, None)
            if it % 10 == 0:
                print(f"[e2e] it={it} loss={float(loss):.4f} "
                      f"dt={time.time()-t0:.3f}s")
    elif args.block_parallel:
        # the real thing (repro.parallel): all blocks advance concurrently on
        # a pod-per-block mesh when the devices exist, round-robin otherwise
        if args.model_parallel > 1:
            raise SystemExit(
                "--block-parallel builds its own (pod, data) mesh and does "
                "not compose with --model-parallel yet; drop one of the two")
        from repro.parallel import BlockParallelTrainer
        trainer = BlockParallelTrainer(
            dbm, tcfg, periphery=args.periphery, impl=args.impl,
            precision=args.precision,
            periphery_lr_scale=args.periphery_lr_scale)
        print(f"block-parallel mode={trainer.mode}"
              + (f" mesh={dict(trainer.mesh.shape)}" if trainer.mesh else ""))
        params, _ = trainer.train(data, rng, params=params,
                                  ckpt_dir=args.ckpt_dir or None)
    else:
        steppers, opts = [], []
        for b in range(db.num_blocks):
            io, st = make_db_train_step(dbm, b, tcfg, impl=args.impl,
                                        precision=args.precision, donate=True)
            steppers.append(st)
            opts.append(io(params))
        for it in range(args.steps):
            rng, rb, rs = jax.random.split(rng, 3)
            b = int(jax.random.randint(rb, (), 0, db.num_blocks))
            t0 = time.time()
            params, opts[b], loss, m = steppers[b](params, opts[b],
                                                   next(data), rs, None)
            if it % 10 == 0:
                print(f"[db] it={it} block={b} loss={float(loss):.4f} "
                      f"dt={time.time()-t0:.3f}s")
        if args.ckpt_dir:
            for b, (start, size) in enumerate(dbm.ranges):
                p = save_block(args.ckpt_dir, params, b, start, size,
                               step=args.steps)
                print("saved", p)
    data.close()
    print("done")


if __name__ == "__main__":
    main()
