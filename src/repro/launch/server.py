"""Asyncio HTTP/SSE serving frontend over the continuous-batching engine.

``repro.launch.serve`` gave the engine throughput but no network surface —
``ContinuousBatcher.submit()`` is a Python call, so offered-load behavior
(arrival bursts, slow consumers, mid-stream aborts) was unobservable. This
module puts an asyncio server in front of the batcher:

  * ``POST /v1/generate`` — JSON request (prompt token ids, ``max_new``,
    optional ``aux`` conditioning reference) answered as a Server-Sent
    Events stream: one ``token`` event per decode segment, a final ``done``
    event with the full output, ``error`` events for rejected work. Set
    ``"stream": false`` for a single JSON response instead.
  * per-request ids (``x-request-id`` response header and in every event).
  * mid-stream cancellation: ``POST /v1/cancel/<rid>`` or simply closing
    the connection aborts the request — the batcher retires the slot
    between segments and its pages return to the pool immediately
    (prefix-cache refcounts respected).
  * slow-consumer backpressure: each request's tokens flow through a
    BOUNDED bridge queue; when a consumer falls ``queue_cap`` tokens
    behind, the batcher PAUSES that slot (it keeps its pages but leaves
    decode segments) until the consumer drains — one stalled client never
    forces the engine to buffer unboundedly or stall neighbors.
  * graceful drain: ``InferenceServer.drain()`` rejects new work with 503,
    completes everything in flight, then stops the engine thread.
  * SLO-aware scheduling: requests carry ``priority`` (batch / standard /
    interactive) and ``ttft_slo_ms`` / ``tpot_slo_ms`` deadlines; admission
    control sheds over-threshold load with 429 + ``Retry-After`` and the
    scheduler preempts (page spill/restore) low-priority work under pool
    pressure — see ``repro.launch.serve``.
  * supervised engine thread: an exception escaping ``step()`` spills every
    active slot and restarts the loop (bounded by ``max_restarts``); past
    the budget all in-flight streams finish with a terminal error instead
    of hanging. ``GET /v1/health`` exposes the full robustness picture.

Threading model: the batcher loop runs in ONE dedicated engine thread
(``EngineRunner``) — jitted dispatches never run on the event loop. The
asyncio side talks to it only through thread-safe calls (``submit`` /
``cancel`` / ``pause`` / ``resume``) and per-request ``TokenStream``
bridges (engine pushes under a lock, the loop is woken via
``call_soon_threadsafe``). No engine code moved into the event loop.

The HTTP layer is deliberately stdlib-only (``asyncio.start_server`` +
hand-rolled HTTP/1.1): the container must not grow dependencies, and the
endpoint surface is two routes. See ``docs/api.md`` for the wire format.
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import json
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.launch.serve import (AdmissionError, ContinuousBatcher,
                                PRIORITY_CLASSES, Request)

DEFAULT_QUEUE_CAP = 256      # tokens a consumer may fall behind before pause


# ---------------------------------------------------------------------------
# Engine thread <-> event loop bridge
# ---------------------------------------------------------------------------

class TokenStream:
    """Bounded bridge carrying ONE request's tokens from the engine thread
    to an event-loop consumer.

    The engine pushes each decode segment's tokens under a lock and wakes
    the loop via ``call_soon_threadsafe``. When the consumer falls ``cap``
    tokens behind, ``on_pause(rid)`` fires (the batcher stops decoding the
    slot); the next full drain fires ``on_resume(rid)``. ``finish`` marks
    the stream complete and carries the finished ``Request``.
    """

    def __init__(self, loop: asyncio.AbstractEventLoop, rid: int, cap: int,
                 on_pause=None, on_resume=None):
        self.loop, self.rid, self.cap = loop, rid, cap
        self.on_pause, self.on_resume = on_pause, on_resume
        self._buf: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._ready = asyncio.Event()
        self.req: Optional[Request] = None
        self.done = False
        self.paused = False
        self.pauses = 0              # times backpressure engaged (stats)

    # ---- engine-thread side ------------------------------------------
    def push(self, toks: List[int]):
        with self._lock:
            self._buf.extend(toks)
            engage = not self.paused and len(self._buf) >= self.cap
            if engage:
                self.paused = True
                self.pauses += 1
        if engage and self.on_pause is not None:
            self.on_pause(self.rid)
        self._wake()

    def finish(self, req: Request):
        with self._lock:
            self.req = req
            self.done = True
        self._wake()

    def _wake(self):
        try:
            self.loop.call_soon_threadsafe(self._ready.set)
        except RuntimeError:         # loop already closed (shutdown race)
            pass

    # ---- event-loop side ---------------------------------------------
    async def next_batch(self):
        """Wait for progress; returns ``(tokens, done)`` draining the whole
        buffer (resuming a paused slot once drained)."""
        while True:
            with self._lock:
                toks = list(self._buf)
                self._buf.clear()
                done = self.done
                resume = self.paused and bool(toks)
                if resume:
                    self.paused = False
                self._ready.clear()
            if resume and self.on_resume is not None:
                self.on_resume(self.rid)
            if toks or done:
                return toks, done
            await self._ready.wait()


class EngineRunner:
    """Owns the dedicated engine thread: a loop of ``batcher.step()`` calls
    that routes each request's tokens into its ``TokenStream`` and finishes
    streams as requests retire. Idles on an event when there is no work;
    ``stop()`` drains everything in flight before the thread exits.

    SUPERVISION: an exception escaping ``step()`` (a real bug, or an
    injected ``engine_crash``) no longer strands every in-flight stream.
    The loop catches it, spills every active slot back to the queue
    (``cb.recover()`` — partial output intact, no token duplication) and
    restarts stepping, up to ``max_restarts`` times. Past that the engine
    gives up: every queued/active request is errored and its stream
    finished (``cb.abort_all``), so clients get a terminal ``error`` event
    instead of a hung connection."""

    def __init__(self, batcher: ContinuousBatcher, rng=None,
                 max_restarts: int = 3, fatal_types: tuple = (),
                 name: str = "engine"):
        self.cb = batcher
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.max_restarts = max_restarts
        self.fatal_types = fatal_types   # exceptions = process death: no
        self._streams: Dict[int, TokenStream] = {}   # restart, no abort —
        self._orphans: Dict[int, List[List[int]]] = {}   # router fails over
        self._slock = threading.Lock()
        self._work = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._main,
                                        name=name, daemon=True)
        self.served = 0
        self.crashes = 0             # engine-thread exceptions caught
        self.restarts = 0            # successful supervisor recoveries
        self.last_error: Optional[str] = None
        self.gave_up = False         # crash budget exhausted; engine dead
        self.died = False            # fatal exception hit: worker is dead
        self.last_beat = time.time()  # heartbeat stamp (loop-top, each turn)
        batcher.token_cb = self._on_tokens

    def start(self):
        self._thread.start()

    def wake(self):
        self._work.set()

    def attach(self, rid: int, stream: TokenStream):
        """Register the stream for ``rid``. Tokens the engine emitted
        between ``submit`` and this call were stashed and are replayed here
        in order — nothing is lost to the registration race."""
        with self._slock:
            for toks in self._orphans.pop(rid, []):
                stream.push(toks)
            self._streams[rid] = stream
        self.wake()

    def cancel(self, rid: int) -> bool:
        ok = self.cb.cancel(rid)
        self.wake()
        return ok

    def stop(self, timeout: Optional[float] = None):
        """Drain then stop: the engine keeps stepping until queue and slots
        are empty, then the thread exits."""
        self._stop.set()
        self.wake()
        if self._thread.is_alive():
            self._thread.join(timeout)

    # ---- engine thread ------------------------------------------------
    def _on_tokens(self, req: Request, toks: List[int]):
        with self._slock:
            stream = self._streams.get(req.rid)
            if stream is None:
                self._orphans.setdefault(req.rid, []).append(list(toks))
                return
            stream.push(toks)

    def _finish(self, req: Request):
        with self._slock:
            stream = self._streams.pop(req.rid, None)
            self._orphans.pop(req.rid, None)
        self.served += 1
        if stream is not None:
            stream.finish(req)

    def _fail_inflight(self, msg: str):
        """Terminal failure: error + finish every request the engine will
        never serve, including streams attached for requests the batcher no
        longer knows (nothing may hang waiting on a dead engine)."""
        self.gave_up = True
        for req in self.cb.abort_all(msg):
            self._finish(req)
        with self._slock:
            leftover = list(self._streams.items())
            self._streams.clear()
            self._orphans.clear()
        for rid, stream in leftover:
            req = Request(rid, np.zeros(0, np.int32), 0)
            req.error = msg
            stream.finish(req)

    def _main(self):
        while True:
            self.last_beat = time.time()
            if not self.cb.has_work():
                if self._stop.is_set():
                    break
                self._work.wait(0.05)
                self._work.clear()
                continue
            d0 = self.cb.eng.dispatches
            try:
                self.rng, finished = self.cb.step(self.rng, strict=False)
            except Exception as e:      # noqa: BLE001 — supervisor boundary
                self.crashes += 1
                self.last_error = f"{type(e).__name__}: {e}"
                if isinstance(e, self.fatal_types):
                    # simulated process death: the thread exits without
                    # recovery OR failing streams — a dead process cannot
                    # apologize to its clients. The router's heartbeat check
                    # notices and fails the in-flight work over.
                    self.died = True
                    return
                if self.crashes > self.max_restarts:
                    self._fail_inflight(
                        f"engine failed after {self.crashes} crashes "
                        f"(last: {self.last_error})")
                    break
                self.cb.recover()       # spill + requeue every active slot
                self.restarts += 1
                continue
            for req in finished:
                self._finish(req)
            if not finished and self.cb.eng.dispatches == d0:
                # every active slot paused (backpressure) — wait for a
                # resume/cancel instead of spinning on no-op steps
                self._work.wait(0.005)
                self._work.clear()


# ---------------------------------------------------------------------------
# HTTP plumbing (stdlib asyncio streams; HTTP/1.1, connection: close)
# ---------------------------------------------------------------------------

async def _read_request(reader):
    """Parse one HTTP request: (method, path, headers, body) or None."""
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin-1").split()
    if len(parts) < 2:
        return None
    method, path = parts[0], parts[1]
    headers = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", 0) or 0)
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


def _response(status: str, obj, extra=()) -> bytes:
    body = json.dumps(obj).encode()
    head = [f"HTTP/1.1 {status}", "content-type: application/json",
            f"content-length: {len(body)}", "connection: close"]
    head += [f"{k}: {v}" for k, v in extra]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def _sse_head(rid: int) -> bytes:
    return (f"HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\n"
            f"cache-control: no-cache\r\nconnection: close\r\n"
            f"x-request-id: {rid}\r\n\r\n").encode()


def _sse_event(event: str, obj) -> bytes:
    return f"event: {event}\ndata: {json.dumps(obj)}\n\n".encode()


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------

class InferenceServer:
    """Asyncio HTTP/SSE frontend over one ``ContinuousBatcher``.

    ``aux_registry`` maps names to conditioning inputs (``{"image_embs":
    (Sk, d)}`` dicts); requests reference them as ``{"aux": "<name>"}`` —
    raw embedding tensors never travel over the wire. Sampler settings are
    engine-STATIC (they select the compiled program): a request may state
    ``temperature`` / ``top_k``, but values diverging from the server's
    engine are rejected with 400 rather than silently ignored.
    """

    def __init__(self, batcher: ContinuousBatcher, *, host: str = "127.0.0.1",
                 port: int = 0, queue_cap: int = DEFAULT_QUEUE_CAP,
                 aux_registry: Optional[dict] = None, rng=None,
                 max_restarts: int = 3):
        self.cb = batcher
        if getattr(batcher, "is_router", False):
            # disaggregated fleet: the router runs its own workers + tick
            # thread; RouterRunner is the stream-bookkeeping facade
            from repro.launch.router import RouterRunner
            self.runner = RouterRunner(batcher, rng=rng,
                                       max_restarts=max_restarts)
        else:
            self.runner = EngineRunner(batcher, rng=rng,
                                       max_restarts=max_restarts)
        self.host, self._want_port = host, port
        self.queue_cap = queue_cap
        self.aux_registry = dict(aux_registry or {})
        self.backpressure_pauses = 0     # slow-consumer pause events (total)
        self.draining = False
        self.port: Optional[int] = None
        self._srv = None
        self._loop = None

    # ---- lifecycle ----------------------------------------------------
    async def start(self) -> "InferenceServer":
        self._loop = asyncio.get_running_loop()
        self.runner.start()
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self._want_port)
        self.port = self._srv.sockets[0].getsockname()[1]
        return self

    async def drain(self):
        """Graceful shutdown: new ``/v1/generate`` requests get 503, every
        queued/active request runs to completion (their streams deliver all
        tokens), then the engine thread stops."""
        self.draining = True
        await self._loop.run_in_executor(None, self.runner.stop)

    async def aclose(self):
        await self.drain()
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()

    # ---- request handling ---------------------------------------------
    async def _handle(self, reader, writer):
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, path, headers, body = parsed
            if method == "GET" and path == "/v1/health":
                writer.write(_response("200 OK", self.stats()))
                await writer.drain()
            elif method == "POST" and path.startswith("/v1/cancel/"):
                try:
                    rid = int(path.rsplit("/", 1)[1])
                except ValueError:
                    writer.write(_response("400 Bad Request",
                                           {"error": "bad request id"}))
                else:
                    ok = self.runner.cancel(rid)
                    writer.write(_response(
                        "200 OK", {"request_id": rid, "cancelled": ok}))
                await writer.drain()
            elif method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            else:
                writer.write(_response("404 Not Found",
                                       {"error": f"no route {path}"}))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    def stats(self) -> dict:
        """``GET /v1/health`` payload: everything an external load balancer
        needs for shed/route decisions — live queue depth, slot and page
        headroom, drain state — plus the robustness counters (preemptions,
        SLO cancels, sheds, supervisor crash/restart tallies).

        Disaggregated servers report the router surface instead: mode,
        migration/failover/handoff-retry counters, and a per-worker list
        (role, alive, heartbeat age, pool headroom, inflight)."""
        cb = self.cb
        if getattr(cb, "is_router", False):
            out = cb.stats()
            out.update({
                "served": self.runner.served,
                "shed": cb.shed_count,
                "max_queue": cb.max_queue,
                "backpressure_pauses": self.backpressure_pauses,
                "draining": self.draining,
                "engine_alive": any(w["alive"] for w in out["workers"]),
            })
            return out
        active = int(cb.active.sum())
        return {
            "active_slots": active,
            "free_slots": cb.num_slots - active,
            "num_slots": cb.num_slots,
            "queued": len(cb.queue),
            "free_pages": len(cb.free_pages),
            "total_pages": cb.total_pages,
            # pool BYTES, mixed-dtype aware (int8 pages + fp32 scales)
            **cb.kv_stats(),
            "served": self.runner.served,
            "cancelled": cb.cancelled_count,
            "backpressure_pauses": self.backpressure_pauses,
            "draining": self.draining,
            "max_queue": cb.max_queue,
            "shed": cb.shed_count,
            "preemptions": cb.preemptions,
            "restores": cb.restores,
            "deadline_cancels": cb.deadline_cancels,
            "engine_crashes": self.runner.crashes,
            "engine_restarts": self.runner.restarts,
            "engine_alive": (self.runner._thread.is_alive()
                             and not self.runner.gave_up),
        }

    def _on_pause(self, rid: int):
        self.backpressure_pauses += 1
        self.cb.pause(rid)

    def _validate(self, payload) -> Optional[str]:
        if not isinstance(payload, dict):
            return "body must be a JSON object"
        prompt = payload.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            return "prompt must be a non-empty list of token ids"
        vocab = self.cb.dbm.cfg.vocab_size
        if not all(0 <= t < vocab for t in prompt):
            return f"prompt token ids must be in [0, {vocab})"
        if len(prompt) > self.cb.max_prompt:
            return (f"prompt length {len(prompt)} exceeds max_prompt "
                    f"{self.cb.max_prompt}")
        max_new = payload.get("max_new", 16)
        if not isinstance(max_new, int) or max_new < 1:
            return "max_new must be a positive integer"
        if len(prompt) + max_new > self.cb.max_len:
            return (f"prompt + max_new = {len(prompt) + max_new} exceeds "
                    f"max_len {self.cb.max_len}")
        eng = self.cb.eng
        for k, have in (("temperature", eng.temperature),
                        ("top_k", eng.top_k)):
            want = payload.get(k)
            if want is not None and float(want) != float(have):
                return (f"{k}={want} does not match this server's engine "
                        f"({k}={have}); sampler settings are static per "
                        "compiled engine — restart the server to change "
                        "them")
        aux = payload.get("aux")
        if aux is not None and aux not in self.aux_registry:
            known = sorted(self.aux_registry)
            return f"unknown aux reference {aux!r} (registered: {known})"
        prio = payload.get("priority")
        if prio is not None and not (
                isinstance(prio, int) and not isinstance(prio, bool)
                or prio in PRIORITY_CLASSES):
            return (f"priority must be an int or one of "
                    f"{sorted(PRIORITY_CLASSES)}, got {prio!r}")
        for k in ("ttft_slo_ms", "tpot_slo_ms"):
            v = payload.get(k)
            if v is not None and not (isinstance(v, (int, float))
                                      and not isinstance(v, bool) and v > 0):
                return f"{k} must be a positive number, got {v!r}"
        return None

    async def _generate(self, reader, writer, body):
        retry = f"{self.cb.retry_after_hint():.1f}"
        if self.draining or self.runner.gave_up:
            why = "server draining" if self.draining else "engine failed"
            writer.write(_response("503 Service Unavailable",
                                   {"error": why,
                                    "retry_after_s": float(retry)},
                                   extra=[("retry-after", retry)]))
            await writer.drain()
            return
        try:
            payload = json.loads(body or b"null")
        except json.JSONDecodeError:
            payload = None
        err = self._validate(payload)
        if err is not None:
            writer.write(_response("400 Bad Request", {"error": err}))
            await writer.drain()
            return
        max_new = payload.get("max_new", 16)
        aux = (self.aux_registry[payload["aux"]]
               if payload.get("aux") is not None else None)
        ttft = payload.get("ttft_slo_ms")
        tpot = payload.get("tpot_slo_ms")
        try:
            rid = self.cb.submit(np.asarray(payload["prompt"], np.int32),
                                 max_new, aux_inputs=aux,
                                 priority=payload.get("priority", "standard"),
                                 ttft_slo_s=(ttft / 1e3
                                             if ttft is not None else None),
                                 tpot_slo_s=(tpot / 1e3
                                             if tpot is not None else None))
        except AdmissionError as e:
            retry = f"{e.retry_after:.1f}"
            writer.write(_response("429 Too Many Requests",
                                   {"error": str(e),
                                    "retry_after_s": float(retry)},
                                   extra=[("retry-after", retry)]))
            await writer.drain()
            return
        except (ValueError, AssertionError) as e:
            writer.write(_response("400 Bad Request", {"error": str(e)}))
            await writer.drain()
            return
        stream = TokenStream(
            self._loop, rid, self.queue_cap, on_pause=self._on_pause,
            on_resume=lambda r: (self.cb.resume(r), self.runner.wake()))
        self.runner.attach(rid, stream)
        if payload.get("stream", True):
            await self._stream_sse(reader, writer, rid, stream)
        else:
            await self._respond_once(writer, rid, stream)

    @staticmethod
    def _final_payload(rid: int, req: Request) -> dict:
        out = {"request_id": rid, "ids": list(req.out), "n": len(req.out),
               "cancelled": bool(req.cancelled)}
        if req.ttft is not None:
            out["ttft_ms"] = round(req.ttft * 1e3, 3)
        out["preempted"] = req.preempt_count
        if req.deadline_blown:
            out["deadline_blown"] = True
        return out

    async def _respond_once(self, writer, rid: int, stream: TokenStream):
        done = False
        while not done:
            _, done = await stream.next_batch()
        req = stream.req
        if req.error:
            # deadline-blown / failed requests still deliver their partial
            # output alongside the error
            payload = dict(self._final_payload(rid, req), error=req.error)
            writer.write(_response("503 Service Unavailable", payload))
        else:
            writer.write(_response("200 OK", self._final_payload(rid, req)))
        await writer.drain()

    async def _stream_sse(self, reader, writer, rid: int,
                          stream: TokenStream):
        writer.write(_sse_head(rid))
        await writer.drain()
        # reads nothing in normal operation: completes only when the client
        # closes or resets the connection mid-stream -> cancel the request
        monitor = asyncio.ensure_future(reader.read())
        offset, done, disconnected = 0, False, False
        try:
            while not done:
                getter = asyncio.ensure_future(stream.next_batch())
                await asyncio.wait({getter, monitor},
                                   return_when=asyncio.FIRST_COMPLETED)
                if monitor.done() and not disconnected:
                    disconnected = True
                    self.runner.cancel(rid)
                if not getter.done():
                    # woken by the monitor alone: keep the pending getter
                    # result by awaiting it (the engine will finish the
                    # stream once the cancel lands)
                    toks, done = await getter
                else:
                    toks, done = getter.result()
                if toks and not disconnected:
                    try:
                        writer.write(_sse_event("token", {
                            "request_id": rid, "ids": toks,
                            "offset": offset}))
                        await writer.drain()
                    except (ConnectionResetError, BrokenPipeError):
                        disconnected = True
                        self.runner.cancel(rid)
                offset += len(toks)
            req = stream.req
            if not disconnected:
                if req.error:
                    writer.write(_sse_event("error", dict(
                        self._final_payload(rid, req), error=req.error)))
                else:
                    writer.write(_sse_event("done",
                                            self._final_payload(rid, req)))
                await writer.drain()
        finally:
            monitor.cancel()


# ---------------------------------------------------------------------------
# Minimal async client (tests, examples/serve_client.py, the load harness)
# ---------------------------------------------------------------------------

async def _read_status_headers(reader):
    status = (await reader.readline()).decode("latin-1").split()
    code = int(status[1]) if len(status) > 1 else 0
    headers = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    return code, headers


async def request_json(host: str, port: int, method: str, path: str,
                       payload=None, *, return_headers: bool = False):
    """One JSON request/response roundtrip -> (status_code, object), plus
    the response-header dict when ``return_headers`` is set (Retry-After
    inspection)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = b"" if payload is None else json.dumps(payload).encode()
        writer.write((f"{method} {path} HTTP/1.1\r\nhost: {host}\r\n"
                      f"content-type: application/json\r\n"
                      f"content-length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        code, headers = await _read_status_headers(reader)
        n = int(headers.get("content-length", 0) or 0)
        raw = await reader.readexactly(n) if n else await reader.read()
        obj = json.loads(raw) if raw else None
        return (code, obj, headers) if return_headers else (code, obj)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except Exception:
            pass


async def sse_events(reader):
    """Async generator over SSE ``(event, data)`` pairs until EOF."""
    event, data = None, []
    while True:
        line = await reader.readline()
        if not line:
            return
        line = line.decode().rstrip("\n").rstrip("\r")
        if line.startswith("event:"):
            event = line[6:].strip()
        elif line.startswith("data:"):
            data.append(line[5:].strip())
        elif not line and event is not None:
            yield event, json.loads("\n".join(data) or "null")
            event, data = None, []


async def stream_generate(host: str, port: int, prompt, max_new: int, *,
                          aux: Optional[str] = None,
                          cancel_after: Optional[int] = None,
                          slow_consumer_s: float = 0.0,
                          priority=None, ttft_slo_ms=None, tpot_slo_ms=None,
                          abort_after: Optional[int] = None) -> dict:
    """Stream one request; returns reassembled output + timing.

    ``cancel_after=N`` issues ``POST /v1/cancel/<rid>`` once >= N tokens
    have arrived (exercises mid-stream cancellation); ``abort_after=N``
    instead closes the connection abruptly with NO cancel RPC — the
    server's disconnect monitor must notice (disconnect-storm chaos).
    ``slow_consumer_s`` sleeps between event reads (exercises
    backpressure). ``priority`` / ``ttft_slo_ms`` / ``tpot_slo_ms`` pass
    through to the scheduler. Returns a dict: ids, request_id, events
    (count), token_times (monotonic stamps per token event), final (the
    done/error payload), status, retry_after (seconds, on 429/503).
    """
    t0 = time.monotonic()
    payload = {"prompt": [int(t) for t in prompt], "max_new": int(max_new),
               "stream": True}
    if aux is not None:
        payload["aux"] = aux
    if priority is not None:
        payload["priority"] = priority
    if ttft_slo_ms is not None:
        payload["ttft_slo_ms"] = ttft_slo_ms
    if tpot_slo_ms is not None:
        payload["tpot_slo_ms"] = tpot_slo_ms
    body = json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    result = {"ids": [], "request_id": None, "events": 0, "final": None,
              "token_times": [], "token_counts": [], "status": None,
              "submit_t": t0, "retry_after": None, "aborted": False}
    try:
        writer.write((f"POST /v1/generate HTTP/1.1\r\nhost: {host}\r\n"
                      f"content-type: application/json\r\n"
                      f"content-length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        code, headers = await _read_status_headers(reader)
        result["status"] = code
        if code != 200:
            if "retry-after" in headers:
                result["retry_after"] = float(headers["retry-after"])
            n = int(headers.get("content-length", 0) or 0)
            raw = await reader.readexactly(n) if n else b""
            result["final"] = json.loads(raw) if raw else None
            return result
        result["request_id"] = int(headers.get("x-request-id", -1))
        cancelled_sent = False
        async for event, data in sse_events(reader):
            result["events"] += 1
            if event == "token":
                assert data["offset"] == len(result["ids"]), \
                    "SSE token events arrived out of order"
                result["ids"].extend(data["ids"])
                result["token_times"].append(time.monotonic())
                result["token_counts"].append(len(data["ids"]))
                if (abort_after is not None
                        and len(result["ids"]) >= abort_after):
                    result["aborted"] = True   # hard disconnect, no RPC
                    return result
                if (cancel_after is not None and not cancelled_sent
                        and len(result["ids"]) >= cancel_after):
                    cancelled_sent = True
                    await request_json(host, port, "POST",
                                       f"/v1/cancel/{result['request_id']}")
                if slow_consumer_s:
                    await asyncio.sleep(slow_consumer_s)
            elif event in ("done", "error"):
                result["final"] = data
                break
        return result
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def build_batcher_from_args(args):
    """Construct (dbm, params, batcher, aux_registry) from serve-style CLI
    args — shared by this CLI and ``examples/serve_client.py``."""
    from repro.configs import DBConfig, get_config, reduced
    from repro.core import DiffusionBlocksModel

    cfg = reduced(get_config(args.arch))
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    db = DBConfig(num_blocks=min(args.blocks, n_units), overlap_gamma=0.1)
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    aux_registry = {}
    if args.conditioned:
        specs = dbm.model.aux_input_specs(1)
        if not specs:
            raise SystemExit(f"--conditioned: family {cfg.family!r} takes "
                             "no aux inputs (pick a vlm/audio arch)")
        aux_key = next(iter(specs))
        rs = np.random.RandomState(1)
        Sk = dbm.model.max_cond_tokens
        for i in range(args.cond_pool):
            aux_registry[f"cond{i}"] = {
                aux_key: rs.randn(Sk, cfg.d_model).astype(np.float32)}
    cb_kw = dict(
        num_slots=args.num_slots, page_size=args.page_size,
        max_prompt=args.prompt_len, max_len=args.prompt_len + args.max_new,
        seg_len=args.seg_len, temperature=args.temperature,
        top_k=args.top_k, precision=args.precision,
        kv_dtype=getattr(args, "kv_dtype", None), impl=args.impl,
        prefill=args.prefill,
        chunk_size=min(args.chunk_size, max(args.prompt_len, 1)),
        prefix_cache=args.prefix_cache)
    if getattr(args, "disagg", False):
        from repro.launch.router import DisaggRouter
        cb = DisaggRouter(
            dbm, params, n_prefill=args.prefill_workers,
            n_decode=args.decode_workers, handoff=args.handoff,
            restart_dead_after_s=getattr(args, "restart_dead_after", None),
            max_queue=getattr(args, "max_queue", None),
            shed_below_pages=getattr(args, "shed_below_pages", 0), **cb_kw)
    else:
        cb = ContinuousBatcher(
            dbm, params, max_queue=getattr(args, "max_queue", None),
            shed_below_pages=getattr(args, "shed_below_pages", 0), **cb_kw)
    return dbm, params, cb, aux_registry


def add_server_args(ap: argparse.ArgumentParser):
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--seg-len", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=64)
    ap.add_argument("--prefill", choices=("chunked", "per-token"),
                    default="chunked")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--precision", default="bf16")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("int8", "bf16", "fp32", "auto"),
                    help="KV pool storage dtype; 'int8' quantizes pages "
                         "per-page (symmetric absmax, one fp32 scale per "
                         "page) for ~2x pool capacity (default: the "
                         "precision policy's native KV dtype)")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--conditioned", action="store_true",
                    help="register a pool of named conditioning inputs "
                         "(vlm/audio archs); requests reference them via "
                         '{"aux": "cond<i>"}')
    ap.add_argument("--cond-pool", type=int, default=3)
    ap.add_argument("--queue-cap", type=int, default=DEFAULT_QUEUE_CAP,
                    help="tokens a slow consumer may fall behind before "
                         "its slot is paused (backpressure)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: shed (429 + Retry-After) when "
                         "the backlog at >= the request's priority reaches "
                         "this depth (default: unbounded)")
    ap.add_argument("--shed-below-pages", type=int, default=0,
                    help="admission control: shed batch-class requests "
                         "while free pages are below this threshold")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: prefill and decode on "
                         "separate supervised workers behind a migrating "
                         "router (see repro.launch.router)")
    ap.add_argument("--prefill-workers", type=int, default=1)
    ap.add_argument("--decode-workers", type=int, default=1)
    ap.add_argument("--handoff", choices=("copy", "pages"), default="copy",
                    help="migration payload: 'copy' snapshots KV to host "
                         "and restores into the decode pool; 'pages' moves "
                         "page-table handles on one shared pool")
    ap.add_argument("--restart-dead-after", type=float, default=None,
                    help="seconds before a dead worker is restarted "
                         "(default: never — survivors absorb the load)")


async def _serve_forever(args):
    _, _, cb, aux_registry = build_batcher_from_args(args)
    server = InferenceServer(cb, host=args.host, port=args.port,
                             queue_cap=args.queue_cap,
                             aux_registry=aux_registry)
    await server.start()
    if getattr(cb, "is_router", False):
        shape = (f"disagg {len(cb.prefill_workers)}p+"
                 f"{len(cb.decode_workers)}d, handoff={cb.handoff}")
    else:
        shape = f"slots={cb.num_slots}, pool={cb.total_pages} pages"
    print(f"serving on http://{server.host}:{server.port}  "
          f"({shape}; POST /v1/generate, GET /v1/health)")
    try:
        while True:
            await asyncio.sleep(3600)
    except asyncio.CancelledError:
        pass
    finally:
        print("draining ...")
        await server.aclose()
        print("drained; bye")


def main():
    ap = argparse.ArgumentParser(
        description="asyncio HTTP/SSE frontend over the continuous batcher")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    add_server_args(ap)
    args = ap.parse_args()
    try:
        asyncio.run(_serve_forever(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
