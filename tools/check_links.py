"""Dead-link checker for the docs tree (stdlib-only; runs in the lint job).

Validates every relative markdown link in ``docs/*.md`` and ``README.md``:
the target file must exist, and a ``#fragment`` must match a heading's
GitHub-style anchor in the target. Skipped on purpose: absolute URLs
(``http``/``https``/``mailto``) and links that escape the repository root
(the CI badge's ``../../actions/...`` resolves on github.com, not in the
checkout).

    python tools/check_links.py            # exit 1 on any dead link
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug: strip markup, lowercase, drop
    punctuation, spaces to hyphens."""
    text = re.sub(r"[*_`]|\[|\]|\([^)]*\)", "", heading).strip()
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    return {github_anchor(h) for h in HEADING_RE.findall(body)}


def check_file(md_path: str) -> list:
    errors = []
    with open(md_path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    for target in LINK_RE.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        dest = (os.path.normpath(os.path.join(os.path.dirname(md_path),
                                              path))
                if path else md_path)
        if not (dest + os.sep).startswith(ROOT + os.sep):
            continue                     # escapes the repo (e.g. CI badge)
        rel = os.path.relpath(md_path, ROOT)
        if not os.path.exists(dest):
            errors.append(f"{rel}: dead link -> {target}")
            continue
        if frag and dest.endswith(".md"):
            if github_anchor(frag) not in anchors_of(dest):
                errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def main() -> int:
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    errors = []
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(e)
    print(f"checked {len(files)} files: "
          + ("FAILED" if errors else "all links ok"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
