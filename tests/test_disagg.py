"""Disaggregated prefill/decode serving: migration and failover bit-parity,
the handoff fault seams (drop -> re-prefill, stall -> timeout -> bounded
retry), role wipe-out degradation + automatic re-split, the router's
``/v1/health`` worker surface, and cross-pool ``SpilledSlot`` wire
round-trips for every cache-state family.

Bit-parity discipline (same as tests/test_server.py): greedy decode draws
per-step noise from the engine rng, so parity populations run ONE request
at a time with the decode worker's rng pinned to the unified baseline's
PRNGKey. Prefill consumes no rng and boundary-spilled slots never enter a
decode segment on the prefill side, so migration — and a failover that
adopts the dead decode worker's rng — must reproduce the uninterrupted
token sequence exactly. Chaos-style concurrent coverage lives in
``benchmarks/table20_disagg.py``; these are the deterministic seams.
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, SSMConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector
from repro.launch.router import DisaggRouter
from repro.launch.serve import ContinuousBatcher
from repro.launch.server import (InferenceServer, request_json,
                                 stream_generate)
from repro.nn.cache import SpilledSlot

TINY = ModelConfig(name="tiny-disagg", family="dense", n_layers=4,
                   d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                   vocab_size=32)
TINY_VLM = ModelConfig(name="tiny-disagg-vlm", family="vlm", n_layers=4,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab_size=32, cross_attn_every=2, n_image_tokens=4)

CB_KW = dict(num_slots=2, max_prompt=12, max_len=24, seg_len=3, page_size=4,
             chunk_size=4, precision="fp32")


@pytest.fixture(scope="module")
def dense_env():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=2,
                                              overlap_gamma=0.1))
    return dbm, dbm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def vlm_env():
    dbm = DiffusionBlocksModel(TINY_VLM, DBConfig(num_blocks=2,
                                                  overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    # open the zero-init cross gate so conditioning moves the greedy argmax
    params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
        params["units"]["cross"]["xgate"])
    return dbm, params


def pool_whole(router):
    """No leaked page anywhere: every non-trash page is free or mapped."""
    if router.pool is not None:
        free, refs, tot = (len(router.pool.free_pages),
                           len(router.pool.page_refs),
                           router.pool.total_pages)
        assert free + refs == tot - 1, ("shared pool leak", free, refs, tot)
    else:
        for w in router.workers:
            free, refs, tot = (len(w.cb.free_pages), len(w.cb.page_refs),
                               w.cb.total_pages)
            assert free + refs == tot - 1, (w.name, free, refs, tot)
    assert not router._handoffs, "payload stranded in the handoff queue"


def unified_seq(dbm, params, reqs, seed, **kw):
    """Ground truth: each request alone on one unified batcher, one rng
    stream carried across the whole sequence. NOTE: decode noise is drawn
    per-step with shape ``(num_slots, 1, d)``, so every batcher in a
    parity population must use the same ``num_slots``."""
    cb = ContinuousBatcher(dbm, params, **{**CB_KW, **kw})
    rng = jax.random.PRNGKey(seed)
    outs = []
    for prompt, max_new, aux in reqs:
        cb.submit(prompt, max_new, aux_inputs=aux)
        fin = []
        while cb.has_work():
            rng, f = cb.step(rng, strict=False)
            fin.extend(f)
        assert len(fin) == 1 and fin[0].error is None, fin
        outs.append(list(fin[0].out))
    return outs


def router_seq(dbm, params, reqs, *, handoff, seed, die_at=None,
               timeout_s=120.0, **router_kw):
    """The same requests, one at a time, through a disaggregated router;
    decode0's rng pinned to the baseline seed. ``die_at`` kills decode0 on
    its ``die_at``-th engine step (requires n_decode=2 for a survivor)."""
    router = DisaggRouter(dbm, params, n_prefill=1,
                          n_decode=2 if die_at is not None else 1,
                          handoff=handoff, **{**CB_KW, **router_kw})
    done = {}
    router.finish_cb = lambda r: done.setdefault(r.rid, r)
    router.decode_workers[0].runner.rng = jax.random.PRNGKey(seed)
    if die_at is not None:
        router.decode_workers[0].cb.faults = FaultInjector(
            {"worker_die": {"at": [die_at]}}, seed=0)
    router.start()
    outs = []
    try:
        for prompt, max_new, aux in reqs:
            rid = router.submit(prompt, max_new, aux_inputs=aux)
            t0 = time.time()
            while rid not in done and time.time() - t0 < timeout_s:
                time.sleep(0.005)
            assert rid in done, ("router request never finished", rid)
            r = done[rid]
            assert r.error is None, r.error
            outs.append(list(r.out))
    finally:
        router.stop(30)
    pool_whole(router)
    return outs, router.stats()


def mk_reqs(vocab, aux=None, seed=7):
    rs = np.random.RandomState(seed)
    return [(rs.randint(0, vocab, size=n).astype(np.int32), 8, aux)
            for n in (9, 6)]


# ---------------------------------------------------------------------------
# Migration / failover bit-parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("handoff", ["copy", "pages"])
def test_migration_parity_unconditioned(dense_env, handoff):
    """A request migrated prefill->decode (byte-copy or page handles) emits
    exactly the tokens of an uninterrupted unified run."""
    dbm, params = dense_env
    reqs = mk_reqs(TINY.vocab_size)
    base = unified_seq(dbm, params, reqs, seed=11)
    got, stats = router_seq(dbm, params, reqs, handoff=handoff, seed=11)
    assert got == base, (handoff, got, base)
    assert stats["migrations"] >= len(reqs), stats
    assert stats["failovers"] == 0 and stats["re_prefills"] == 0, stats


def test_migration_parity_conditioned(vlm_env):
    """Same gate for a CONDITIONED request: the payload must carry the
    per-slot cross block or the migrated decode silently drops the image."""
    dbm, params = vlm_env
    aux = {"image_embs": 4.0 * np.random.RandomState(3)
           .randn(TINY_VLM.n_image_tokens, TINY_VLM.d_model)
           .astype(np.float32)}
    reqs = mk_reqs(TINY_VLM.vocab_size, aux=aux)
    base = unified_seq(dbm, params, reqs, seed=11)
    uncond = unified_seq(dbm, params,
                         [(p, n, None) for p, n, _ in reqs], seed=11)
    assert base != uncond, "conditioning must change the output"
    for handoff in ("copy", "pages"):
        got, stats = router_seq(dbm, params, reqs, handoff=handoff, seed=11)
        assert got == base, (handoff, got, base)
        assert stats["migrations"] >= len(reqs), stats


@pytest.mark.parametrize("handoff", ["copy", "pages"])
def test_failover_parity_mid_decode(dense_env, handoff):
    """decode0 dies on its 2nd engine step (one segment delivered); the
    survivor adopts the dead worker's rng and the re-migrated (pages) or
    re-prefilled (copy) request finishes bit-identically."""
    dbm, params = dense_env
    reqs = mk_reqs(TINY.vocab_size)
    base = unified_seq(dbm, params, reqs, seed=11)
    got, stats = router_seq(dbm, params, reqs, handoff=handoff, seed=11,
                            die_at=2)
    assert got == base, (handoff, got, base)
    assert stats["failovers"] >= 1, stats


# ---------------------------------------------------------------------------
# Handoff fault seams
# ---------------------------------------------------------------------------

def test_handoff_drop_falls_back_to_reprefill(dense_env):
    """A payload lost in transit re-prefills from the original prompt —
    rng-neutral (no decode step had run), so parity still holds."""
    dbm, params = dense_env
    reqs = mk_reqs(TINY.vocab_size)
    base = unified_seq(dbm, params, reqs, seed=11)
    got, stats = router_seq(
        dbm, params, reqs, handoff="copy", seed=11,
        faults=FaultInjector({"handoff_drop": {"at": [1]}}, seed=0))
    assert got == base, (got, base)
    assert stats["handoff_drops"] >= 1, stats
    assert stats["re_prefills"] >= 1, stats


def test_handoff_stall_times_out_then_retries(dense_env):
    """A stalled send exceeds the handoff timeout; the bounded-backoff
    retry delivers the SAME payload on the next attempt (no re-prefill
    needed) and output parity holds."""
    dbm, params = dense_env
    reqs = mk_reqs(TINY.vocab_size)
    base = unified_seq(dbm, params, reqs, seed=11)
    got, stats = router_seq(
        dbm, params, reqs, handoff="copy", seed=11,
        handoff_timeout_s=0.05, handoff_backoff_s=0.01,
        faults=FaultInjector({"handoff_stall": {"at": [1], "sleep": 0.2}},
                             seed=0))
    assert got == base, (got, base)
    assert stats["handoff_retries"] >= 1, stats
    assert stats["re_prefills"] == 0, stats


def test_decode_wipeout_degrades_then_resplits(dense_env):
    """Killing the ONLY decode worker degrades the router to unified mode
    (the prefill worker decodes everything itself); once the dead worker
    restarts the router re-splits and later requests migrate again."""
    dbm, params = dense_env
    router = DisaggRouter(dbm, params, n_prefill=1, n_decode=1,
                          handoff="copy", restart_dead_after_s=0.3,
                          **CB_KW)
    done = {}
    router.finish_cb = lambda r: done.setdefault(r.rid, r)
    router.decode_workers[0].cb.faults = FaultInjector(
        {"worker_die": {"at": [1]}}, seed=0)
    router.start()
    try:
        prompt = np.arange(1, 9, dtype=np.int32) % TINY.vocab_size
        rid = router.submit(prompt, 8)
        t0 = time.time()
        while rid not in done and time.time() - t0 < 120:
            time.sleep(0.005)
        assert rid in done and done[rid].error is None
        assert len(done[rid].out) == 8
        assert router.degradations >= 1, router.stats()
        # wait out the restart timer; the router re-splits automatically
        t0 = time.time()
        while router.mode != "split" and time.time() - t0 < 30:
            time.sleep(0.01)
        assert router.mode == "split" and router.resplits >= 1
        m0 = router.migrations
        rid = router.submit(prompt, 6)
        t0 = time.time()
        while rid not in done and time.time() - t0 < 120:
            time.sleep(0.005)
        assert rid in done and done[rid].error is None
        assert len(done[rid].out) == 6
        assert router.migrations > m0, "re-split router must migrate again"
    finally:
        router.stop(30)
    pool_whole(router)


# ---------------------------------------------------------------------------
# /v1/health router surface (HTTP frontend over a DisaggRouter)
# ---------------------------------------------------------------------------

def test_router_health_endpoint(dense_env):
    """The HTTP frontend drives a router transparently and ``/v1/health``
    reports per-worker status plus the migration/failover counters."""
    dbm, params = dense_env
    prompt = (np.arange(2, 9) * 3) % TINY.vocab_size

    async def main():
        router = DisaggRouter(dbm, params, n_prefill=1, n_decode=1,
                              handoff="copy", **CB_KW)
        server = InferenceServer(router, rng=jax.random.PRNGKey(7))
        await server.start()
        try:
            r = await stream_generate("127.0.0.1", server.port, prompt, 6)
            assert r["status"] == 200 and len(r["ids"]) == 6
            code, health = await request_json("127.0.0.1", server.port,
                                              "GET", "/v1/health")
            return code, health
        finally:
            await server.aclose()

    code, health = asyncio.run(main())
    assert code == 200
    assert health["router"] is True and health["mode"] == "split"
    assert health["served"] == 1 and health["engine_alive"] is True
    for key in ("migrations", "failovers", "handoff_retries",
                "handoff_drops", "re_prefills", "degradations", "resplits"):
        assert isinstance(health[key], int), key
    assert health["migrations"] >= 1
    workers = {w["name"]: w for w in health["workers"]}
    assert set(workers) == {"prefill0", "decode0"}
    assert workers["prefill0"]["role"] == "prefill"
    assert workers["decode0"]["role"] == "decode"
    for w in workers.values():
        assert w["alive"] is True
        assert w["heartbeat_age_s"] >= 0.0
        assert w["free_pages"] <= w["total_pages"]
    assert workers["prefill0"]["migrated_out"] >= 1


# ---------------------------------------------------------------------------
# SpilledSlot wire round-trip across pools, all cache-state families
# ---------------------------------------------------------------------------

TINY_HYBRID = ModelConfig(name="tiny-disagg-hybrid", family="hybrid",
                          n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=32, attn_every=2,
                          ssm=SSMConfig(d_state=8, d_conv=4, expand=2,
                                        head_dim=16, chunk_size=8))
TINY_AUDIO = ModelConfig(name="tiny-disagg-audio", family="audio",
                         n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                         d_ff=64, vocab_size=32, n_encoder_layers=2,
                         n_audio_frames=6, rope_theta=0.0, norm="layernorm",
                         mlp="gelu", is_encoder_decoder=True)

FAMILY_CFGS = {"dense": TINY, "hybrid": TINY_HYBRID, "vlm": TINY_VLM,
               "audio": TINY_AUDIO}


@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", None), ("hybrid", None), ("vlm", None), ("audio", None),
    ("dense", "int8"), ("hybrid", "int8"),
])
def test_spilled_slot_roundtrip_across_pools(family, kv_dtype):
    """spill -> ``to_bytes`` -> ``from_bytes`` -> restore into a DIFFERENT
    pool's free pages is exact for every cache-state family: pure paged
    attention (dense), paged KV + recurrent mamba rows (hybrid), and the
    per-slot cross blocks (vlm, audio). The receiving batcher has a
    different pool size and a rotated free list, so the snapshot lands in
    physically different pages; the finished output must still be
    bit-identical to an uninterrupted single-batcher run.

    The int8 variants run the same round trip on quantized pools: the wire
    payload then carries int8 page bytes PLUS their fp32 per-page scales,
    and a restore into a same-dtype pool is a byte copy — so the migrated
    output must match an uninterrupted int8 run bit-for-bit (the spill is
    exact even though quantization itself is lossy)."""
    cfg = FAMILY_CFGS[family]
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2,
                                             overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    aux = None
    if family == "vlm":
        params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
            params["units"]["cross"]["xgate"])
        aux = {"image_embs": 4.0 * np.random.RandomState(3)
               .randn(cfg.n_image_tokens, cfg.d_model).astype(np.float32)}
    elif family == "audio":
        aux = {"audio_embs": 4.0 * np.random.RandomState(3)
               .randn(cfg.n_audio_frames, cfg.d_model).astype(np.float32)}
    prompt = (np.arange(1, 9) * 5 % cfg.vocab_size).astype(np.int32)
    max_new, seed = 8, 11
    kw = dict(CB_KW, num_slots=1, kv_dtype=kv_dtype)

    base = unified_seq(dbm, params, [(prompt, max_new, aux)], seed,
                       num_slots=1, kv_dtype=kv_dtype)[0]

    # interrupted run: 2 prefill chunks + 1 decode segment, then spill
    src = ContinuousBatcher(dbm, params, **kw)
    rid = src.submit(prompt, max_new, aux_inputs=aux)
    rng = jax.random.PRNGKey(seed)
    for _ in range(3):
        rng, f = src.step(rng, strict=False)
        assert not f
    with src._pool_lock:
        req = src._spill_slot(0)
    assert req.rid == rid and 0 < len(req.out) < max_new, req.out
    assert len(src.free_pages) == src.total_pages - 1, "pages leaked"
    used_src = {e[0].shape[0] if isinstance(e, tuple) else None
                for e in req.spilled.data}

    # wire format: the payload crosses pools as numpy bytes, no pickle
    raw = req.spilled.to_bytes()
    assert isinstance(raw, bytes)
    req.spilled = SpilledSlot.from_bytes(raw)
    assert {e[0].shape[0] if isinstance(e, tuple) else None
            for e in req.spilled.data} == used_src
    paged_entries = [e for e in req.spilled.data if isinstance(e, tuple)]
    if kv_dtype == "int8":   # scales must survive the wire round trip
        assert paged_entries and all(len(e) == 4 for e in paged_entries)
        assert all(e[0].dtype == np.int8 and e[2].dtype == np.float32
                   for e in paged_entries)
    else:
        assert all(len(e) == 2 for e in paged_entries)

    # different pool (bigger, rotated free list) so the restore cannot
    # land in the same physical ids; same num_slots (see unified_seq note)
    dst = ContinuousBatcher(dbm, params,
                            **dict(kw, total_pages=src.total_pages + 6))
    dst.free_pages = dst.free_pages[5:] + dst.free_pages[:5]
    dst.submit_request(req)
    fin = []
    while dst.has_work():
        rng, f = dst.step(rng, strict=False)
        fin.extend(f)
    assert len(fin) == 1 and fin[0].error is None
    assert dst.restores == 1
    assert list(fin[0].out) == base, (family, fin[0].out, base)
    assert len(dst.free_pages) == dst.total_pages - 1 and not dst.page_refs


def test_spilled_slot_cross_dtype_restore_refused(dense_env):
    """A snapshot spilled from an int8 pool must NOT restore into a pool
    with a different KV storage dtype: reinterpreting int8 page bytes as
    dense floats would silently serve garbage KV. The restoring step has to
    fail LOUDLY with the remediation (same ``--kv-dtype`` everywhere, or
    re-prefill on the destination) — and the mismatch must survive the wire
    round trip, which is exactly where disagg deployments with divergent
    worker configs would hit it."""
    dbm, params = dense_env
    prompt = (np.arange(1, 9) * 5 % TINY.vocab_size).astype(np.int32)
    kw = dict(CB_KW, num_slots=1)

    src = ContinuousBatcher(dbm, params, **dict(kw, kv_dtype="int8"))
    src.submit(prompt, 8)
    rng = jax.random.PRNGKey(11)
    for _ in range(3):
        rng, f = src.step(rng, strict=False)
        assert not f
    with src._pool_lock:
        req = src._spill_slot(0)
    req.spilled = SpilledSlot.from_bytes(req.spilled.to_bytes())

    # destination pool keeps the policy's dense KV dtype (fp32 here)
    dst = ContinuousBatcher(dbm, params, **kw)
    dst.submit_request(req)
    with pytest.raises(ValueError,
                       match=r"cache-state dtype mismatch") as ei:
        dst.step(rng, strict=False)
    msg = str(ei.value)
    assert "--kv-dtype" in msg and "re-prefill" in msg, msg
