"""DiffusionBlocks training semantics: structural block independence,
view extraction/write-back, and learning on a tiny exact task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db, train_e2e
from repro.core.training import (extract_block_view, make_db_train_step,
                                 write_back_block_view)
from repro.data import arithmetic_stream

TINY = ModelConfig(name="tiny", family="dense", n_layers=6, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def dbm():
    return DiffusionBlocksModel(TINY, DBConfig(num_blocks=3,
                                               overlap_gamma=0.05))


def test_view_roundtrip(dbm):
    params = dbm.init(jax.random.PRNGKey(0))
    start, size = dbm.ranges[1]
    view = extract_block_view(params, start, size)
    assert view["layers"]["attn"]["wq"].shape[0] == size
    back = write_back_block_view(params, view, start)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_block_step_leaves_other_blocks_untouched(dbm):
    """THE paper property: training block b must not move any other block's
    parameters (gradients for them are never materialized)."""
    params = dbm.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(arithmetic_stream(4, 32, 64, 0))
    tcfg = TrainConfig(steps=4, lr=1e-2, warmup_steps=0)
    b = 1
    init_opt, step = make_db_train_step(dbm, b, tcfg)
    opt = init_opt(params)
    p2, _, loss, _ = step(params, opt, tokens, jax.random.PRNGKey(1), None)
    start, size = dbm.ranges[b]
    layers0 = params["layers"]
    layers2 = p2["layers"]
    for (path, a), (_, c) in zip(
            jax.tree_util.tree_flatten_with_path(layers2)[0],
            jax.tree_util.tree_flatten_with_path(layers0)[0]):
        a, c = np.asarray(a), np.asarray(c)
        outside = np.concatenate([a[:start], a[start + size:]])
        outside_ref = np.concatenate([c[:start], c[start + size:]])
        np.testing.assert_array_equal(outside, outside_ref,
                                      err_msg=f"other-block moved: {path}")
        # at least some inside params must move
    moved = any(
        not np.allclose(np.asarray(a)[start:start + size],
                        np.asarray(c)[start:start + size])
        for a, c in zip(jax.tree_util.tree_leaves(layers2),
                        jax.tree_util.tree_leaves(layers0)))
    assert moved


def test_grads_structurally_restricted(dbm):
    """The loss only reads the view — grads have the view's (small) shape."""
    params = dbm.init(jax.random.PRNGKey(0))
    start, size = dbm.ranges[0]
    view = extract_block_view(params, start, size)
    tokens = jnp.asarray(arithmetic_stream(2, 16, 64, 0))

    def loss_fn(v):
        return dbm.block_loss(v, 0, tokens, jax.random.PRNGKey(1),
                              unit_range=(0, size))[0]

    g = jax.grad(loss_fn)(view)
    assert g["layers"]["attn"]["wq"].shape[0] == size  # not n_layers
    total = sum(x.size for x in jax.tree_util.tree_leaves(g))
    full = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert total < full  # strictly fewer gradient elements than e2e


def test_db_training_learns():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=3,
                                              overlap_gamma=0.05))
    tcfg = TrainConfig(steps=45, lr=2e-3, warmup_steps=5, log_every=0)

    def it():
        s = 0
        while True:
            s += 1
            yield jnp.asarray(arithmetic_stream(16, 32, 64, s))

    params, hist = train_db(dbm, tcfg, it(), jax.random.PRNGKey(0),
                            log=lambda *_: None)
    first = np.mean([l for _, _, l in hist[:9]])
    last = np.mean([l for _, _, l in hist[-9:]])
    assert last < first * 0.8, (first, last)


def test_e2e_training_learns():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=3))
    tcfg = TrainConfig(steps=30, lr=2e-3, warmup_steps=5, log_every=0)

    def it():
        s = 0
        while True:
            s += 1
            yield jnp.asarray(arithmetic_stream(16, 32, 64, s))

    params, hist = train_e2e(dbm, tcfg, it(), jax.random.PRNGKey(0),
                             log=lambda *_: None)
    assert hist[-1][2] < hist[0][2] * 0.9


def test_two_pass_equals_concat_objective():
    """For an attention arch both causal modes implement the same objective:
    with identical (σ, ε) draws the losses must match."""
    db_c = DBConfig(num_blocks=2, causal_mode="concat", overlap_gamma=0.0)
    db_t = DBConfig(num_blocks=2, causal_mode="two_pass", overlap_gamma=0.0)
    dbm_c = DiffusionBlocksModel(TINY, db_c)
    dbm_t = DiffusionBlocksModel(TINY, db_t)
    params = dbm_c.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(arithmetic_stream(2, 12, 64, 3))
    rng = jax.random.PRNGKey(7)
    l1, _ = dbm_c.block_loss(params, 0, tokens, rng)
    l2, _ = dbm_t.block_loss(params, 0, tokens, rng)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-4)
