"""Paper adapters: ViT classification (§5.1), masked diffusion (§5.3/App. D),
recurrent-depth (§5.5), MoE layer invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.masked import MaskedDiffusionBlocks
from repro.core.recurrent import RecurrentDepthModel
from repro.core.vit import ViTDiffusionBlocks
from repro.data import GaussianMixtureImages, MarkovLM
from repro.nn.moe import moe_fwd, moe_spec
from repro.nn.init import init_params


def test_vit_adapter_trains_and_predicts():
    cfg = ModelConfig(name="vit-t", family="dense", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=6,
                      norm="layernorm", mlp="gelu", rope_theta=0.0)
    db = DBConfig(num_blocks=2, overlap_gamma=0.05)
    vit = ViTDiffusionBlocks(cfg, db, image_size=8, patch=4, channels=3)
    params = vit.init(jax.random.PRNGKey(0))
    g = GaussianMixtureImages(num_classes=6, image_size=8, noise_scale=0.2)
    x, y = g.sample(np.random.RandomState(0), 16)
    x, y = jnp.asarray(x), jnp.asarray(y)
    for b in range(2):
        loss, _ = vit.block_loss(params, b, x, y, jax.random.PRNGKey(b))
        assert np.isfinite(float(loss))
    le, _ = vit.e2e_loss(params, x, y)
    assert np.isfinite(float(le))
    pred, logits = vit.predict(params, x, jax.random.PRNGKey(3))
    assert pred.shape == (16,) and logits.shape == (16, 6)
    # quick learning check: a few AdamW steps reduce block-0 loss
    from repro.optim import adamw, apply_updates
    init, update = adamw(3e-3)
    st = init(params)
    losses = []
    for i in range(25):
        def lf(p):
            return vit.block_loss(p, 1, x, y, jax.random.PRNGKey(5))[0]
        loss, grads = jax.value_and_grad(lf)(params)
        upd, st, _ = update(grads, st, params)
        params = apply_updates(params, upd)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_mdm_adapter_mass_partition_and_training():
    cfg = ModelConfig(name="mdm-t", family="dense", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=33,
                      norm="layernorm", mlp="gelu")
    db = DBConfig(num_blocks=2, overlap_gamma=0.0)
    mdm = MaskedDiffusionBlocks(cfg, db)
    # App. D: equal decrements of alpha — for linear schedule, t_b = b/B
    assert mdm.t_range(0) == (0.5, 1.0)        # block 0 = highest masking
    assert mdm.t_range(1) == (0.0, 0.5)
    assert mdm.block_of_t(0.9) == 0 and mdm.block_of_t(0.1) == 1
    params = mdm.init(jax.random.PRNGKey(0))
    lm = MarkovLM(vocab_size=32, seed=1)
    toks = jnp.asarray(lm.sample(np.random.RandomState(0), 4, 32))
    for b in range(2):
        loss, m = mdm.block_loss(params, b, toks, jax.random.PRNGKey(b))
        assert np.isfinite(float(loss))
    # block 0 must mask more than block 1 on average
    _, m0 = mdm.block_loss(params, 0, toks, jax.random.PRNGKey(5))
    _, m1 = mdm.block_loss(params, 1, toks, jax.random.PRNGKey(5))
    assert float(m0["mask_rate"]) > float(m1["mask_rate"])
    bpc = mdm.nelbo_bpc(params, toks, jax.random.PRNGKey(9), n_samples=1)
    assert np.isfinite(float(bpc))
    out = mdm.generate(params, jax.random.PRNGKey(11), 2, 16, num_steps=6)
    assert out.shape == (2, 16)
    assert bool(jnp.all(out != mdm.mask_id))


def test_recurrent_depth_db_vs_baseline():
    cfg = ModelConfig(name="hug-t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)
    db = DBConfig(num_blocks=1, overlap_gamma=0.0)
    m = RecurrentDepthModel(cfg, db, prelude=1, coda=1, recurrence=4,
                            bptt_k=2)
    params = m.init(jax.random.PRNGKey(0))
    lm = MarkovLM(vocab_size=64, seed=1)
    toks = jnp.asarray(lm.sample(np.random.RandomState(0), 4, 24))
    lb, _ = m.baseline_loss(params, toks, jax.random.PRNGKey(1))
    ld, _ = m.db_loss(params, toks, jax.random.PRNGKey(1))
    assert np.isfinite(float(lb)) and np.isfinite(float(ld))
    logits = m.db_generate_logits(params, toks, num_steps=4)
    assert logits.shape == (4, 24, 64)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_moe_invariants():
    cfg = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0)
    spec = moe_spec(32, 64, cfg, "swiglu")
    p = init_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_fwd(p, x, cfg, "swiglu")
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss lower-bounded by 1 (perfect balance) for softmax gates
    assert float(aux) >= 0.99
    # capacity drop: with tiny capacity, outputs shrink but stay finite
    out2, _ = moe_fwd(p, x, dataclasses.replace(cfg, capacity_factor=0.1),
                      "swiglu")
    assert bool(jnp.all(jnp.isfinite(out2)))
    assert float(jnp.linalg.norm(out2)) <= float(jnp.linalg.norm(out)) + 1e-3


def test_moe_grouping_invariance():
    cfg = MoEConfig(num_experts=4, top_k=1, capacity_factor=4.0)
    spec = moe_spec(16, 32, cfg, "gelu")
    p = init_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    o1, _ = moe_fwd(p, x, cfg, "gelu", group_size=16)
    o2, _ = moe_fwd(p, x, cfg, "gelu", group_size=64)
    # generous capacity => no drops => grouping must not matter
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
