"""Pallas kernel sweeps: every kernel × shapes × dtypes vs the pure-jnp
oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.edm_loss import edm_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_self, flash_decode
from repro.kernels.fused_adaln import (fused_euler, fused_gate_residual,
                                       fused_ln_modulate)
from repro.nn import cache as KVC

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,KV,Sq,Sk,hd", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 128, 64),     # GQA
    (1, 4, 1, 96, 200, 32),      # MQA, ragged (padding path)
    (2, 2, 2, 256, 256, 128),    # MXU-aligned
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention_sweep(B, H, KV, Sq, Sk, hd, dtype, causal, window):
    if not causal and window is not None:
        pytest.skip("window implies causal")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), dtype)
    k = jax.random.normal(k2, (B, KV, Sk, hd), dtype)
    v = jax.random.normal(k3, (B, KV, Sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(1, 64, 128), (2, 100, 256), (3, 513, 64)])
def test_fused_ln_modulate_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(k1, (B, S, d), dtype)
    sc = (0.1 * jax.random.normal(k2, (B, d))).astype(dtype)
    sh = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)
    out = fused_ln_modulate(x, sc, sh, block_rows=64, interpret=True)
    expect = ref.ln_modulate_reference(x, sc, sh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 257, 64)])
def test_fused_gate_residual_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    r = jax.random.normal(k1, (B, S, d), dtype)
    br = jax.random.normal(k2, (B, S, d), dtype)
    g = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)
    out = fused_gate_residual(r, br, g, block_rows=64, interpret=True)
    expect = ref.gate_residual_reference(r, br, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 130, 64)])
def test_fused_euler_sweep(B, S, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    z = jax.random.normal(k1, (B, S, d), dtype)
    f = jax.random.normal(k2, (B, S, d), dtype)
    sig = jnp.linspace(0.5, 3.0, B)
    sig2 = sig * 0.3
    out = fused_euler(z, f, sig, sig2, 0.5, block_rows=64, interpret=True)
    expect = ref.euler_reference(z, f, sig, sig2, 0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("B,KV,G,hd,psz,npg", [
    (2, 2, 2, 32, 8, 4),      # GQA
    (1, 4, 1, 64, 16, 2),     # MQA-ish (G=1: group-pad path)
    (3, 1, 8, 32, 4, 8),      # wide group, many small pages
])
def test_flash_decode_sweep(B, KV, G, hd, psz, npg, window, dtype):
    """Split-KV paged decode kernel vs the gather reference: ragged lengths
    (incl. an EMPTY slot and a full slot), GQA grouping, window masking,
    bf16 pages with fp32 logsumexp. fp32 must match <=1e-4 (ISSUE gate)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    P = 1 + B * npg
    pool = KVC.PagedKV(
        jax.random.normal(ks[0], (P, psz, KV, hd), dtype),
        jax.random.normal(ks[1], (P, psz, KV, hd), dtype))
    table = KVC.identity_page_table(B, npg)
    # ragged: slot 0 empty, last slot full, middle arbitrary
    lens = np.linspace(0, npg * psz, B).astype(np.int32)
    lengths = jnp.asarray(lens)
    q = jax.random.normal(ks[2], (B, KV, G, hd), dtype)
    k_self = jax.random.normal(ks[3], (B, KV, hd), dtype)
    v_self = jax.random.normal(ks[4], (B, KV, hd), dtype)
    out_p, lse = flash_decode(q, pool.k, pool.v, table, lengths,
                              window=window, interpret=True)
    scale = 1.0 / (hd ** 0.5)
    s_self = jnp.einsum("bkgd,bkd->bkg", q.astype(jnp.float32),
                        k_self.astype(jnp.float32)) * scale
    got = combine_self(out_p, lse, s_self, v_self.astype(jnp.float32))
    expect = KVC._attend_pages_ref(q, pool, table, lengths, k_self, v_self,
                                   window)
    tol_ = dict(atol=1e-4, rtol=1e-4) if dtype == jnp.float32 else tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), **tol_)


def test_flash_decode_trash_page_entries_inert():
    """Page-table entries past a slot's allocation point at the trash page;
    whatever garbage lives there must never leak into the output."""
    dims_kv, G, hd, psz, npg = 2, 2, 32, 4, 3
    P = 1 + npg
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    pool = KVC.PagedKV(jax.random.normal(k1, (P, psz, dims_kv, hd)),
                       jax.random.normal(k2, (P, psz, dims_kv, hd)))
    # slot uses only its first page (length 3 < psz); rest point at trash
    table = jnp.asarray([[1, KVC.TRASH_PAGE, KVC.TRASH_PAGE]], jnp.int32)
    lengths = jnp.asarray([3], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(2), (1, dims_kv, G, hd))
    out1, lse1 = flash_decode(q, pool.k, pool.v, table, lengths,
                              interpret=True)
    poisoned = KVC.PagedKV(pool.k.at[KVC.TRASH_PAGE].set(1e3),
                           pool.v.at[KVC.TRASH_PAGE].set(1e3))
    out2, lse2 = flash_decode(q, poisoned.k, poisoned.v, table, lengths,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
    np.testing.assert_allclose(np.asarray(lse1), np.asarray(lse2))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 300, 64)])
def test_edm_loss_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    f = jax.random.normal(k1, (B, S, d), dtype)
    z = jax.random.normal(k2, (B, S, d), dtype)
    y = jax.random.normal(k3, (B, S, d), dtype)
    sig = jnp.linspace(0.3, 2.0, B)
    out = edm_loss(f, z, y, sig, 0.5, interpret=True)
    expect = ref.edm_loss_reference(f, z, y, sig, 0.5)
    np.testing.assert_allclose(float(out), float(expect), rtol=1e-5)
