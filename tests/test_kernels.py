"""Pallas kernel sweeps: every kernel × shapes × dtypes vs the pure-jnp
oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.edm_loss import edm_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_self, flash_decode
from repro.kernels.fused_adaln import (fused_euler, fused_gate_residual,
                                       fused_ln_modulate)
from repro.nn import cache as KVC

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,KV,Sq,Sk,hd", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 128, 64),     # GQA
    (1, 4, 1, 96, 200, 32),      # MQA, ragged (padding path)
    (2, 2, 2, 256, 256, 128),    # MXU-aligned
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention_sweep(B, H, KV, Sq, Sk, hd, dtype, causal, window):
    if not causal and window is not None:
        pytest.skip("window implies causal")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), dtype)
    k = jax.random.normal(k2, (B, KV, Sk, hd), dtype)
    v = jax.random.normal(k3, (B, KV, Sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(1, 64, 128), (2, 100, 256), (3, 513, 64)])
def test_fused_ln_modulate_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(k1, (B, S, d), dtype)
    sc = (0.1 * jax.random.normal(k2, (B, d))).astype(dtype)
    sh = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)
    out = fused_ln_modulate(x, sc, sh, block_rows=64, interpret=True)
    expect = ref.ln_modulate_reference(x, sc, sh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 257, 64)])
def test_fused_gate_residual_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    r = jax.random.normal(k1, (B, S, d), dtype)
    br = jax.random.normal(k2, (B, S, d), dtype)
    g = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)
    out = fused_gate_residual(r, br, g, block_rows=64, interpret=True)
    expect = ref.gate_residual_reference(r, br, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 130, 64)])
def test_fused_euler_sweep(B, S, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    z = jax.random.normal(k1, (B, S, d), dtype)
    f = jax.random.normal(k2, (B, S, d), dtype)
    sig = jnp.linspace(0.5, 3.0, B)
    sig2 = sig * 0.3
    out = fused_euler(z, f, sig, sig2, 0.5, block_rows=64, interpret=True)
    expect = ref.euler_reference(z, f, sig, sig2, 0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("B,KV,G,hd,psz,npg", [
    (2, 2, 2, 32, 8, 4),      # GQA
    (1, 4, 1, 64, 16, 2),     # MQA-ish (G=1: group-pad path)
    (3, 1, 8, 32, 4, 8),      # wide group, many small pages
])
def test_flash_decode_sweep(B, KV, G, hd, psz, npg, window, dtype):
    """Split-KV paged decode kernel vs the gather reference: ragged lengths
    (incl. an EMPTY slot and a full slot), GQA grouping, window masking,
    bf16 pages with fp32 logsumexp. fp32 must match <=1e-4 (ISSUE gate)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    P = 1 + B * npg
    pool = KVC.PagedKV(
        jax.random.normal(ks[0], (P, psz, KV, hd), dtype),
        jax.random.normal(ks[1], (P, psz, KV, hd), dtype))
    table = KVC.identity_page_table(B, npg)
    # ragged: slot 0 empty, last slot full, middle arbitrary
    lens = np.linspace(0, npg * psz, B).astype(np.int32)
    lengths = jnp.asarray(lens)
    q = jax.random.normal(ks[2], (B, KV, G, hd), dtype)
    k_self = jax.random.normal(ks[3], (B, KV, hd), dtype)
    v_self = jax.random.normal(ks[4], (B, KV, hd), dtype)
    out_p, lse = flash_decode(q, pool.k, pool.v, table, lengths,
                              window=window, interpret=True)
    scale = 1.0 / (hd ** 0.5)
    s_self = jnp.einsum("bkgd,bkd->bkg", q.astype(jnp.float32),
                        k_self.astype(jnp.float32)) * scale
    got = combine_self(out_p, lse, s_self, v_self.astype(jnp.float32))
    expect = KVC._attend_pages_ref(q, pool, table, lengths, k_self, v_self,
                                   window)
    tol_ = dict(atol=1e-4, rtol=1e-4) if dtype == jnp.float32 else tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), **tol_)


def test_flash_decode_trash_page_entries_inert():
    """Page-table entries past a slot's allocation point at the trash page;
    whatever garbage lives there must never leak into the output."""
    dims_kv, G, hd, psz, npg = 2, 2, 32, 4, 3
    P = 1 + npg
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    pool = KVC.PagedKV(jax.random.normal(k1, (P, psz, dims_kv, hd)),
                       jax.random.normal(k2, (P, psz, dims_kv, hd)))
    # slot uses only its first page (length 3 < psz); rest point at trash
    table = jnp.asarray([[1, KVC.TRASH_PAGE, KVC.TRASH_PAGE]], jnp.int32)
    lengths = jnp.asarray([3], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(2), (1, dims_kv, G, hd))
    out1, lse1 = flash_decode(q, pool.k, pool.v, table, lengths,
                              interpret=True)
    poisoned = KVC.PagedKV(pool.k.at[KVC.TRASH_PAGE].set(1e3),
                           pool.v.at[KVC.TRASH_PAGE].set(1e3))
    out2, lse2 = flash_decode(q, poisoned.k, poisoned.v, table, lengths,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
    np.testing.assert_allclose(np.asarray(lse1), np.asarray(lse2))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 300, 64)])
def test_edm_loss_sweep(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    f = jax.random.normal(k1, (B, S, d), dtype)
    z = jax.random.normal(k2, (B, S, d), dtype)
    y = jax.random.normal(k3, (B, S, d), dtype)
    sig = jnp.linspace(0.3, 2.0, B)
    out = edm_loss(f, z, y, sig, 0.5, interpret=True)
    expect = ref.edm_loss_reference(f, z, y, sig, 0.5)
    np.testing.assert_allclose(float(out), float(expect), rtol=1e-5)


# ---------------------------------------------------------------------------
# int8 KV: quantize round-trip bounds + quantized kernels vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("psz,KV,hd", [(4, 2, 16), (8, 1, 32), (16, 4, 8)])
def test_quantize_roundtrip_error_bound(psz, KV, hd):
    """Per-page symmetric absmax int8: |dequant - x| <= scale/2 elementwise
    (half a quantization step), scales are fp32 with the page axis aligned
    to PAGE_AXIS, and an all-zero page round-trips exactly with scale 0."""
    rng = np.random.RandomState(0)
    P = 6
    x = jnp.asarray(rng.randn(P, psz, KV, hd) *
                    rng.uniform(0.1, 10.0, size=(P, 1, 1, 1)), jnp.float32)
    x = x.at[-1].set(0.0)                       # empty page
    q, s = KVC.quantize_pages(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == (P, 1, 1, 1)              # broadcasts at PAGE_AXIS
    got = KVC.dequantize_pages(q, s)
    err = np.abs(np.asarray(got) - np.asarray(x))
    bound = np.asarray(s) / 2 + 1e-7
    assert (err <= bound).all(), (err.max(), np.asarray(s).ravel())
    np.testing.assert_array_equal(np.asarray(got[-1]), 0.0)
    assert float(s[-1].reshape(())) == 0.0
    # the max-magnitude element of each non-empty page hits the full range
    np.testing.assert_allclose(
        np.abs(np.asarray(q[:-1])).reshape(P - 1, -1).max(1), 127.0)


def _quantized_pool(rng, P, psz, KV, hd):
    kf = jnp.asarray(rng.randn(P, psz, KV, hd), jnp.float32)
    vf = jnp.asarray(rng.randn(P, psz, KV, hd), jnp.float32)
    qk, ks = KVC.quantize_pages(kf)
    qv, vs = KVC.quantize_pages(vf)
    return KVC.PagedKV(qk, qv, ks, vs)


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("B,KV,G,hd,psz,npg", [
    (2, 2, 2, 32, 8, 4),      # GQA
    (1, 4, 1, 64, 16, 2),     # MQA-ish (G=1: group-pad path)
    (3, 1, 8, 32, 4, 8),      # wide group, many small pages
])
def test_flash_decode_int8_sweep(B, KV, G, hd, psz, npg, window):
    """int8 decode kernel (scales scalar-prefetched, dequant fused in
    registers) vs the quantized gather reference — the SAME dequantized
    values feed both, so parity is tight fp32."""
    rng = np.random.RandomState(3)
    pool = _quantized_pool(rng, 1 + B * npg, psz, KV, hd)
    assert pool.quantized
    table = KVC.identity_page_table(B, npg)
    lengths = jnp.asarray(np.linspace(0, npg * psz, B).astype(np.int32))
    q = jnp.asarray(rng.randn(B, KV, G, hd), jnp.float32)
    k_self = jnp.asarray(rng.randn(B, KV, hd), jnp.float32)
    v_self = jnp.asarray(rng.randn(B, KV, hd), jnp.float32)
    out_p, lse = flash_decode(q, pool.k, pool.v, table, lengths,
                              window=window, k_scale=pool.k_scale,
                              v_scale=pool.v_scale, interpret=True)
    scale = 1.0 / (hd ** 0.5)
    s_self = jnp.einsum("bkgd,bkd->bkg", q, k_self) * scale
    got = combine_self(out_p, lse, s_self, v_self)
    expect = KVC._attend_pages_ref(q, pool, table, lengths, k_self, v_self,
                                   window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32),
                               atol=1e-4, rtol=1e-4)


def test_flash_decode_int8_trash_page_inert():
    """Poisoned trash-page CONTENT and SCALE must never leak into output."""
    KV, G, hd, psz, npg = 2, 2, 32, 4, 3
    rng = np.random.RandomState(4)
    pool = _quantized_pool(rng, 1 + npg, psz, KV, hd)
    table = jnp.asarray([[1, KVC.TRASH_PAGE, KVC.TRASH_PAGE]], jnp.int32)
    lengths = jnp.asarray([3], jnp.int32)
    q = jnp.asarray(rng.randn(1, KV, G, hd), jnp.float32)
    out1, lse1 = flash_decode(q, pool.k, pool.v, table, lengths,
                              k_scale=pool.k_scale, v_scale=pool.v_scale,
                              interpret=True)
    poisoned = KVC.PagedKV(
        pool.k.at[KVC.TRASH_PAGE].set(127), pool.v.at[KVC.TRASH_PAGE].set(127),
        pool.k_scale.at[KVC.TRASH_PAGE].set(1e3),
        pool.v_scale.at[KVC.TRASH_PAGE].set(1e3))
    out2, lse2 = flash_decode(q, poisoned.k, poisoned.v, table, lengths,
                              k_scale=poisoned.k_scale,
                              v_scale=poisoned.v_scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
    np.testing.assert_allclose(np.asarray(lse1), np.asarray(lse2))


@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("G", [1, 2])
def test_flash_prefill_int8_matches_ref(window, G):
    """int8 chunked-prefill kernel vs the quantized gather reference over a
    pool built through the REAL quantized append paths (token + chunk)."""
    rng = np.random.RandomState(5)
    B, C, KV, hd, psz = 3, 6, 2, 16, 4
    from repro.nn import attention as A
    dims = A.AttnDims(KV * G, KV, hd)
    lengths = jnp.asarray([0, 3, 9], jnp.int32)
    pps = KVC.pages_for(16, psz)
    pkv = KVC.init_paged_kv(1 + B * pps, psz, dims, jnp.int8)
    assert pkv.quantized
    table = KVC.identity_page_table(B, pps)
    for t in range(int(jnp.max(lengths))):
        kt = jnp.asarray(rng.randn(B, KV, hd), jnp.float32)
        pkv = KVC.append_paged(pkv, kt, kt * 0.5, table,
                               jnp.minimum(lengths, t), active=t < lengths)
    k_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    v_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    n_valid = jnp.asarray([6, 4, 2], jnp.int32)
    pkv = KVC.append_paged_chunk(pkv, k_new, v_new, table, lengths, n_valid)
    q = jnp.asarray(rng.randn(B, C, KV, G, hd), jnp.float32)
    ref_out = KVC.attend_prefill(q, pkv, table, lengths, window=window,
                                 impl="auto")
    ker_out = KVC.attend_prefill(q, pkv, table, lengths, window=window,
                                 impl="kernels")
    for b in range(B):
        nv = int(n_valid[b])
        if nv:
            np.testing.assert_allclose(np.asarray(ker_out)[b, :nv],
                                       np.asarray(ref_out)[b, :nv],
                                       atol=1e-4, rtol=1e-4)
